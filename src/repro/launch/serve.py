"""Serving launcher: continuous-batching engine on a trained (or random)
model with a synthetic request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --reduced --requests 16 --rate 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.core.scheduling.request import Request
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas paged-attention (interpret mode on CPU)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix KV cache (cross-request reuse)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=args.pages, page_size=args.page_size,
        max_slots=args.slots, temperature=args.temperature,
        use_kernel=args.use_kernel, enable_prefix_cache=args.prefix_cache))

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        reqs.append(Request(i, float(arrivals[i]),
                            rng.integers(0, cfg.vocab_size, plen).tolist(),
                            max_new_tokens=int(rng.integers(
                                2, args.max_new))))

    t0 = time.monotonic()
    i = 0
    while i < len(reqs) or eng.scheduler.waiting or eng.scheduler.running:
        now = time.monotonic() - t0
        while i < len(reqs) and reqs[i].arrival_time <= now:
            eng.add_request(reqs[i])
            i += 1
        fin = eng.step(now)
        for r in fin:
            print(f"[{now:7.2f}s] req {r.request_id} done: "
                  f"{len(r.full_output)} tokens "
                  f"(norm-lat {r.normalized_latency():.3f}s/tok)")
        if not fin and not eng.scheduler.running and i < len(reqs):
            time.sleep(max(0.0, reqs[i].arrival_time - now))
    tok = sum(r.total_generated for r in reqs)
    dt = time.monotonic() - t0
    print(f"served {len(reqs)} requests, {tok} tokens in {dt:.1f}s "
          f"({tok/dt:.1f} tok/s, {eng.iterations} iterations), "
          f"kv-util {eng.kv_utilization():.2f}")
    stats = eng.prefix_cache_stats()
    if stats:
        print(f"prefix-cache hit-rate {stats['hit_rate']:.1%}, "
              f"{stats['cached_pages']:.0f} pages resident")


if __name__ == "__main__":
    main()
