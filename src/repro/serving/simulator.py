"""Event-driven serving simulator (paper §III.E).

The paper tabulates results from the ORCA/vLLM/InfiniteLLM papers because the
authors "lacked the computing abilities" to run the systems. We instead
*simulate* the serving cluster with an explicit iteration cost model, so
Fig. 9 / Fig. 10-style sweeps run on this CPU container while exercising the
real scheduler + allocator code paths from ``repro.core``.

Cost model (per engine iteration, A100-ish serving OPT-13B unless overridden):
  t_iter = t_fixed + c_token * (#tokens through MLP/linear, the selective-
           batching flattened buffer) + c_ctx * Σ context lens (attention
           reads) [+ c_remote * Σ remote context (DistKV borrowed rBlocks)]

All schedulers/allocators are the *real* implementations — the simulator only
replaces the model execution with the cost model and draws output lengths
from request metadata.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.distkv.gmanager import GManager
from repro.core.distkv.netmodel import NetworkModel
from repro.core.distkv.rmanager import RManager
from repro.core.paging.allocator import (BlockAllocator,
                                         ContiguousPreallocAllocator,
                                         OutOfBlocks)
from repro.core.prefixcache.radix import PrefixCache
from repro.core.scheduling.batch import BatchScheduler
from repro.core.scheduling.iteration import IterationScheduler
from repro.core.scheduling.request import Phase, Request
from repro.core.telemetry import MetricsRegistry, Tracer, percentile


@dataclasses.dataclass
class CostModel:
    t_fixed: float = 0.004       # kernel-launch/sync floor per iteration
    c_token: float = 12e-6       # s per flattened token (linear layers)
    c_ctx: float = 18e-9         # s per cached token read (attention)
    # borrowed rBlocks: DistAttention computes the micro-attention where the
    # block lives and ships only (o, m, l) partials, so the penalty is the
    # merge + coordination, not a remote read of the whole page (~35% extra)
    c_remote: float = 6e-9

    def iteration_time(self, n_tokens: int, sum_ctx: int,
                       sum_remote_ctx: int = 0) -> float:
        return (self.t_fixed + self.c_token * n_tokens +
                self.c_ctx * sum_ctx + self.c_remote * sum_remote_ctx)

    @staticmethod
    def prefill_read_tokens(start: int, length: int) -> int:
        """Attention KV reads of one prefill chunk computing prompt tokens
        ``[start, start+length)``: every chunk token reads the ``start``
        tokens already in the cache (earlier chunks / radix-cached prefix)
        plus its causal predecessors within the chunk. Charged via ``c_ctx``
        like decode reads, so N chunks of a prompt cost the same attention
        total as one monolithic prefill (start=0, length=P: P*(P-1)/2) —
        chunking only adds per-iteration ``t_fixed``, which is the real
        hardware trade."""
        return length * start + length * (length - 1) // 2


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    makespan: float
    peak_memory_frac: float = 0.0
    kv_utilization: float = 1.0
    preemptions: int = 0
    rejected: int = 0
    # radix prefix-cache stats (None when the cache is disabled)
    prefix_hit_rate: Optional[float] = None
    cached_pages: int = 0
    # multi-instance router runs: per-instance breakdown + adopted pages
    per_instance: Optional[Dict[int, Dict]] = None
    adopted_pages: int = 0
    # zero-copy runs: pages served in place via borrowed rBlocks, and the
    # modeled network time spent on copies + lease RPCs
    borrowed_pages: int = 0
    net_time: float = 0.0
    # host swap tier: swap-out / swap-in events and total PCIe time charged
    swapped_out: int = 0
    swapped_in: int = 0
    swap_time: float = 0.0
    # speculative swap-outs cancelled because pressure receded (the pages
    # never left the device)
    swap_cancels: int = 0
    # disaggregated runs: prefill->decode KV handoffs by path, and the
    # per-role metric timelines (role -> time-ordered rows)
    handoffs_migrated: int = 0
    handoffs_leased: int = 0
    handoff_deferrals: int = 0
    handoff_fallbacks: int = 0
    role_timelines: Optional[Dict[str, List[Dict]]] = None
    # telemetry (``trace=True`` runs only): merged tracer events on the
    # virtual clock, and per-instance metric timelines (instance -> rows)
    events: Optional[List] = None
    timelines: Optional[Dict[int, List[Dict]]] = None

    @property
    def max_tbts(self) -> np.ndarray:
        """Per-request worst inter-token gap (>= 2 tokens emitted) — the
        decode-stall metric chunked prefill targets."""
        return np.array([r.max_tbt for r in self.finished
                         if r.total_generated >= 2])

    @property
    def p99_tbt(self) -> float:
        """P99 of per-request worst inter-token gaps: a decode stalled
        behind a solo long prefill dominates this tail."""
        return float(percentile(self.max_tbts, 99))

    @property
    def finished(self) -> List[Request]:
        return [r for r in self.requests if r.finish_time is not None]

    @property
    def mean_ttft(self) -> float:
        """Mean time-to-first-token (prefill queueing + compute)."""
        ts = [r.first_token_time - r.arrival_time for r in self.requests
              if r.first_token_time is not None]
        return float(np.mean(ts)) if ts else float("inf")

    @property
    def completed_frac(self) -> float:
        return len(self.finished) / max(len(self.requests), 1)

    @property
    def normalized_latencies(self) -> np.ndarray:
        return np.array([r.normalized_latency() for r in self.finished])

    @property
    def mean_normalized_latency(self) -> float:
        ls = self.normalized_latencies
        return float(ls.mean()) if len(ls) else float("inf")

    @property
    def p99_normalized_latency(self) -> float:
        return float(percentile(self.normalized_latencies, 99))

    @property
    def throughput_tokens_per_s(self) -> float:
        """Useful throughput: tokens of *finished* requests only."""
        tok = sum(r.total_generated for r in self.finished)
        return tok / self.makespan if self.makespan > 0 else 0.0


def make_workload(n: int, *, rate: float, dist: str = "sharegpt",
                  seed: int = 0, long_frac: float = 0.0,
                  long_len: int = 16_384,
                  max_len: int = 2048,
                  materialize_tokens: bool = False,
                  vocab: int = 32_000) -> List[Request]:
    """Poisson arrivals; prompt/output lengths follow the named distribution.

    ``dist``: "sharegpt" (long, heavy-tailed outputs) | "alpaca" (short).
    ``long_frac``: fraction of requests with ~``long_len`` total context
    (the Fig. 10 knob: 1% / 10% long requests).
    ``materialize_tokens``: fill ``prompt`` with (unique) random token ids so
    the radix prefix cache has something to key on — the unique-prompt
    baseline for the prefix-cache sweep."""
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, n))
    reqs = []
    for i in range(n):
        if dist == "sharegpt":
            p = int(np.clip(rng.lognormal(4.9, 0.9), 4, max_len - 2))
            o = int(np.clip(rng.lognormal(5.2, 0.9), 1, max_len - p - 1))
        elif dist == "alpaca":
            p = int(np.clip(rng.lognormal(3.0, 0.8), 4, max_len - 2))
            o = int(np.clip(rng.lognormal(3.7, 0.9), 1, max_len - p - 1))
        else:
            raise ValueError(dist)
        if long_frac and rng.random() < long_frac:
            # long-context requests are prompt-heavy (long document in,
            # short answer out), as in the InfiniteLLM evaluation
            total = long_len
            p = max(4, int(total * rng.uniform(0.90, 0.97)))
            o = max(1, total - p)
        prompt = rng.integers(0, vocab, p).tolist() if materialize_tokens \
            else []
        reqs.append(Request(i, float(arr[i]), prompt, max_new_tokens=o,
                            prompt_len=p))
    return reqs


def make_shared_prefix_workload(n: int, *, rate: float, n_groups: int = 4,
                                prefix_len: int = 512, suffix_len: int = 64,
                                out_len: int = 128, seed: int = 0,
                                group_draw: str = "cyclic",
                                vocab: int = 32_000) -> List[Request]:
    """Shared-system-prompt traffic: each request is one of ``n_groups``
    shared system prompts plus a unique user suffix (real token ids so the
    radix cache can key on pages).

    ``group_draw``: "cyclic" assigns request ``i`` to group ``i % n_groups``
    (deterministic, good for single-instance cache studies); "random" draws
    the group per request (a stochastic tenant mix — required for honest
    multi-instance routing comparisons, where a cyclic assignment can
    accidentally align with a round-robin placement and look affine)."""
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.exponential(1.0 / rate, n))
    prefixes = [rng.integers(0, vocab, prefix_len).tolist()
                for _ in range(n_groups)]
    reqs = []
    for i in range(n):
        g = i % n_groups if group_draw == "cyclic" else \
            int(rng.integers(0, n_groups))
        suf = int(rng.integers(max(1, suffix_len // 2), suffix_len + 1))
        prompt = prefixes[g] + rng.integers(0, vocab, suf).tolist()
        o = int(np.clip(rng.lognormal(np.log(out_len), 0.4), 1, 4 * out_len))
        reqs.append(Request(i, float(arr[i]), prompt, max_new_tokens=o))
    return reqs


def make_few_shot_workload(n: int, *, rate: float, template_len: int = 1024,
                           question_len: int = 48, out_len: int = 32,
                           seed: int = 0, vocab: int = 32_000) -> List[Request]:
    """Few-shot template traffic: every request shares ONE long in-context
    example block and differs only in a short question (classification /
    extraction serving, the highest-hit-rate scenario)."""
    return make_shared_prefix_workload(
        n, rate=rate, n_groups=1, prefix_len=template_len,
        suffix_len=question_len, out_len=out_len, seed=seed, vocab=vocab)


def make_multi_turn_workload(n_sessions: int, n_turns: int, *, rate: float,
                             system_len: int = 128, user_len: int = 48,
                             reply_len: int = 96, think_time: float = 2.0,
                             service_time_per_token: float = 0.005,
                             seed: int = 0,
                             vocab: int = 32_000) -> List[Request]:
    """Multi-turn chat: turn ``t`` resends the full history (system prompt +
    prior user/assistant turns) plus a new user message, as chat clients do.
    Assistant replies are synthesized at build time (the simulator emits
    placeholder tokens, not real ones); the radix cache reuses the history's
    full pages across turns, so the hit rate grows with conversation depth.

    A real client cannot send turn ``t+1`` before turn ``t``'s reply streamed
    back, so the next arrival is spaced by an estimate of the reply's service
    time (``out_tokens * service_time_per_token``) plus ``think_time``. The
    estimate is approximate — under heavy congestion a turn may still arrive
    before its predecessor finished and simply miss the cache for the newest
    history pages."""
    rng = np.random.default_rng(seed)
    starts = np.cumsum(rng.exponential(1.0 / rate, n_sessions))
    reqs = []
    rid = 0
    for s in range(n_sessions):
        history = rng.integers(0, vocab, system_len).tolist()
        t_arr = float(starts[s])
        for _ in range(n_turns):
            user = rng.integers(
                0, vocab, int(rng.integers(max(1, user_len // 2),
                                           user_len + 1))).tolist()
            prompt = history + user
            o = int(rng.integers(max(1, reply_len // 2), reply_len + 1))
            reqs.append(Request(rid, t_arr, list(prompt), max_new_tokens=o))
            rid += 1
            # stand-in for the assistant reply the client would resend
            history = prompt + rng.integers(0, vocab, o).tolist()
            t_arr += o * service_time_per_token + think_time
    return sorted(reqs, key=lambda r: r.arrival_time)


# ---------------------------------------------------------------------------
# paged / iteration-level simulation (vLLM = paged; Orca variants = prealloc)
# ---------------------------------------------------------------------------

class SimBackend:
    """Cost-model ServingBackend: the *real* scheduler / allocator / radix
    tree driven on a virtual clock, with model execution replaced by the
    :class:`CostModel` (paper §III.E). Drop-in peer of ``PagedEngine``
    behind :class:`repro.serving.api.LLMService` — benchmarks choose the
    backend by flag, not by import."""

    def __init__(self, *, num_blocks: int = 7000, block_size: int = 16,
                 max_running: int = 256, max_tokens_per_iter: int = 8192,
                 prefix_cache: bool = False,
                 max_preemptions: Optional[int] = None,
                 chunk_policy: str = "decode_first",
                 host_blocks: int = 0,
                 swap_mode: str = "sacrifice",
                 victim_policy: str = "lifo",
                 swap_overlap: bool = False,
                 speculative_swap: bool = False,
                 cache_spill_pages: int = 0,
                 cost: Optional[CostModel] = None,
                 net: Optional[NetworkModel] = None,
                 layout=None,
                 trace: bool = False):
        self.cost = cost or CostModel()
        # network/serialization model for cross-instance KV movement: the
        # router charges payload copies / lease RPCs via charge_network, and
        # step() adds the per-iteration partial-merge overhead of requests
        # decoding over borrowed rBlocks. None = network is free (the old
        # behavior, which flattered copy-mode sharing).
        self.net = net
        self.net_time = 0.0
        # the PCIe lane is always charged — a swap is never free, even when
        # the interconnect model is off (swap traffic rides host PCIe, not
        # the network; only the bandwidth figure is shared via NetworkModel)
        self.swap_net = net if net is not None else NetworkModel()
        # layout (optional KVPageLayout): the simulated arch's page-payload
        # schema. When set, every swap/copy charge uses the layout's true
        # bytes per page instead of the NetworkModel default — compressed
        # layouts (MLA latent pages) move ~10x fewer bytes, which flips
        # should_swap / victim_policy="cost" decisions at the margin. None
        # keeps the default-bytes behavior (and the committed swap-sweep
        # baselines) bit-identical.
        self.kv_layout = layout
        self.kv_page_bytes = layout.page_bytes(block_size) \
            if layout is not None else None
        self.swap_time_s = 0.0
        self.swapped_out = 0
        self.swapped_in = 0
        # overlap window: PCIe transfers hide behind the iteration's compute
        # (double-buffered DMA); only the surplus past the compute time is
        # charged on the virtual clock. Off = PR 8's serial model.
        self.swap_overlap = swap_overlap
        self.swap_cancels = 0
        self.allocator = BlockAllocator(num_blocks, block_size,
                                        host_blocks=host_blocks,
                                        layout=layout)
        self.prefix_cache = PrefixCache(
            self.allocator, spill_budget=cache_spill_pages) if prefix_cache \
            else None
        self.scheduler = IterationScheduler(
            self.allocator, max_running=max_running,
            max_tokens_per_iter=max_tokens_per_iter,
            prefix_cache=self.prefix_cache, max_preemptions=max_preemptions,
            chunk_policy=chunk_policy,
            # sim outputs are placeholder ids — adopting them into the radix
            # tree would cache meaningless pages
            cache_generated=False,
            swap_mode=swap_mode, victim_policy=victim_policy,
            # "auto" resolves per victim against the cost model: swap when
            # the PCIe round trip (out now + in later) undercuts recomputing
            # the victim's context from scratch
            swap_decider=self._swap_worth_it if swap_mode == "auto"
            else None,
            # victim_policy="cost" ranks candidates by this (eviction cost
            # per freed page) instead of queue position — only consulted by
            # the scheduler for the cost policy
            victim_cost_fn=self._victim_cost,
            speculative_swap=speculative_swap)
        self._now = 0.0
        self.iterations = 0
        self.preemptions = 0
        self.peak_memory_frac = 0.0
        self._utils: List[float] = []
        # telemetry: events are stamped through the VIRTUAL clock, so a
        # traced sim run is perfectly reproducible (no wall time anywhere)
        if trace:
            self.trace = Tracer(clock=self.clock)
            self.metrics = MetricsRegistry()
            self.scheduler.trace = self.trace
        else:
            self.trace = None
            self.metrics = None

    def _swap_worth_it(self, req: Request, n_pages: int) -> bool:
        """swap_mode="auto" decision: is this victim's KV worth the PCIe
        round trip? Recomputing its computed context costs linear-layer
        FLOPs plus the quadratic attention reads; swapping costs two
        transfers of its pages. Short contexts recompute, long ones swap —
        the crossover ``benchmarks/swap_sweep.py`` measures."""
        ctx = req.prefilled_len + req.n_generated
        recompute = self.cost.c_token * ctx + \
            self.cost.c_ctx * self.cost.prefill_read_tokens(0, ctx)
        return 2.0 * self.swap_net.swap_time(
            n_pages, page_bytes=self.kv_page_bytes) < recompute

    def _victim_cost(self, req: Request, table) -> float:
        """victim_policy="cost" raw eviction bill: the modeled cost of
        evicting this request — PCIe round trip when its KV is worth
        swapping, quadratic recompute time otherwise. The scheduler
        normalizes by the pages freed toward the current shortfall and
        the cheapest seconds-per-needed-page victim wins."""
        n = len(table.blocks)
        ctx = min(req.prefilled_len, table.num_tokens) + req.n_generated
        if self._swap_worth_it(req, n):
            return 2.0 * self.swap_net.swap_time(
                n, page_bytes=self.kv_page_bytes)
        return self.cost.c_token * ctx + \
            self.cost.c_ctx * self.cost.prefill_read_tokens(0, ctx)

    # -- ServingBackend protocol ----------------------------------------------
    def add_request(self, req: Request) -> None:
        self.scheduler.add_request(req)

    @property
    def has_work(self) -> bool:
        return bool(self.scheduler.waiting or self.scheduler.running)

    def clock(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        """Fast-forward across an idle gap (next arrival)."""
        self._now = max(self._now, t)

    def charge_network(self, seconds: float) -> None:
        """Advance the virtual clock by modeled network time (payload copy
        at copy-mode adoption, lease RPC at borrow)."""
        self._now += seconds
        self.net_time += seconds
        if self.trace is not None:
            self.trace.instant("net", "charge", seconds=seconds)

    def step(self, now: Optional[float] = None) -> List[Request]:
        tr = self.trace
        if tr is not None:
            tr.iteration = self.iterations
        plan = self.scheduler.schedule()
        self.preemptions += len(plan.preempted)
        # PCIe traffic this iteration: demand swap-outs, speculative issues
        # (the DMA starts now, regardless of how it later resolves), and
        # swap-ins. Completions/cancels were charged at their issue.
        out_pages = sum(len(p) for _, p in plan.swap_out) + \
            sum(len(p) for _, p in plan.swap_issue)
        in_pages = sum(len(p) for _, p in plan.swap_in)
        t_swap = 0.0
        if out_pages or in_pages:
            t_swap = self.swap_net.swap_time(
                out_pages, page_bytes=self.kv_page_bytes) + \
                self.swap_net.swap_time(in_pages,
                                        page_bytes=self.kv_page_bytes)
            self.swap_time_s += t_swap
        self.swapped_out += len(plan.swap_out) + len(plan.swap_complete)
        self.swapped_in += len(plan.swap_in)
        self.swap_cancels += len(plan.swap_cancel)
        if plan.empty:
            # no compute to hide behind — the PCIe time is fully exposed
            self._now += t_swap
            # nothing computed, but a preemption may still have happened
            # (a lone request outgrowing the whole pool preempts *itself*,
            # leaving an empty plan) — complete_iteration must still run so
            # the max_preemptions drop policy can retire it, else the
            # backend stalls forever with the request bouncing in waiting
            return self.scheduler.complete_iteration(plan, self._now) \
                if plan.preempted else []
        # context reads split local vs remote: a zero-copy lease serves a
        # request's leading r_base tokens from a creditor instance's pages
        # (micro-attention computed where the block lives, partials merged),
        # charged at c_remote instead of c_ctx, plus a per-request merge
        # round when the network model is on
        remote_of = self.scheduler.remote_tokens_of
        sum_ctx = sum_remote = n_borrowing = 0
        for r in plan.decode:
            rb = remote_of(r.request_id)
            sum_ctx += r.context_len - rb
            sum_remote += rb
            n_borrowing += 1 if rb else 0
        # per-chunk cost: chunk tokens read the KV already written by the
        # cached prefix and earlier chunks (see prefill_read_tokens);
        # borrowed prefix tokens are read remotely by every chunk token
        for c in plan.chunks:
            rb = remote_of(c.req.request_id)
            sum_ctx += self.cost.prefill_read_tokens(c.start - rb, c.length)
            sum_remote += c.length * rb
            n_borrowing += 1 if rb else 0
        t_start = self._now
        t_iter = self.cost.iteration_time(plan.token_count(), sum_ctx,
                                          sum_remote)
        # one batched DMA per direction per iteration. Serial (PR 8's
        # conservative model): transfers stack on top of compute. Overlap:
        # double-buffered against this iteration's compute, only the
        # surplus past t_iter is exposed on the clock.
        t_exposed = max(0.0, t_swap - t_iter) if self.swap_overlap \
            else t_swap
        self._now += t_iter + t_exposed
        if self.net is not None and n_borrowing:
            t_net = self.net.borrow_iter_overhead(n_borrowing)
            self._now += t_net
            self.net_time += t_net  # network-attributable, like copies
        for c in plan.chunks:  # prefill-in-flight: admission time
            if c.req.scheduled_time is None:
                c.req.scheduled_time = self._now
        # simulate generation: each request whose final chunk or decode ran
        # emits one token (mid-prefill requests emit nothing yet)
        for r in plan.prefill + plan.decode:
            r.output.append(0)
            r.record_token_time(self._now)
            if r.first_token_time is None:
                r.first_token_time = self._now
                if tr is not None:
                    tr.instant("req", "first_token", rid=r.request_id)
            if r.scheduled_time is None:
                r.scheduled_time = self._now
        finished = self.scheduler.complete_iteration(plan, self._now)
        if tr is not None:
            tr.complete("engine", "iteration", ts=t_start,
                        dur=self._now - t_start, tokens=plan.token_count(),
                        decodes=len(plan.decode), chunks=len(plan.chunks),
                        ctx=sum_ctx, remote=sum_remote)
        if self.metrics is not None:
            m = self.metrics
            m.gauge("kv_util_frac",
                    self.allocator.num_used / self.allocator.num_blocks)
            m.gauge("prefill_backlog_tokens",
                    self.scheduler.prefill_backlog_tokens())
            m.gauge("budget_fill_frac",
                    plan.token_count() / self.scheduler.max_tokens)
            m.gauge("running", len(self.scheduler.running))
            m.gauge("waiting", len(self.scheduler.waiting))
            m.gauge("net_time_s", self.net_time)
            if self.allocator.num_host_blocks:
                m.gauge("swapped_pages", self.allocator.swapped_pages)
                m.gauge("swap_time_s", self.swap_time_s)
                m.gauge("swap_pending_pages",
                        self.allocator.pending_out_pages)
            if self.prefix_cache is not None:
                m.gauge("prefix_hit_rate", self.prefix_cache.hit_rate)
            m.count("tokens", plan.token_count())
            m.count("decode_tokens", len(plan.decode))
            m.count("prefill_tokens", sum(c.length for c in plan.chunks))
            m.count("preemptions", len(plan.preempted))
            m.count("swap_outs", len(plan.swap_out) + len(plan.swap_complete))
            m.count("swap_ins", len(plan.swap_in))
            m.count("swap_issues", len(plan.swap_issue))
            m.count("swap_cancels", len(plan.swap_cancel))
            m.observe("iteration_time_s", self._now - t_start)
            m.snapshot(self._now, self.iterations)
        self.iterations += 1
        self.peak_memory_frac = max(
            self.peak_memory_frac,
            self.allocator.num_used / self.allocator.num_blocks)
        tables = list(self.scheduler.tables.values())
        if tables:
            self._utils.append(self.allocator.utilization(tables))
        return finished

    @property
    def kv_utilization(self) -> float:
        return float(np.mean(self._utils)) if self._utils else 1.0


def simulate_paged(requests: Sequence[Request], *, num_blocks: int = 7000,
                   block_size: int = 16, max_running: int = 256,
                   max_tokens_per_iter: int = 8192,
                   prefix_cache: bool = False,
                   chunk_policy: str = "decode_first",
                   max_preemptions: Optional[int] = None,
                   host_blocks: int = 0,
                   swap_mode: str = "sacrifice",
                   victim_policy: str = "lifo",
                   swap_overlap: bool = False,
                   speculative_swap: bool = False,
                   cost: Optional[CostModel] = None,
                   net: Optional[NetworkModel] = None,
                   trace: bool = False) -> SimResult:
    """Replay ``requests`` through :class:`SimBackend` behind the LLMService
    front-end (one drive loop for engine and simulator alike).

    ``prefix_cache``: attach a radix-tree prefix KV cache — admission
    charges only the uncached prompt suffix (requests need real token ids,
    e.g. from :func:`make_shared_prefix_workload`).
    ``chunk_policy``: chunked-prefill budget policy (``decode_first`` |
    ``prefill_first`` | ``monolithic`` | legacy ``solo``), see
    :class:`~repro.core.scheduling.iteration.IterationScheduler`.
    ``host_blocks`` / ``swap_mode`` / ``victim_policy``: host swap tier —
    preemption victims' KV moves to host pages over a modeled PCIe lane
    (``net.pcie_gbps``) instead of being recomputed; see SWAP_MODES /
    VICTIM_POLICIES in the scheduler module.
    ``swap_overlap``: double-buffer the PCIe DMAs against each iteration's
    compute (only the surplus past the compute time hits the clock).
    ``speculative_swap``: the scheduler issues decode swap-outs *early*
    when free pages trend under the watermark, cancelling if pressure
    recedes before the transfer resolves."""
    from repro.serving.api import LLMService  # late: api imports Request

    backend = SimBackend(num_blocks=num_blocks, block_size=block_size,
                         max_running=max_running,
                         max_tokens_per_iter=max_tokens_per_iter,
                         prefix_cache=prefix_cache,
                         max_preemptions=max_preemptions,
                         host_blocks=host_blocks, swap_mode=swap_mode,
                         victim_policy=victim_policy,
                         swap_overlap=swap_overlap,
                         speculative_swap=speculative_swap,
                         chunk_policy=chunk_policy, cost=cost, net=net,
                         trace=trace)
    svc = LLMService(backend)
    for r in sorted(requests, key=lambda r: r.arrival_time):
        svc.submit_request(r)
    svc.drain()
    res = SimResult(list(requests), makespan=backend.clock(),
                    peak_memory_frac=backend.peak_memory_frac,
                    kv_utilization=backend.kv_utilization,
                    preemptions=backend.preemptions,
                    swapped_out=backend.swapped_out,
                    swapped_in=backend.swapped_in,
                    swap_time=backend.swap_time_s,
                    swap_cancels=backend.swap_cancels)
    if backend.prefix_cache is not None:
        res.prefix_hit_rate = backend.prefix_cache.hit_rate
        res.cached_pages = backend.prefix_cache.num_pages
    if backend.trace is not None:
        res.events = backend.trace.events()
        res.timelines = {0: backend.metrics.rows()}
    return res


def simulate_router(requests: Sequence[Request], *, n_instances: int = 4,
                    policy: str = "round_robin",
                    prefix_cache: bool = True,
                    prefix_share: bool = False,
                    share_mode: str = "copy",
                    hot_threshold: int = 1,
                    board_pages: Optional[int] = None,
                    peer_spill: bool = False,
                    cache_spill_pages: int = 0,
                    blocks_per_instance: int = 1800, block_size: int = 16,
                    max_running: int = 64,
                    max_tokens_per_iter: int = 8192,
                    max_preemptions: Optional[int] = None,
                    chunk_policy: str = "decode_first",
                    cost: Optional[CostModel] = None,
                    net: Optional[NetworkModel] = None,
                    trace: bool = False) -> SimResult:
    """Virtual-clock cluster sim: N :class:`SimBackend` instances behind a
    :class:`~repro.serving.router.RouterBackend`, driven to completion
    through the LLMService front-end. The event-driven router advances the
    laggard instance each step, so policy sweeps over many instances run in
    milliseconds of wall time.

    ``policy``: ``round_robin`` | ``least_loaded`` | ``prefix_affinity``
    (see ``serving.router.POLICIES``). ``prefix_share`` publishes hot radix
    paths through the distkv board so instances reuse each other's cached
    prefixes; ``share_mode`` picks how (``copy`` payload adoption |
    ``zero_copy`` borrowed rBlocks served through the DistAttention merge |
    ``auto`` per-request cost decision). ``net`` attaches the
    :class:`~repro.core.distkv.netmodel.NetworkModel` so copies and borrows
    cost virtual time (required for an honest copy-vs-borrow comparison)."""
    from repro.serving.api import LLMService  # late: api imports Request
    from repro.serving.router import RouterBackend

    children = [SimBackend(num_blocks=blocks_per_instance,
                           block_size=block_size, max_running=max_running,
                           max_tokens_per_iter=max_tokens_per_iter,
                           prefix_cache=prefix_cache,
                           max_preemptions=max_preemptions,
                           cache_spill_pages=cache_spill_pages,
                           chunk_policy=chunk_policy, cost=cost, net=net,
                           trace=trace)
                for _ in range(n_instances)]
    router = RouterBackend(children, policy=policy,
                           prefix_share=prefix_share,
                           share_mode=share_mode,
                           hot_threshold=hot_threshold,
                           board_pages=board_pages, net=net,
                           peer_spill=peer_spill)
    svc = LLMService(router)
    for r in sorted(requests, key=lambda r: r.arrival_time):
        svc.submit_request(r)
    svc.drain()
    # utilization over instances that actually held tables — an idle
    # instance's vacuous 1.0 default would flatter a policy that
    # concentrates load
    utils = [c.kv_utilization for c in children if c._utils]
    res = SimResult(list(requests), makespan=router.clock(),
                    peak_memory_frac=max(c.peak_memory_frac
                                         for c in children),
                    kv_utilization=float(np.mean(utils)) if utils else 1.0,
                    preemptions=router.preemptions,
                    per_instance=router.instance_stats())
    agg = router.prefix_cache
    if agg is not None:
        res.prefix_hit_rate = agg.hit_rate
        res.cached_pages = agg.num_pages
        res.adopted_pages = agg.adopted_pages
    res.borrowed_pages = router.pages_borrowed
    res.net_time = sum(getattr(c, "net_time", 0.0) for c in children)
    if trace:
        res.events = router.trace_events()
        res.timelines = router.metrics_timelines()
    return res


def simulate_disagg(requests: Sequence[Request], *, roles: str = "2p2d",
                    handoff_mode: str = "auto",
                    handoff_defer_cap: int = 8,
                    policy: str = "least_loaded",
                    prefix_cache: bool = True,
                    blocks_per_instance: int = 1800, block_size: int = 16,
                    max_running: int = 64,
                    max_tokens_per_iter: int = 8192,
                    max_preemptions: Optional[int] = None,
                    chunk_policy: str = "decode_first",
                    cost: Optional[CostModel] = None,
                    net: Optional[NetworkModel] = None,
                    trace: bool = False) -> SimResult:
    """Disaggregated prefill/decode cluster sim: role-tagged
    :class:`SimBackend` instances behind the router's
    :class:`~repro.serving.disagg.KVHandoff` coordinator.

    ``roles`` is a ``parse_role_spec`` string (``"2p2d"`` = 2 prefill + 2
    decode instances) or role-name list; the instance count comes from it.
    New prompts land only on prefill-capable instances, finished prompt KV
    moves to a decode instance per ``handoff_mode`` (``migrate`` |
    ``zero_copy`` | ``auto``), and ``net`` (defaulted by the router when
    omitted) charges the transfer against the virtual clocks — the frontier
    against mixed-instance chunked prefill is only honest with the handoff
    cost on the books. Decode instances run pure decode iterations, which
    is the P99-TBT story ``benchmarks/disagg_sweep.py`` measures."""
    from repro.serving.api import LLMService  # late: api imports Request
    from repro.serving.disagg import parse_role_spec
    from repro.serving.router import RouterBackend

    role_list = parse_role_spec(roles)
    children = [SimBackend(num_blocks=blocks_per_instance,
                           block_size=block_size, max_running=max_running,
                           max_tokens_per_iter=max_tokens_per_iter,
                           prefix_cache=prefix_cache,
                           max_preemptions=max_preemptions,
                           chunk_policy=chunk_policy, cost=cost, net=net,
                           trace=trace)
                for _ in role_list]
    router = RouterBackend(children, policy=policy, roles=role_list,
                           handoff_mode=handoff_mode,
                           handoff_defer_cap=handoff_defer_cap, net=net)
    svc = LLMService(router)
    for r in sorted(requests, key=lambda r: r.arrival_time):
        svc.submit_request(r)
    svc.drain()
    utils = [c.kv_utilization for c in children if c._utils]
    res = SimResult(list(requests), makespan=router.clock(),
                    peak_memory_frac=max(c.peak_memory_frac
                                         for c in children),
                    kv_utilization=float(np.mean(utils)) if utils else 1.0,
                    preemptions=router.preemptions,
                    per_instance=router.instance_stats())
    agg = router.prefix_cache
    if agg is not None:
        res.prefix_hit_rate = agg.hit_rate
        res.cached_pages = agg.num_pages
        res.adopted_pages = agg.adopted_pages
    res.borrowed_pages = router.pages_borrowed
    res.net_time = sum(getattr(c, "net_time", 0.0) for c in children)
    res.handoffs_migrated = router.handoff.handoffs_migrated
    res.handoffs_leased = router.handoff.handoffs_leased
    res.handoff_deferrals = router.handoff.deferrals
    res.handoff_fallbacks = router.handoff.fallbacks
    if trace:
        res.events = router.trace_events()
        res.timelines = router.metrics_timelines()
        res.role_timelines = router.role_timelines()
    return res


def simulate_prealloc(requests: Sequence[Request], *, total_slots: int,
                      max_len: int = 2048, policy: str = "max",
                      max_running: int = 256,
                      max_tokens_per_iter: int = 8192,
                      cost: Optional[CostModel] = None) -> SimResult:
    """Orca (Max/Pow2/Oracle): iteration-level scheduling with contiguous
    per-request reservations instead of paging."""
    cost = cost or CostModel()
    res = ContiguousPreallocAllocator(total_slots, max_len, policy)
    pending = sorted(requests, key=lambda r: r.arrival_time)
    waiting: List[Request] = []
    running: List[Request] = []
    now, i_pending = 0.0, 0
    utils = []
    n_left = len(pending)
    while n_left > 0:
        while i_pending < len(pending) and \
                pending[i_pending].arrival_time <= now:
            waiting.append(pending[i_pending])
            i_pending += 1
        # admit FCFS while reservations fit
        prefill: List[Request] = []
        budget = max_tokens_per_iter - len(running)
        while waiting and len(running) + len(prefill) < max_running:
            req = waiting[0]
            total = req.prompt_len + req.max_new_tokens
            if req.prompt_len > budget or not res.can_admit(total):
                break
            waiting.pop(0)
            res.admit(req.request_id, total)
            res.store(req.request_id, req.prompt_len)
            budget -= req.prompt_len
            prefill.append(req)
        decode = list(running)
        if not prefill and not decode:
            if i_pending < len(pending):
                now = max(now, pending[i_pending].arrival_time)
                continue
            break
        n_tok = sum(r.prompt_len for r in prefill) + len(decode)
        sum_ctx = sum(r.context_len for r in decode) + \
            sum(cost.prefill_read_tokens(0, r.prompt_len) for r in prefill)
        now += cost.iteration_time(n_tok, sum_ctx)
        for r in prefill + decode:
            r.output.append(0)
            res.store(r.request_id, 1)
            if r.first_token_time is None:
                r.first_token_time = now
        running.extend(prefill)
        for r in list(running):
            if r.done:
                r.phase = Phase.FINISHED
                r.finish_time = now
                res.release(r.request_id)
                running.remove(r)
                n_left -= 1
        utils.append(res.utilization())
    return SimResult(list(requests), makespan=now,
                     kv_utilization=float(np.mean(utils)) if utils else 1.0)


def simulate_batch_level(requests: Sequence[Request], *, max_batch: int = 32,
                         cost: Optional[CostModel] = None) -> SimResult:
    """Pre-ORCA batch-level scheduling: the whole batch runs until its
    longest member finishes (early-finish waste + queueing delay)."""
    cost = cost or CostModel()
    sched = BatchScheduler(max_batch=max_batch)
    pending = sorted(requests, key=lambda r: r.arrival_time)
    now, i_pending = 0.0, 0
    n_left = len(pending)
    while n_left > 0:
        while i_pending < len(pending) and \
                pending[i_pending].arrival_time <= now:
            sched.add_request(pending[i_pending])
            i_pending += 1
        plan = sched.schedule()
        if plan.empty:
            if i_pending < len(pending):
                now = max(now, pending[i_pending].arrival_time)
                continue
            break
        batch = plan.batch
        n_iters = max(r.max_new_tokens for r in batch)
        # prefill iteration
        now += cost.iteration_time(
            sum(r.prompt_len for r in batch),
            sum(cost.prefill_read_tokens(0, r.prompt_len) for r in batch))
        for it in range(n_iters):
            live_ctx = sum(min(r.context_len + 1, r.prompt_len +
                               r.max_new_tokens) for r in batch)
            now += cost.iteration_time(len(batch), live_ctx)
            for r in batch:
                if r.n_generated < r.max_new_tokens:
                    r.output.append(0)
                    if r.first_token_time is None:
                        r.first_token_time = now
        n_left -= len(sched.complete_batch(now))
    return SimResult(list(requests), makespan=now)


# ---------------------------------------------------------------------------
# DistKV-LLM multi-instance simulation (Fig. 10)
# ---------------------------------------------------------------------------

class _LocalKV:
    """Instance-local paged KV backend (vanilla vLLM instance)."""

    def __init__(self, alloc: BlockAllocator):
        self.alloc = alloc
        self.counts: Dict[int, int] = {}  # req -> tokens stored
        self.blocks: Dict[int, List[int]] = {}

    def grow(self, rid: int, n: int) -> bool:
        cur = self.counts.get(rid, 0)
        bs = self.alloc.block_size
        need = -(-(cur + n) // bs) - len(self.blocks.get(rid, []))
        if need > self.alloc.num_free:
            return False
        owned = self.blocks.setdefault(rid, [])
        for _ in range(need):
            owned.append(self.alloc.alloc_block())
        self.counts[rid] = cur + n
        return True

    def free(self, rid: int) -> None:
        self.counts.pop(rid, None)
        for b in self.blocks.pop(rid, []):
            self.alloc.decref(b)

    def remote_fraction(self, rid: int) -> float:
        return 0.0


class _DistKV:
    """DistKV-LLM backend: local first, then borrow via gManager."""

    def __init__(self, rm: RManager):
        self.rm = rm

    def grow(self, rid: int, n: int) -> bool:
        try:
            self.rm.append_tokens(rid, n)
            return True
        except OutOfBlocks:
            return False

    def free(self, rid: int) -> None:
        self.rm.free_seq(rid)

    def remote_fraction(self, rid: int) -> float:
        return self.rm.remote_fraction(rid)


def simulate_distkv(requests: Sequence[Request], *, n_instances: int = 4,
                    blocks_per_instance: int = 1800, block_size: int = 16,
                    max_running: int = 64, max_tokens_per_iter: int = 8192,
                    borrow: bool = True,
                    cost: Optional[CostModel] = None) -> SimResult:
    """Round-robin requests over instances. With ``borrow`` (DistKV-LLM) an
    exhausted instance borrows rBlocks via the gManager debt ledger; remote
    context incurs ``c_remote``. Without it (vanilla paged instances) a
    request that cannot grow is preempted (recompute) — the paper's baseline.
    Instances run in lockstep epochs of the slowest instance's iteration."""
    cost = cost or CostModel()
    g = GManager(n_instances)
    backends: Dict[int, object] = {}
    if borrow:
        rms = {i: RManager(i, BlockAllocator(blocks_per_instance, block_size),
                           g) for i in range(n_instances)}
        for r in rms.values():
            r.register_peers(rms)
        backends = {i: _DistKV(rms[i]) for i in range(n_instances)}
    else:
        backends = {i: _LocalKV(BlockAllocator(blocks_per_instance,
                                               block_size))
                    for i in range(n_instances)}

    waiting: Dict[int, List[Request]] = {i: [] for i in range(n_instances)}
    running: Dict[int, List[Request]] = {i: [] for i in range(n_instances)}
    pending = sorted(requests, key=lambda r: r.arrival_time)
    preemptions = 0
    rejected = 0
    # capacity guard: a request whose *total* context can never fit is
    # rejected up front (local capacity without borrowing; cluster capacity
    # with) — the baseline's fundamental long-context limitation.
    cap_tokens = blocks_per_instance * block_size
    if borrow:
        cap_tokens *= n_instances

    now, i_pending, n_left = 0.0, 0, len(pending)
    while n_left > 0:
        while i_pending < len(pending) and \
                pending[i_pending].arrival_time <= now:
            r = pending[i_pending]
            if r.prompt_len + r.max_new_tokens > cap_tokens * 0.9:
                rejected += 1
                n_left -= 1
            else:
                waiting[i_pending % n_instances].append(r)
            i_pending += 1
        t_instances = [0.0]
        for inst in range(n_instances):
            kv = backends[inst]
            budget = max_tokens_per_iter
            decode: List[Request] = []
            prefill: List[Request] = []
            # decode growth (borrow or preempt)
            for req in list(running[inst]):
                if budget <= 0:
                    break
                if kv.grow(req.request_id, 1):
                    decode.append(req)
                    budget -= 1
                else:
                    kv.free(req.request_id)
                    req.committed_output.extend(req.output)
                    req.prompt_len = req.context_len
                    req.max_new_tokens -= req.n_generated
                    req.output = []
                    req.preemptions += 1
                    preemptions += 1
                    running[inst].remove(req)
                    waiting[inst].insert(0, req)
            # admission (a prompt larger than the whole token budget may run
            # alone when the instance is otherwise idle — chunked-prefill
            # stand-in, else huge prompts head-of-line-block forever)
            while waiting[inst] and len(running[inst]) + len(prefill) \
                    < max_running:
                req = waiting[inst][0]
                solo_ok = (not decode and not prefill)
                if (req.prompt_len > budget and not solo_ok) or \
                        not kv.grow(req.request_id, req.prompt_len):
                    break
                waiting[inst].pop(0)
                prefill.append(req)
                budget -= req.prompt_len
            if not decode and not prefill:
                continue
            sum_ctx = sum(r.context_len for r in decode) + \
                sum(cost.prefill_read_tokens(0, r.prompt_len)
                    for r in prefill)
            remote_ctx = sum(int(r.context_len *
                                 kv.remote_fraction(r.request_id))
                             for r in decode)
            n_tok = sum(r.prompt_len for r in prefill) + len(decode)
            t = cost.iteration_time(n_tok, sum_ctx, remote_ctx)
            t_instances.append(t)
            running[inst].extend(prefill)
            for r in prefill + decode:
                r.output.append(0)
                if r.first_token_time is None:
                    r.first_token_time = now + t
            for r in list(running[inst]):
                if r.done:
                    r.phase = Phase.FINISHED
                    r.finish_time = now + t
                    kv.free(r.request_id)
                    running[inst].remove(r)
                    n_left -= 1
        step = max(t_instances)
        if step == 0.0:
            if i_pending < len(pending):
                now = max(now, pending[i_pending].arrival_time)
                continue
            break
        now += step
    return SimResult(list(requests), makespan=now, preemptions=preemptions,
                     rejected=rejected)
