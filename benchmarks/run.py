"""Benchmark driver — one entry per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV summary at the end (us_per_call =
benchmark wall time; derived = the benchmark's headline metric), and exits
non-zero if any registered benchmark raised — a failing benchmark must not
pass silently in CI.
"""

from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    import benchmarks.chain_compare as chain_compare
    import benchmarks.kv_utilization as kv_utilization
    import benchmarks.orca_scheduling as orca_scheduling
    import benchmarks.serving_fig9 as serving_fig9
    import benchmarks.serving_fig10 as serving_fig10
    import benchmarks.chunked_prefill_sweep as chunked_prefill_sweep
    import benchmarks.prefix_cache_sweep as prefix_cache_sweep
    import benchmarks.roofline_report as roofline_report
    import benchmarks.router_sweep as router_sweep
    import benchmarks.zero_copy_sweep as zero_copy_sweep

    csv_rows = []
    failures = []

    def bench(name, fn, derive):
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.monotonic()
        try:
            out = fn()
        except Exception:
            # record and continue: the remaining benchmarks still run, but
            # the driver exits non-zero at the end
            traceback.print_exc()
            failures.append(name)
            csv_rows.append((name, (time.monotonic() - t0) * 1e6, "FAILED"))
            return None
        us = (time.monotonic() - t0) * 1e6
        try:
            derived = derive(out)
        except Exception:  # pragma: no cover - derived metric best-effort
            traceback.print_exc()
            derived = "n/a"
        csv_rows.append((name, us, derived))
        return out

    bench("chain_nsga2_vs_dijkstra (paper §II.B.5)",
          lambda: chain_compare.run(n_fleets=6),
          lambda out: f"hv_ratio={out[1]['hv_ga']/max(out[1]['hv_base'],1e-9):.2f}x")

    bench("serving_fig9_paged_vs_orca",
          lambda: serving_fig9.run(n_requests=300),
          lambda out: "latency_curves=%d" % sum(len(v) for v in out.values()))

    bench("kv_utilization (§III.C 20.4-38.2%)",
          kv_utilization.run,
          lambda out: f"orca_max={out['orca-max']:.1%},paged={out['vLLM-paged']:.1%}")

    bench("serving_fig10_distkv",
          lambda: serving_fig10.run(n_requests=200),
          lambda out: "max_gain=%.2fx" % max(r["gain"] for r in out))

    bench("chunked_prefill_sweep (stall-free mixed batching)",
          lambda: chunked_prefill_sweep.run(n_requests=220),
          chunked_prefill_sweep.headline)

    bench("prefix_cache_sweep (radix KV reuse)",
          lambda: prefix_cache_sweep.run(n_requests=150),
          lambda out: "shared_speedup=%.3fx,hit=%.0f%%" % (
              out[0]["speedup"], 100 * out[0]["hit_rate"]))

    bench("router_sweep (cluster placement policies)",
          lambda: router_sweep.run(n_requests=160),
          router_sweep.headline)

    bench("zero_copy_sweep (copy vs borrowed-rBlock prefix serving)",
          lambda: zero_copy_sweep.run(n_requests=160,
                                      out_lens=(16, 96, 256)),
          zero_copy_sweep.headline)

    bench("orca_iteration_vs_batch",
          orca_scheduling.run,
          lambda out: "batch/iter=%.1fx" % max(
              r["batch_lat"] / r["iter_lat"] for r in out))

    bench("roofline_report (dry-run artifacts)",
          roofline_report.run,
          lambda out: "rows=%d" % len(out))

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.0f},{derived}")

    if failures:
        print(f"\nFAILED benchmarks: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
