"""Bench-regression guard: fresh BENCH_swap_sweep.json vs committed baseline.

CI copies the checkout's committed ``bench_out/BENCH_swap_sweep.json`` aside
BEFORE ``benchmarks/run.py`` overwrites the directory, then calls this tool
to compare the fresh artifact against it. Two classes of check:

* **Tolerance band** — every metric key present in BOTH artifacts must not
  regress by more than ``--tolerance`` (relative): throughputs may not drop,
  P99 normalized latencies may not rise. The sim is virtual-clock
  deterministic, so the band only absorbs intentional model recalibration;
  improvements always pass.
* **Overlap headline** — the long-point ``swap-overlap-cost`` row (overlapped
  PCIe transfers + cost-ranked victims) must beat the baseline's serial
  ``swap`` row: ≥ +5% throughput, OR lower P99 normalized latency at equal-
  or-better throughput. This is the PR acceptance criterion, kept green
  forever after.

    python tools/check_bench_regression.py BASELINE FRESH [--tolerance 0.02]

Exit status is non-zero on any regression; every comparison is printed.
"""

from __future__ import annotations

import argparse
import json
import sys

HEADLINE_GAIN = 1.05  # +5% throughput branch of the headline check


def _load(path):
    with open(path) as f:
        return json.load(f)["metrics"]


def compare(base: dict, fresh: dict, tolerance: float) -> list:
    """Returns a list of human-readable regressions (empty ⇒ pass)."""
    problems = []

    def band(group, higher_is_better):
        b, f = base.get(group) or {}, fresh.get(group) or {}
        for key in sorted(set(b) & set(f)):
            bv, fv = b[key], f[key]
            if bv <= 0:
                continue
            rel = fv / bv - 1.0
            bad = rel < -tolerance if higher_is_better else rel > tolerance
            arrow = "REGRESSION" if bad else "ok"
            print(f"  {group}[{key}]: {bv:.6g} -> {fv:.6g} "
                  f"({rel:+.2%}) {arrow}")
            if bad:
                problems.append(f"{group}[{key}] regressed {rel:+.2%} "
                                f"(tolerance {tolerance:.0%})")

    band("long_throughput", higher_is_better=True)
    band("short_throughput", higher_is_better=True)
    band("long_p99_norm_lat", higher_is_better=False)

    if not fresh.get("reprefill_ok", False):
        problems.append("no-re-prefill proof failed in the fresh run")

    # overlap headline: fresh overlap+cost vs the baseline serial swap row
    base_thr = (base.get("long_throughput") or {}).get("swap")
    base_p99 = (base.get("long_p99_norm_lat") or {}).get("swap")
    ovl_thr = (fresh.get("long_throughput") or {}).get("swap-overlap-cost")
    ovl_p99 = (fresh.get("long_p99_norm_lat") or {}).get("swap-overlap-cost")
    if None in (base_thr, base_p99, ovl_thr, ovl_p99):
        problems.append("headline rows missing: need baseline long swap and "
                        "fresh long swap-overlap-cost metrics")
    else:
        gain = ovl_thr / base_thr
        print(f"  headline: overlap+cost {ovl_thr:.2f} tok/s vs baseline "
              f"swap {base_thr:.2f} ({gain - 1:+.2%}), "
              f"p99 {ovl_p99 * 1e3:.2f} vs {base_p99 * 1e3:.2f} ms/tok")
        if not (gain >= HEADLINE_GAIN
                or (gain >= 1.0 and ovl_p99 < base_p99)):
            problems.append(
                f"overlap+cost headline does not beat the baseline swap "
                f"row: thr {gain - 1:+.2%} (needs >= +{HEADLINE_GAIN - 1:.0%}"
                f") and p99 {ovl_p99:.6g} vs {base_p99:.6g} "
                f"(needs lower at equal-or-better throughput)")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser(
        description="compare a fresh BENCH_swap_sweep.json to the baseline")
    ap.add_argument("baseline", help="committed baseline artifact")
    ap.add_argument("fresh", help="freshly produced artifact")
    ap.add_argument("--tolerance", type=float, default=0.02, metavar="FRAC",
                    help="relative regression band (default 0.02)")
    args = ap.parse_args()
    base, fresh = _load(args.baseline), _load(args.fresh)
    print(f"comparing {args.fresh} against {args.baseline} "
          f"(tolerance {args.tolerance:.0%})")
    problems = compare(base, fresh, args.tolerance)
    if problems:
        print("\nbench regressions:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        raise SystemExit(1)
    print("bench regression guard: ok")


if __name__ == "__main__":
    main()
