"""Public jit'd wrappers for the Pallas kernels.

``INTERPRET`` defaults to True because this container is CPU-only; on real
TPU hardware set ``repro.kernels.ops.INTERPRET = False`` (or env
``REPRO_PALLAS_INTERPRET=0``) and the same ``pl.pallas_call`` lowers to
Mosaic.
"""

from __future__ import annotations

import os

from repro.kernels.flash_prefill import flash_prefill as _flash
from repro.kernels.paged_attention import paged_attention as _paged

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    page_size, window=None, return_partials=False):
    return _paged(q, k_pages, v_pages, block_tables, context_lens,
                  page_size=page_size, window=window,
                  return_partials=return_partials, interpret=INTERPRET)


def flash_prefill(q, k, v, *, causal=True, window=None, q_block=128,
                  kv_block=128):
    return _flash(q, k, v, causal=causal, window=window, q_block=q_block,
                  kv_block=kv_block, interpret=INTERPRET)


def ssd_scan(x, dt, A, B, C, *, chunk=64):
    from repro.kernels.ssd_scan import ssd_scan as _ssd
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=INTERPRET)
