"""Benchmark driver — one entry per paper table/figure.

Prints a ``name,us_per_call,derived`` CSV summary at the end (us_per_call =
benchmark wall time; derived = the benchmark's headline metric), and exits
non-zero if any registered benchmark raised — a failing benchmark must not
pass silently in CI.

Each benchmark also writes a machine-readable ``BENCH_<slug>.json`` to
``--out-dir`` with its headline-metric dict, the exact config it ran under,
the git revision, and wall time — so CI runs leave comparable artifacts
instead of only scrollback. ``--smoke`` shrinks every workload for a
minutes-not-hours CI pass; the artifact records which mode produced it.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def main() -> None:
    import benchmarks.chain_compare as chain_compare
    import benchmarks.kv_utilization as kv_utilization
    import benchmarks.orca_scheduling as orca_scheduling
    import benchmarks.serving_fig9 as serving_fig9
    import benchmarks.serving_fig10 as serving_fig10
    import benchmarks.chunked_prefill_sweep as chunked_prefill_sweep
    import benchmarks.disagg_sweep as disagg_sweep
    import benchmarks.prefix_cache_sweep as prefix_cache_sweep
    import benchmarks.mla_sweep as mla_sweep
    import benchmarks.roofline_report as roofline_report
    import benchmarks.router_sweep as router_sweep
    import benchmarks.swap_sweep as swap_sweep
    import benchmarks.zero_copy_sweep as zero_copy_sweep

    ap = argparse.ArgumentParser(description="run all paper benchmarks")
    ap.add_argument("--smoke", action="store_true",
                    help="shrink every workload for a fast CI pass")
    ap.add_argument("--out-dir", default="bench_out", metavar="DIR",
                    help="where BENCH_<slug>.json artifacts land "
                         "(default: bench_out)")
    ap.add_argument("--only", default=None, metavar="SUBSTR",
                    help="run only benchmarks whose slug contains SUBSTR")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    rev = git_rev()

    csv_rows = []
    failures = []

    def bench(slug, title, fn, config, derive, metrics):
        """Run one benchmark: stdout table, CSV row, BENCH_<slug>.json."""
        if args.only and args.only not in slug:
            return None
        print(f"\n=== {title} " + "=" * max(0, 60 - len(title)))
        t0 = time.monotonic()
        try:
            out = fn(**config)
        except Exception:
            # record and continue: the remaining benchmarks still run, but
            # the driver exits non-zero at the end
            traceback.print_exc()
            failures.append(slug)
            csv_rows.append((slug, (time.monotonic() - t0) * 1e6, "FAILED"))
            return None
        wall_s = time.monotonic() - t0
        try:
            derived = derive(out)
        except Exception:  # pragma: no cover - derived metric best-effort
            traceback.print_exc()
            derived = "n/a"
        try:
            metric_dict = metrics(out)
        except Exception:  # pragma: no cover - same best-effort policy
            traceback.print_exc()
            metric_dict = {"error": "metric extraction failed"}
        artifact = {
            "name": slug,
            "title": title,
            "metrics": metric_dict,
            "config": dict(config, smoke=args.smoke),
            "git_rev": rev,
            "wall_s": round(wall_s, 4),
        }
        path = os.path.join(args.out_dir, f"BENCH_{slug}.json")
        with open(path, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True, default=str)
        csv_rows.append((slug, wall_s * 1e6, derived))
        return out

    smoke = args.smoke

    bench("chain_compare", "chain_nsga2_vs_dijkstra (paper §II.B.5)",
          chain_compare.run,
          {"n_fleets": 3 if smoke else 6},
          lambda out: f"hv_ratio={out[1]['hv_ga']/max(out[1]['hv_base'],1e-9):.2f}x",
          lambda out: {"hv_ga": out[1]["hv_ga"], "hv_base": out[1]["hv_base"],
                       "hv_ratio": out[1]["hv_ga"]
                       / max(out[1]["hv_base"], 1e-9)})

    bench("serving_fig9", "serving_fig9_paged_vs_orca",
          serving_fig9.run,
          {"n_requests": 80 if smoke else 300},
          lambda out: "latency_curves=%d" % sum(len(v) for v in out.values()),
          lambda out: {
              f"{dist}_sustainable_{sysname}": max(
                  (r["rate"] for r in rows if r[sysname] <= 0.040),
                  default=0.0)
              for dist, rows in out.items()
              for sysname in ("vLLM-paged", "orca-max")})

    bench("kv_utilization", "kv_utilization (§III.C 20.4-38.2%)",
          kv_utilization.run, {},
          lambda out: f"orca_max={out['orca-max']:.1%},paged={out['vLLM-paged']:.1%}",
          lambda out: dict(out))

    bench("serving_fig10", "serving_fig10_distkv",
          serving_fig10.run,
          {"n_requests": 60 if smoke else 200},
          lambda out: "max_gain=%.2fx" % max(r["gain"] for r in out),
          lambda out: {"max_gain": max(r["gain"] for r in out),
                       "n_points": len(out)})

    bench("chunked_prefill_sweep",
          "chunked_prefill_sweep (stall-free mixed batching)",
          chunked_prefill_sweep.run,
          {"n_requests": 60 if smoke else 220},
          chunked_prefill_sweep.headline,
          lambda rows: {
              "p99_tbt_gain_vs_monolithic":
                  next(r for r in rows if r["workload"] == "mixed-long"
                       and r["policy"] == "monolithic")["p99_tbt"]
                  / max(next(r for r in rows if r["workload"] == "mixed-long"
                             and r["policy"] == "decode_first")["p99_tbt"],
                        1e-12),
              "decode_first_p99_tbt_s":
                  next(r for r in rows if r["workload"] == "mixed-long"
                       and r["policy"] == "decode_first")["p99_tbt"]})

    bench("disagg_sweep",
          "disagg_sweep (prefill/decode disaggregation frontier)",
          disagg_sweep.run,
          {"n_requests": 80 if smoke else 200,
           "rates": disagg_sweep.SMOKE_RATES if smoke
           else disagg_sweep.RATES},
          disagg_sweep.headline,
          lambda rows: {
              "p99_tbt": {f"{r['system']}@{r['rate']:g}": r["p99_tbt"]
                          for r in rows},
              "throughput": {f"{r['system']}@{r['rate']:g}": r["throughput"]
                             for r in rows},
              "handoffs_leased": sum(r.get("handoffs_leased", 0)
                                     for r in rows
                                     if r["system"] == "disagg-2p2d"),
              "handoffs_migrated": sum(r.get("handoffs_migrated", 0)
                                       for r in rows
                                       if r["system"] == "disagg-2p2d")})

    bench("swap_sweep", "swap_sweep (swap-to-host vs recompute crossover)",
          swap_sweep.run,
          # the two operating points are already CI-sized; the PCIe swap
          # lane calibration is pinned here so the artifact records it
          {"pcie_gbps": 256.0, "t_swap_fixed": 2e-5},
          swap_sweep.headline,
          lambda rows: {
              "long_throughput": {
                  r["system"]: r["throughput"] for r in rows
                  if r["point"] == "long" and "throughput" in r},
              "long_p99_norm_lat": {
                  r["system"]: r["p99_norm_lat"] for r in rows
                  if r["point"] == "long" and "p99_norm_lat" in r},
              "short_throughput": {
                  r["system"]: r["throughput"] for r in rows
                  if r["point"] == "short" and "throughput" in r},
              "reprefill_ok": not next(
                  r for r in rows if r["system"] == "proof"
              )["reprefill_problems"]})

    bench("mla_sweep", "mla_sweep (latent-KV paging vs GQA at fixed HBM)",
          mla_sweep.run,
          # the two layout points are already CI-sized; the HBM KV budget
          # is pinned here so the artifact records it
          {"hbm_budget": mla_sweep.HBM_KV_BUDGET},
          mla_sweep.headline,
          lambda rows: {
              "bytes_per_token": {r["layout"]: r["bytes_per_token"]
                                  for r in rows},
              "compression_ratio":
                  next(r for r in rows if r["layout"] == "gqa")
                  ["bytes_per_token"]
                  / next(r for r in rows if r["layout"] == "mla")
                  ["bytes_per_token"],
              "throughput": {r["layout"]: r["throughput"] for r in rows},
              "p99_norm_lat": {r["layout"]: r["p99_norm_lat"]
                               for r in rows},
              "achievable_batch": {r["layout"]: r["achievable_batch"]
                                   for r in rows},
              "completed": {r["layout"]: r["completed"] for r in rows}})

    bench("prefix_cache_sweep", "prefix_cache_sweep (radix KV reuse)",
          prefix_cache_sweep.run,
          {"n_requests": 50 if smoke else 150},
          lambda out: "shared_speedup=%.3fx,hit=%.0f%%" % (
              out[0]["speedup"], 100 * out[0]["hit_rate"]),
          lambda out: {"shared_speedup": out[0]["speedup"],
                       "hit_rate": out[0]["hit_rate"]})

    bench("router_sweep", "router_sweep (cluster placement policies)",
          router_sweep.run,
          {"n_requests": 60 if smoke else 160},
          router_sweep.headline,
          lambda rows: {
              "affinity_hit_rate":
                  next(r for r in rows if r["workload"] == "shared-prefix"
                       and r["policy"] == "prefix_affinity"
                       and not r["share"])["hit_rate"],
              "round_robin_hit_rate":
                  next(r for r in rows if r["workload"] == "shared-prefix"
                       and r["policy"] == "round_robin"
                       and not r["share"])["hit_rate"]})

    bench("zero_copy_sweep",
          "zero_copy_sweep (copy vs borrowed-rBlock prefix serving)",
          zero_copy_sweep.run,
          {"n_requests": 60 if smoke else 160,
           "out_lens": (16, 96) if smoke else (16, 96, 256)},
          zero_copy_sweep.headline,
          lambda rows: {
              "net_ms": {f"{r['mode']}@{r['out_len']}": r["net_ms"]
                         for r in rows},
              "borrowed_pages": sum(r["borrowed_pages"] for r in rows)})

    bench("orca_scheduling", "orca_iteration_vs_batch",
          orca_scheduling.run,
          {"n_requests": 60 if smoke else 300},
          lambda out: "batch/iter=%.1fx" % max(
              r["batch_lat"] / r["iter_lat"] for r in out),
          lambda out: {"max_batch_over_iter_latency": max(
              r["batch_lat"] / r["iter_lat"] for r in out)})

    bench("roofline_report", "roofline_report (dry-run artifacts)",
          roofline_report.run, {},
          lambda out: "rows=%d" % len(out),
          lambda out: {"rows": len(out)})

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.0f},{derived}")
    print(f"\nartifacts: {args.out_dir}/BENCH_*.json (rev {rev})")

    if failures:
        print(f"\nFAILED benchmarks: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
