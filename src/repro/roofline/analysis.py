"""Roofline-term extraction from a compiled dry-run artifact.

Terms (TPU v5e constants):
  compute    = FLOPs / (chips x 197e12)         [bf16 MXU peak]
  memory     = bytes / (chips x 819e9)          [HBM]
  collective = collective bytes / (chips x 50e9) [ICI per link]

``cost_analysis`` of the SPMD-partitioned module reports *per-device*
FLOPs/bytes, so terms divide by per-chip peaks directly. Collective bytes
are not in cost_analysis: we parse the post-partitioning HLO text and sum
the output-shape bytes of every collective op (per-device traffic).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # bytes/s / chip
LINK_BW = 50e9       # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every typed shape literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device) from HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        shape_part, op = m.groups()
        # normalize fusion'd names like all-reduce-start
        base = op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _shape_bytes(shape_part)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device
    bytes_accessed: float        # per-device
    coll_bytes: float            # per-device, summed over kinds
    coll_breakdown: Dict[str, int]
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "chips": self.chips,
        }


def analyze(compiled, chips: int) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some backends return [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    cb = collective_bytes(compiled.as_text())
    return Roofline(flops=flops, bytes_accessed=byts,
                    coll_bytes=float(sum(cb.values())), coll_breakdown=cb,
                    chips=chips)


def extrapolate(r2: Roofline, r4: Roofline, l2: int, l4: int,
                l_full: int) -> Roofline:
    """Linear layer-count extrapolation between two capped compiles.

    Exact for per-layer terms because layers within a segment are
    structurally identical; the intercept captures embed/loss/top-level
    costs."""
    if l4 == l2:
        return r4

    def ext(v2, v4):
        slope = (v4 - v2) / (l4 - l2)
        return v2 + slope * (l_full - l2)

    cb = {k: max(0.0, ext(r2.coll_breakdown.get(k, 0),
                          r4.coll_breakdown.get(k, 0)))
          for k in set(r2.coll_breakdown) | set(r4.coll_breakdown)}
    return Roofline(
        flops=max(0.0, ext(r2.flops, r4.flops)),
        bytes_accessed=max(0.0, ext(r2.bytes_accessed, r4.bytes_accessed)),
        coll_bytes=float(sum(cb.values())),
        coll_breakdown={k: int(v) for k, v in cb.items()},
        chips=r4.chips,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode), N = *matmul*
    params (embeddings excluded — lookups are gathers, not FLOPs; the
    unembed projection is added back explicitly). MoE uses N_active.
    Enc-dec: encoder params see seq/4 tokens (the dry-run's encoder input),
    decoder params the full seq."""
    n = cfg.n_active_params() if cfg.is_moe else cfg.n_params()
    from repro.models.layers import pad_vocab
    embed = pad_vocab(cfg.vocab_size) * cfg.d_model
    n_matmul = n - embed * (1 if cfg.tie_embeddings else 2)

    factor = 6 if shape.kind == "train" else 2
    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
    else:
        tokens = shape.global_batch  # one new token per sequence

    if cfg.is_encdec:
        enc_frac = cfg.encoder_layers / (cfg.encoder_layers + cfg.num_layers)
        enc_tokens = (shape.global_batch * (shape.seq_len // 4)
                      if shape.kind != "decode" else 0)
        f = factor * n_matmul * (
            (1 - enc_frac) * tokens + enc_frac * enc_tokens) / 1.0
    else:
        f = factor * n_matmul * tokens
    # unembed projection (vocab-parallel matmul is real compute)
    f += factor * embed * tokens if shape.kind == "train" else \
        2 * embed * shape.global_batch  # prefill unembeds last position only
    return f
