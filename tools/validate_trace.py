#!/usr/bin/env python
"""Validate an exported Chrome/Perfetto trace, and check the
tracer-disabled path really is free.

Usage:
  PYTHONPATH=src python tools/validate_trace.py out.json [more.json ...]
  PYTHONPATH=src python tools/validate_trace.py --check-disabled-overhead

Validation runs the structural schema checks shared with the exporter
tests (``repro.core.telemetry.validate_trace_events``): top-level shape,
required per-event fields, known phase codes, non-negative durations, and
balanced async begin/end spans — plus the host-swap invariant
(``repro.core.telemetry.validate_swap_balance``): per request,
``sched.swap_out``/``sched.swap_in`` instants must alternate with at most
one unmatched trailing swap_out. Exit status is non-zero on any problem.

``--check-disabled-overhead`` runs the chunked-prefill sim path twice —
telemetry off, then on — and asserts with ``tracemalloc`` that the
disabled run allocates ZERO bytes attributable to the telemetry module
files: with ``trace=False`` every emission site is a single ``None``
attribute test, so no Event object, args dict, or string may be
constructed. (A wall-clock <2% bound is reported for information but not
enforced — CI machines are too noisy to gate on sub-percent timing.)
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import tracemalloc


def validate_files(paths) -> int:
    from repro.core.telemetry import validate_swap_balance, \
        validate_trace_events
    bad = 0
    for path in paths:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({e})")
            bad += 1
            continue
        errors = validate_trace_events(obj)
        # host-swap invariant: per request, swap_out/swap_in instants
        # alternate (at most one unmatched trailing swap_out)
        errors += validate_swap_balance(obj)
        n = len(obj.get("traceEvents", obj) if isinstance(obj, (dict, list))
                else [])
        if errors:
            bad += 1
            print(f"{path}: INVALID ({len(errors)} problems, {n} events)")
            for e in errors[:20]:
                print(f"  - {e}")
            if len(errors) > 20:
                print(f"  ... and {len(errors) - 20} more")
        else:
            print(f"{path}: OK ({n} events)")
    return bad


def _sim_run(trace: bool):
    from repro.serving.simulator import make_workload, simulate_paged
    reqs = make_workload(80, rate=40.0, seed=7, max_len=512)
    return simulate_paged(reqs, num_blocks=400, block_size=16,
                          max_tokens_per_iter=512, trace=trace)


def check_disabled_overhead() -> int:
    import repro.core.telemetry.tracer as tracer_mod
    import repro.core.telemetry.metrics as metrics_mod

    _sim_run(False)  # warm imports/caches outside the measured window

    telemetry_files = (tracer_mod.__file__, metrics_mod.__file__)
    flt = [tracemalloc.Filter(True, f) for f in telemetry_files]
    tracemalloc.start(5)
    _sim_run(False)
    snap = tracemalloc.take_snapshot().filter_traces(flt)
    tracemalloc.stop()
    telemetry_bytes = sum(st.size for st in snap.statistics("filename"))

    # time outside the tracemalloc window — it slows every allocation
    t0 = time.perf_counter()
    _sim_run(False)
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_on = _sim_run(True)
    t_on = time.perf_counter() - t0

    print(f"tracer-disabled run: {telemetry_bytes} bytes allocated by "
          f"telemetry code (must be 0)")
    print(f"wall time: disabled {t_off * 1e3:.1f}ms, enabled "
          f"{t_on * 1e3:.1f}ms ({len(res_on.events)} events) "
          f"[informational]")
    if telemetry_bytes != 0:
        print("FAIL: the disabled path constructed telemetry objects")
        return 1
    print("OK: disabled path allocates nothing in the telemetry layer")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="*",
                    help="trace-event JSON files to validate")
    ap.add_argument("--check-disabled-overhead", action="store_true",
                    help="assert the tracer-disabled sim path allocates "
                         "nothing in the telemetry layer")
    args = ap.parse_args()
    if not args.traces and not args.check_disabled_overhead:
        ap.error("nothing to do: pass trace files and/or "
                 "--check-disabled-overhead")
    bad = validate_files(args.traces)
    if args.check_disabled_overhead:
        bad += check_disabled_overhead()
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
