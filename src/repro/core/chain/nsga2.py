"""NSGA-II from scratch (Deb et al. 2002) — pymoo is unavailable offline.

Implements exactly what the paper's §II.B needs: fast non-dominated sorting,
crowding distance, binary tournament selection, single-point crossover,
bit-flip mutation, and Deb's feasibility-first constraint domination (the
paper's "each block must be assigned to at least one server" constraint).

Generic over any problem exposing::

    n_var: int                      # binary genome length
    evaluate(x: np.ndarray) -> (objs: np.ndarray[n_obj], cv: float)

Objectives are minimized; cv <= 0 means feasible.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Individual:
    x: np.ndarray
    f: np.ndarray  # objectives (minimize)
    cv: float  # constraint violation, <=0 feasible
    rank: int = 0
    crowding: float = 0.0


def _dominates(a: Individual, b: Individual) -> bool:
    """Deb's constrained domination."""
    a_feas, b_feas = a.cv <= 0, b.cv <= 0
    if a_feas and not b_feas:
        return True
    if b_feas and not a_feas:
        return False
    if not a_feas and not b_feas:
        return a.cv < b.cv
    return bool(np.all(a.f <= b.f) and np.any(a.f < b.f))


def fast_non_dominated_sort(pop: List[Individual]) -> List[List[int]]:
    n = len(pop)
    S = [[] for _ in range(n)]
    nd = [0] * n
    fronts: List[List[int]] = [[]]
    for i in range(n):
        for j in range(n):
            if i == j:
                continue
            if _dominates(pop[i], pop[j]):
                S[i].append(j)
            elif _dominates(pop[j], pop[i]):
                nd[i] += 1
        if nd[i] == 0:
            pop[i].rank = 0
            fronts[0].append(i)
    k = 0
    while fronts[k]:
        nxt = []
        for i in fronts[k]:
            for j in S[i]:
                nd[j] -= 1
                if nd[j] == 0:
                    pop[j].rank = k + 1
                    nxt.append(j)
        k += 1
        fronts.append(nxt)
    return fronts[:-1]


def crowding_distance(pop: List[Individual], front: Sequence[int]) -> None:
    if not front:
        return
    m = len(pop[front[0]].f)
    for i in front:
        pop[i].crowding = 0.0
    for k in range(m):
        vals = sorted(front, key=lambda i: pop[i].f[k])
        fmin, fmax = pop[vals[0]].f[k], pop[vals[-1]].f[k]
        pop[vals[0]].crowding = pop[vals[-1]].crowding = np.inf
        if fmax == fmin:
            continue
        for a, i, b in zip(vals, vals[1:-1], vals[2:]):
            pop[i].crowding += (pop[b].f[k] - pop[a].f[k]) / (fmax - fmin)


def _tournament(pop: List[Individual], rng: np.random.Generator) -> Individual:
    i, j = rng.integers(0, len(pop), 2)
    a, b = pop[i], pop[j]
    if a.rank != b.rank:
        return a if a.rank < b.rank else b
    return a if a.crowding > b.crowding else b


def single_point_crossover(x1, x2, rng) -> Tuple[np.ndarray, np.ndarray]:
    cut = rng.integers(1, len(x1))
    return (np.concatenate([x1[:cut], x2[cut:]]),
            np.concatenate([x2[:cut], x1[cut:]]))


def bitflip_mutation(x, rng, rate: float) -> np.ndarray:
    flip = rng.random(len(x)) < rate
    y = x.copy()
    y[flip] = 1 - y[flip]
    return y


@dataclasses.dataclass
class NSGA2Result:
    pareto: List[Individual]  # feasible first front
    population: List[Individual]
    evaluations: int


def nsga2(
    evaluate: Callable[[np.ndarray], Tuple[np.ndarray, float]],
    n_var: int,
    *,
    pop_size: int = 100,
    generations: int = 60,
    mutation_rate: float | None = None,
    crossover_prob: float = 0.9,
    seed: int = 0,
    init: Callable[[np.random.Generator], np.ndarray] | None = None,
) -> NSGA2Result:
    rng = np.random.default_rng(seed)
    mutation_rate = mutation_rate if mutation_rate is not None else 1.0 / n_var
    evals = 0

    def make(x) -> Individual:
        nonlocal evals
        f, cv = evaluate(x)
        evals += 1
        return Individual(x=x, f=np.asarray(f, float), cv=float(cv))

    if init is None:
        init = lambda r: (r.random(n_var) < 0.3).astype(np.int8)
    pop = [make(init(rng)) for _ in range(pop_size)]
    fronts = fast_non_dominated_sort(pop)
    for fr in fronts:
        crowding_distance(pop, fr)

    for _ in range(generations):
        children = []
        while len(children) < pop_size:
            p1, p2 = _tournament(pop, rng), _tournament(pop, rng)
            if rng.random() < crossover_prob:
                c1, c2 = single_point_crossover(p1.x, p2.x, rng)
            else:
                c1, c2 = p1.x.copy(), p2.x.copy()
            children.append(make(bitflip_mutation(c1, rng, mutation_rate)))
            if len(children) < pop_size:
                children.append(make(bitflip_mutation(c2, rng, mutation_rate)))
        union = pop + children
        fronts = fast_non_dominated_sort(union)
        newpop: List[Individual] = []
        for fr in fronts:
            crowding_distance(union, fr)
            if len(newpop) + len(fr) <= pop_size:
                newpop.extend(union[i] for i in fr)
            else:
                rest = sorted(fr, key=lambda i: -union[i].crowding)
                newpop.extend(union[i]
                              for i in rest[:pop_size - len(newpop)])
                break
        pop = newpop

    fronts = fast_non_dominated_sort(pop)
    pareto = [pop[i] for i in fronts[0] if pop[i].cv <= 0]
    return NSGA2Result(pareto=pareto, population=pop, evaluations=evals)


def hypervolume_2d(points: np.ndarray, ref: np.ndarray) -> float:
    """Exact 2-objective hypervolume (minimization, reference point ``ref``)."""
    pts = points[np.all(points <= ref, axis=1)]
    if len(pts) == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0])]
    hv = 0.0
    cur_f1 = ref[1]
    for f0, f1 in pts:
        if f1 < cur_f1:
            hv += (ref[0] - f0) * (cur_f1 - f1)
            cur_f1 = f1
    return hv
