"""Chunked-prefill sweep: stall-free mixed prefill/decode batching.

Replays mixed traffic — decode-heavy chat requests plus a fraction of
long-prompt (document-ingest) requests whose prompts exceed the iteration
token budget — through the virtual-clock sim for each chunked-prefill
policy:

* ``monolithic``    — the solo-prefill baseline: the long prompt is
  admitted next to the running decodes and prefills in ONE iteration, so
  every decode stalls for the full prefill (vLLM-default behavior — the
  tail-TBT pathology);
* ``solo``          — the legacy repo stand-in: an over-budget prompt waits
  for an *idle* instance and then runs alone. Decodes never stall, but the
  waiting prompt head-of-line-blocks all admissions behind it while the
  decodes drain (the TTFT/throughput pathology);
* ``decode_first``  — Sarathi-style stall-free batching: running decodes
  get budget first, the long prefill contributes budget-sized chunks that
  piggyback with them — both pathologies gone;
* ``prefill_first`` — chunks take the budget first, decodes run in the
  leftover (TTFT-optimal, TBT-hostile under prefill pressure).

Expected headline (the PR's acceptance bar): on the mixed workload,
``decode_first`` improves P99 worst inter-token gap (the decode-stall tail)
by >= 2x over the solo-prefill (``monolithic``) baseline at no throughput
regression — while also beating the legacy ``solo`` policy's throughput
and TTFT (which it sacrificed to keep decodes smooth). A short-prompt
control workload (every prompt far below the budget) must be unaffected by
policy.

    PYTHONPATH=src python benchmarks/chunked_prefill_sweep.py [--smoke]
"""

from __future__ import annotations

import argparse

from repro.core.scheduling.iteration import CHUNK_POLICIES
from repro.serving.simulator import make_workload, simulate_paged

MAX_TOKENS_PER_ITER = 2048
NUM_BLOCKS = 6000
BLOCK_SIZE = 16
LONG_LEN = 12_288  # 6x the iteration budget: a 6-chunk prefill


def _workloads(n_requests: int):
    return [
        # decode-heavy chat + 8% long document-ingest prompts: the case
        # chunked prefill exists for
        ("mixed-long", lambda: make_workload(
            n_requests, rate=18.0, dist="sharegpt", seed=7, max_len=640,
            long_frac=0.08, long_len=LONG_LEN)),
        # short prompts only: the control — policies must not diverge
        ("short-only", lambda: make_workload(
            n_requests, rate=18.0, dist="sharegpt", seed=7, max_len=640)),
    ]


def run(n_requests: int = 220, verbose: bool = True):
    rows = []
    for wname, wl in _workloads(n_requests):
        for policy in CHUNK_POLICIES:
            res = simulate_paged(
                wl(), num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
                max_tokens_per_iter=MAX_TOKENS_PER_ITER,
                chunk_policy=policy)
            rows.append({
                "workload": wname,
                "policy": policy,
                "p99_tbt": res.p99_tbt,
                "mean_ttft": res.mean_ttft,
                "throughput": res.throughput_tokens_per_s,
                "completed": res.completed_frac,
            })
            if verbose:
                r = rows[-1]
                print(f"{wname:10s} {policy:14s}  "
                      f"p99-gap={1e3 * r['p99_tbt']:8.1f}ms  "
                      f"ttft={1e3 * r['mean_ttft']:8.1f}ms  "
                      f"thr={r['throughput']:7.1f} tok/s  "
                      f"done={r['completed']:.0%}")
    return rows


def headline(rows) -> str:
    """The acceptance comparison: decode_first vs the solo-prefill
    (monolithic) baseline on mixed traffic — P99 worst inter-token gap
    >= 2x better at no throughput regression — plus the legacy-solo
    throughput/TTFT win and the short-prompt control guard."""
    def pick(workload, policy):
        return next(r for r in rows if r["workload"] == workload
                    and r["policy"] == policy)

    mono = pick("mixed-long", "monolithic")
    solo = pick("mixed-long", "solo")
    chunked = pick("mixed-long", "decode_first")
    s_mono = pick("short-only", "monolithic")
    s_chunked = pick("short-only", "decode_first")
    gain = mono["p99_tbt"] / max(chunked["p99_tbt"], 1e-12)
    ok = (gain >= 2.0
          and chunked["throughput"] >= 0.99 * mono["throughput"]
          and chunked["completed"] >= mono["completed"]
          # the legacy idle-gated policy paid for its smooth decodes with
          # throughput and TTFT — chunking must win those back
          and chunked["throughput"] >= solo["throughput"]
          and chunked["mean_ttft"] <= solo["mean_ttft"]
          and abs(s_chunked["p99_tbt"] - s_mono["p99_tbt"])
          <= 0.05 * s_mono["p99_tbt"])
    return (f"chunked_vs_solo_prefill: p99-gap "
            f"{1e3 * mono['p99_tbt']:.0f}->{1e3 * chunked['p99_tbt']:.0f}ms "
            f"({gain:.1f}x) thr "
            f"{mono['throughput']:.0f}->{chunked['throughput']:.0f} tok/s; "
            f"vs legacy-solo: thr {solo['throughput']:.0f}->"
            f"{chunked['throughput']:.0f} tok/s ttft "
            f"{1e3 * solo['mean_ttft']:.0f}->"
            f"{1e3 * chunked['mean_ttft']:.0f}ms "
            f"guard={'ok' if ok else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exits nonzero unless chunked "
                         "prefill beats solo >= 2x on the P99 decode-stall "
                         "tail without a throughput regression")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()
    n = args.requests or (120 if args.smoke else 220)
    rows = run(n_requests=n)
    line = headline(rows)
    print(line)
    if args.smoke and "FAIL" in line:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
