"""Per-arch smoke tests: reduced variant (2 layers, d_model<=512, <=4
experts), one forward + one train step on CPU — output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, smoke_config
from repro.models import Model
from repro.training import optimizer
from repro.training.train_loop import make_train_step


def _batch(cfg, b=2, s=32, enc=16):
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    if cfg.is_encdec:
        batch["encoder_tokens"] = jax.random.randint(key, (b, enc), 0,
                                                     cfg.vocab_size)
    if cfg.frontend != "none":
        batch["media"] = 0.02 * jnp.ones(
            (b, cfg.num_media_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = smoke_config(arch)
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch["tokens"],
                                media=batch.get("media"),
                                encoder_tokens=batch.get("encoder_tokens"))
    b, s = batch["tokens"].shape
    from repro.models.layers import pad_vocab
    assert logits.shape == (b, s, pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = optimizer.init(params)
    step = jax.jit(make_train_step(model, optimizer.OptConfig(lr=1e-3)))
    batch = _batch(cfg)
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b=b, s=s)
    tokens = batch["tokens"]
    kw = dict(media=batch.get("media"),
              encoder_tokens=batch.get("encoder_tokens"))
    full, _ = model.forward(params, tokens, **kw)
    last, caches = model.prefill(params, tokens, seq_capacity=2 * s, **kw)
    np.testing.assert_allclose(
        np.asarray(last, np.float32), np.asarray(full[:, -1], np.float32),
        atol=0.08, rtol=0.08)
    # one decode step vs teacher forcing on the extended sequence
    nxt = jax.random.randint(jax.random.PRNGKey(1), (b, 1), 0,
                             cfg.vocab_size)
    ext = jnp.concatenate(
        [tokens, nxt, jnp.zeros((b, s - 1), jnp.int32)], axis=1)
    full2, _ = model.forward(params, ext, **kw)
    got, _ = model.decode_step(params, nxt,
                               jnp.full((b,), s, jnp.int32), caches)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(full2[:, s], np.float32),
        atol=0.2, rtol=0.2)


def test_long_context_flags_match_design():
    expected_long = {"hymba-1.5b", "mamba2-1.3b", "h2o-danube-1.8b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.supports_long_context == (arch in expected_long), arch


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "hymba-1.5b"])
def test_ssm_decode_state_is_constant_memory(arch):
    """SSM/hybrid decode cache must not grow with context length."""
    cfg = smoke_config(arch)
    model = Model(cfg, remat=False)
    c_small = model.init_cache(2, 64, as_specs=True)
    c_large = model.init_cache(2, 4096, as_specs=True)

    def ssm_sizes(caches):
        from repro.models.ssm import SSMCache
        out = []
        for c in caches:
            if isinstance(c, tuple):  # hybrid
                c = c[1]
            if isinstance(c, SSMCache):
                out.append((c.conv.shape, c.state.shape))
        return out

    assert ssm_sizes(c_small) == ssm_sizes(c_large)
