"""Vectorized per-slot sampling: top-k / top-p filter invariants, greedy
exactness, and per-request stream determinism (batch- and slot-independent)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.models import sampling


def _rows(n, v, seed=0):
    rng = np.random.default_rng(seed)
    # distinct values so top-k set membership is unambiguous
    x = rng.normal(size=(n, v)).astype(np.float32)
    x += np.linspace(0, 1e-3, v)[None, :] * rng.random((n, 1))
    return jnp.asarray(x)


def _kept(filtered):
    return np.isfinite(np.asarray(filtered))


# -- top-k ---------------------------------------------------------------------

def test_top_k_keeps_exactly_k_largest():
    logits = _rows(3, 64)
    ks = jnp.asarray([5, 1, 0], jnp.int32)  # 0 = disabled
    f = sampling.filter_logits(logits, ks, jnp.ones(3, jnp.float32))
    kept = _kept(f)
    assert kept[0].sum() == 5 and kept[1].sum() == 1 and kept[2].sum() == 64
    # the kept entries are precisely the k largest
    row = np.asarray(logits[0])
    assert set(np.where(kept[0])[0]) == set(np.argsort(-row)[:5])
    assert np.where(kept[1])[0][0] == np.argmax(np.asarray(logits[1]))


def test_top_p_keeps_smallest_set_reaching_mass():
    logits = _rows(4, 64, seed=1)
    ps = jnp.asarray([0.1, 0.5, 0.9, 1.0], jnp.float32)
    f = sampling.filter_logits(logits, jnp.zeros(4, jnp.int32), ps)
    kept = _kept(f)
    probs = np.array(jnp.exp(jnp.array(logits) -
                             jnp.max(logits, -1, keepdims=True)))
    probs /= probs.sum(-1, keepdims=True)
    for i, p in enumerate((0.1, 0.5, 0.9)):
        mass = probs[i][kept[i]].sum()
        # kept mass reaches p, and dropping the smallest kept token would
        # fall short of p: the nucleus is the *smallest* such set
        assert mass >= p - 1e-6
        assert mass - probs[i][kept[i]].min() < p + 1e-6
        # argmax always survives
        assert kept[i][np.argmax(probs[i])]
    assert kept[3].all()  # top_p = 1.0 disables the filter


def test_top_k_and_top_p_compose():
    logits = _rows(1, 32, seed=2)
    f = sampling.filter_logits(logits, jnp.asarray([4], jnp.int32),
                               jnp.asarray([0.99], jnp.float32))
    assert _kept(f)[0].sum() <= 4


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_filter_invariants_property(seed):
    rng = np.random.default_rng(seed)
    n, v = 4, 48
    logits = jnp.asarray(rng.normal(size=(n, v)).astype(np.float32))
    top_k = jnp.asarray(rng.integers(0, v + 1, n), jnp.int32)
    top_p = jnp.asarray(rng.uniform(0.05, 1.0, n).astype(np.float32))
    kept = _kept(sampling.filter_logits(logits, top_k, top_p))
    arg = np.argmax(np.asarray(logits), -1)
    for i in range(n):
        assert kept[i].any() and kept[i][arg[i]]
        if int(top_k[i]) > 0:
            assert kept[i].sum() <= int(top_k[i])


# -- sample_batch --------------------------------------------------------------

def _sample(logits, seeds, steps, temp, top_k=None, top_p=None):
    n = logits.shape[0]
    return sampling.sample_batch(
        logits, jnp.asarray(seeds, jnp.int32), jnp.asarray(steps, jnp.int32),
        jnp.asarray(temp, jnp.float32),
        jnp.asarray(top_k if top_k is not None else [0] * n, jnp.int32),
        jnp.asarray(top_p if top_p is not None else [1.0] * n, jnp.float32))


def test_greedy_rows_are_exact_argmax():
    logits = _rows(4, 64, seed=3)
    toks, lps = _sample(logits, [0] * 4, [0] * 4, [0.0, 0.0, 1.0, 0.0])
    arg = np.argmax(np.asarray(logits), -1)
    assert list(np.asarray(toks)[[0, 1, 3]]) == list(arg[[0, 1, 3]])
    # reported logprob is log-softmax of the chosen token
    lsm = np.asarray(jnp.log(jnp.exp(logits[0] - jnp.max(logits[0])) /
                             jnp.sum(jnp.exp(logits[0] - jnp.max(logits[0])))))
    assert np.isclose(float(lps[0]), lsm[arg[0]], atol=1e-5)


def test_sampled_token_respects_filters():
    logits = _rows(8, 64, seed=4)
    # top_k=1 forces the argmax even at high temperature
    toks, _ = _sample(logits, list(range(8)), [0] * 8, [2.0] * 8,
                      top_k=[1] * 8)
    assert list(np.asarray(toks)) == list(np.argmax(np.asarray(logits), -1))


def test_stream_is_deterministic_and_batch_independent():
    logits = _rows(6, 64, seed=5)
    a = _sample(logits, [7] * 6, list(range(6)), [1.0] * 6)[0]
    b = _sample(logits, [7] * 6, list(range(6)), [1.0] * 6)[0]
    assert list(np.asarray(a)) == list(np.asarray(b))
    # row 2 sampled alone (same seed/step) draws the same token as in-batch
    alone = _sample(logits[2:3], [7], [2], [1.0])[0]
    assert int(alone[0]) == int(a[2])


def test_different_steps_decorrelate():
    logits = jnp.zeros((32, 128), jnp.float32)  # uniform: pure randomness
    toks, _ = _sample(logits, [11] * 32, list(range(32)), [1.0] * 32)
    assert len(set(np.asarray(toks).tolist())) > 8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_temperature_mass_property(seed):
    """Sampled tokens at low temperature concentrate on higher-probability
    tokens than at high temperature (distributional sanity via many seeds)."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(1, 32)).astype(np.float32) * 3)
    lo = [int(_sample(logits, [s], [0], [0.3])[0][0]) for s in range(40)]
    hi = [int(_sample(logits, [s], [0], [3.0])[0][0]) for s in range(40)]
    p = np.asarray(jnp.exp(logits[0] - jnp.max(logits[0])))
    p /= p.sum()
    assert np.mean(p[lo]) >= np.mean(p[hi]) - 1e-3
