"""Quickstart: train a small model on the synthetic corpus, checkpoint it,
and serve a few requests through the LLMService front-end (continuous
batching on the paged engine).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import smoke_config
from repro.serving.api import LLMService, SamplingParams
from repro.serving.engine import EngineConfig, PagedEngine
from repro.training import checkpoint
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, train


def main():
    cfg = smoke_config("h2o-danube-1.8b")

    print("== training 120 steps on the synthetic corpus ==")
    res = train(cfg, TrainConfig(
        steps=120, log_every=30,
        opt=OptConfig(lr=1e-3, warmup_steps=15, total_steps=120)))
    first, last = res["losses"][0][1], res["losses"][-1][1]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.3, "model failed to learn"

    path = checkpoint.save("/tmp/quickstart_ckpt", 120,
                           {"params": res["params"]})
    print(f"checkpoint written to {path}")

    print("\n== serving the trained model (LLMService, continuous batching) ==")
    restored = checkpoint.restore("/tmp/quickstart_ckpt", 120,
                                  {"params": res["params"]})
    eng = PagedEngine(cfg, restored["params"],
                      EngineConfig(num_pages=128, page_size=8, max_slots=4))
    svc = LLMService(eng)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, 8).tolist() for _ in range(4)]
    outs = svc.generate(prompts, SamplingParams(max_new_tokens=8))
    for out in outs:
        print(f"req {out.request_id}: {out.token_ids} "
              f"({out.finish_reason}, ttft {out.metrics.ttft:.2f}s)")
    print(f"kv pages free: {eng.allocator.num_free}/{eng.allocator.num_blocks}")


if __name__ == "__main__":
    main()
