"""DistAttention: sequence-sharded micro-attention (InfiniteLLM §III.D.2),
TPU-native.

The paper partitions a long KV cache into Micro Attentions (MAs), "each
handling a subset of KV cache tokens independently", then "aggregates their
results for the final attention computation". On TPU the KV sequence axis is
sharded across a mesh axis; each device runs the shard-local attention
producing partial ``(o, m, l)`` (flash-decoding-style), and the partials are
merged with the numerically-stable log-sum-exp combine over the mesh axis —
ICI collectives replace the paper's datacenter RDMA reads.

Used by the ``long_500k`` decode path (where it is what makes the shape
feasible) and exposed standalone for tests/benchmarks.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def micro_attention_partial(q, k, v, valid, *, scale: Optional[float] = None):
    """Shard-local Micro Attention (single-query view of
    :func:`attention_partial`).

    q: (B, H, Dh); k, v: (B, S_local, Hkv, Dh); valid: (B, S_local) bool.
    Returns (o_unnorm (B,H,Dh) fp32, m (B,H), l (B,H)) — un-normalized
    weighted values plus the local softmax statistics.
    """
    o, m, l = attention_partial(q[:, None], k, v, valid[:, None, :],
                                scale=scale)
    return o[:, 0], m[:, 0], l[:, 0]


def merge_partials(o, m, l, axis_name: str):
    """Log-sum-exp merge of micro-attention partials over a mesh axis.

    o: un-normalized (B,H,Dh); m, l: (B,H). Returns normalized (B,H,Dh).
    """
    m_glob = lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)  # (B,H)
    l_glob = lax.psum(l * corr, axis_name)
    o_glob = lax.psum(o * corr[..., None], axis_name)
    return o_glob / jnp.maximum(l_glob, 1e-9)[..., None]


def attention_partial(q, k, v, mask, *, scale: Optional[float] = None):
    """Masked multi-query Micro Attention partial (the ``T > 1`` sibling of
    :func:`micro_attention_partial`, with a per-query mask).

    q: (B, T, H, Dh); k, v: (B, S, Hkv, Dh); mask: (B, T, S) bool — entry
    ``[b, t, s]`` says query ``t`` may attend key ``s`` (causality and
    validity folded into one mask by the caller). Returns
    ``(o_unnorm (B,T,H,Dh) fp32, m (B,T,H), l (B,T,H))`` ready for
    :func:`merge_partials_tree` — the pieces the engine's zero-copy paths
    merge across local pages and pages borrowed from a peer instance.
    """
    b, t, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    qg = q.reshape(b, t, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bthgd,bshd->bthgs", qg, k.astype(jnp.float32)) * scale
    s = jnp.where(mask[:, :, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)  # (b,t,hkv,g)
    m_safe = jnp.maximum(m, -1e30)  # fully-masked queries must not NaN
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(mask[:, :, None, None, :], p, 0.0)
    l = p.sum(-1)
    o = jnp.einsum("bthgs,bshd->bthgd", p, v.astype(jnp.float32))
    return (o.reshape(b, t, h, dh), m_safe.reshape(b, t, h),
            l.reshape(b, t, h))


def merge_partials_tree(os, ms, ls):
    """Host-side merge across a *list* of partials (used by the engine when
    rBlocks of one sequence live on several instances)."""
    m_glob = jnp.max(jnp.stack(ms), axis=0)
    acc_o = 0.0
    acc_l = 0.0
    for o, m, l in zip(os, ms, ls):
        corr = jnp.exp(m - m_glob)
        acc_l = acc_l + l * corr
        acc_o = acc_o + o * corr[..., None]
    return acc_o / jnp.maximum(acc_l, 1e-9)[..., None]


def dist_attention(mesh, q, k, v, context_lens, *, axis: str = "model"):
    """Full DistAttention decode over a sequence-sharded KV cache.

    q: (B, H, Dh) replicated over ``axis``; k, v: (B, S, Hkv, Dh) with S
    sharded over ``axis``; context_lens: (B,).
    """
    s_total = k.shape[1]
    n_shards = mesh.shape[axis]
    s_local = s_total // n_shards

    def shard_fn(q_l, k_l, v_l, lens):
        idx = lax.axis_index(axis)
        pos = idx * s_local + jnp.arange(s_local)  # absolute positions
        valid = pos[None, :] < lens[:, None]
        o, m, l = micro_attention_partial(q_l, k_l, v_l, valid)
        return merge_partials(o, m, l, axis)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None),
                  P()),
        out_specs=P(),
    )
    return fn(q, k, v, context_lens)


def dist_attention_ref(q, k, v, context_lens):
    """Unsharded oracle."""
    b, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    pos = jnp.arange(k.shape[1])
    valid = pos[None, :] < context_lens[:, None]
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32)) / (dh ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, dh)
