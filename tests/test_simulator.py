"""Serving simulator invariants (the machinery behind Fig. 9/10 benches)."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.serving.simulator import (CostModel, make_workload,
                                     simulate_batch_level, simulate_distkv,
                                     simulate_paged, simulate_prealloc)


def test_workload_shapes():
    reqs = make_workload(50, rate=5.0, dist="alpaca", seed=0)
    assert len(reqs) == 50
    assert all(r.prompt_len >= 4 and r.max_new_tokens >= 1 for r in reqs)
    arr = [r.arrival_time for r in reqs]
    assert arr == sorted(arr)


def test_long_fraction_requests_are_prompt_heavy():
    reqs = make_workload(300, rate=5.0, seed=0, long_frac=0.2,
                         long_len=8000)
    longs = [r for r in reqs if r.prompt_len + r.max_new_tokens > 4000]
    assert longs
    for r in longs:
        assert r.prompt_len / (r.prompt_len + r.max_new_tokens) > 0.8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_paged_sim_completes_everything(seed):
    reqs = make_workload(40, rate=10.0, seed=seed)
    res = simulate_paged(reqs, num_blocks=4096, block_size=16)
    assert res.completed_frac == 1.0
    assert all(r.total_generated == r.max_new_tokens +
               len(r.committed_output) - len(r.committed_output)
               or r.total_generated >= 1 for r in reqs)
    assert res.makespan > 0
    assert 0 < res.kv_utilization <= 1.0


def test_oracle_never_worse_than_max():
    wl = lambda: make_workload(150, rate=20.0, dist="sharegpt", seed=4)
    oracle = simulate_prealloc(wl(), total_slots=30_000, policy="oracle")
    mx = simulate_prealloc(wl(), total_slots=30_000, policy="max")
    assert oracle.mean_normalized_latency <= mx.mean_normalized_latency + 1e-9
    assert oracle.kv_utilization >= mx.kv_utilization


def test_paged_beats_max_under_pressure():
    wl = lambda: make_workload(200, rate=25.0, dist="sharegpt", seed=5)
    paged = simulate_paged(wl(), num_blocks=1500, block_size=16)
    mx = simulate_prealloc(wl(), total_slots=1500 * 16, policy="max")
    assert paged.mean_normalized_latency < mx.mean_normalized_latency


def test_batch_level_worse_latency():
    wl = lambda: make_workload(100, rate=4.0, dist="sharegpt", seed=6)
    it = simulate_paged(wl(), num_blocks=4096)
    bl = simulate_batch_level(wl(), max_batch=16)
    assert bl.mean_normalized_latency > it.mean_normalized_latency


def test_distkv_completes_overflow_requests():
    wl = lambda: make_workload(60, rate=10.0, seed=1, long_frac=0.1,
                               long_len=20_000, max_len=1024)
    with_borrow = simulate_distkv(wl(), borrow=True,
                                  blocks_per_instance=800)
    without = simulate_distkv(wl(), borrow=False, blocks_per_instance=800)
    assert with_borrow.completed_frac == 1.0
    assert without.rejected > 0  # 20k tokens cannot fit one 12.8k instance


def test_cost_model_monotonicity():
    c = CostModel()
    assert c.iteration_time(100, 0) < c.iteration_time(200, 0)
    assert c.iteration_time(100, 1000) < c.iteration_time(100, 2000)
    assert c.iteration_time(100, 1000, 0) < c.iteration_time(100, 1000, 500)
