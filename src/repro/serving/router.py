"""Multi-instance cluster router: one ServingBackend over N serving instances.

The paper's serving endgame (InfiniteLLM-style cluster serving) is many LLM
service instances behind one front door. :class:`RouterBackend` is that
front door as a *backend*: it implements the same ``ServingBackend``
protocol as ``PagedEngine`` and ``SimBackend``, over N child backends
(engine or sim, mixable), so ``LLMService`` and every benchmark drive a
whole cluster exactly like a single instance.

Placement is pluggable (``POLICIES``):

* ``round_robin``     — cycle through instances (the classic baseline);
* ``least_loaded``    — fewest queued+running requests, then the smallest
  in-flight **prefill token backlog** (queued prompts + unprefilled
  remainders of running chunked prefills), then most free KV pages (a
  stand-in for the load heartbeats a real gManager aggregates);
* ``prefix_affinity`` — probe every instance's radix tree for the longest
  cached match of the prompt and route to the best one (SGLang-style
  cache-aware routing); below a match threshold fall back to least-loaded
  so cold traffic still spreads.

Cross-instance prefix sharing (``prefix_share=True``) layers the distkv
publication board underneath placement: after each step the router exports
any radix path whose hit count crossed ``hot_threshold`` from its owning
instance — token keys + page payloads — and publishes it through the
cluster's :class:`~repro.core.distkv.gmanager.GManager`. Each child
scheduler gets a ``prefix_importer`` hook, so at admission an instance that
only partially matches a prompt locally adopts the published extension into
its *own* radix tree (fresh local blocks, payloads copied in) instead of
recomputing the shared system prompt. A hot prefix is therefore computed
once cluster-wide and then served everywhere, even under round-robin
placement.

``share_mode`` picks how a published prefix reaches a peer:

* ``copy`` (default) — payload adoption as above: page contents are shipped
  once and live on in the peer's own radix tree;
* ``zero_copy`` — borrowed rBlocks: the peer's scheduler admits the request
  with a :class:`~repro.core.distkv.rmanager.RemoteLease` on the home
  instance's *physical* pages (pinned on the board, refcounted through the
  home allocator, debt tracked in the gManager ledger) and the engine
  serves them in place through the DistAttention partial ``(o, m, l)``
  merge — no payload ever moves, at the price of a per-iteration merge;
* ``auto`` — per-request decision by the
  :class:`~repro.core.distkv.netmodel.NetworkModel`: borrow when the
  estimated lifetime merge overhead undercuts the one-time payload copy
  (hot short prefixes borrow, long prefixes ahead of long decodes copy).

``net`` attaches that network cost model; virtual-clock children charge
copies and lease RPCs against their clock, wall-clock engines record them
as ``net_time``.

Disaggregated prefill/decode serving layers *roles* on top: pass
``InstanceSpec``-wrapped children and/or ``roles="2p2d"`` and the router
places new prompts only on prefill-capable instances, parks prefill-only
schedulers in ``prefill_only`` mode, and runs a
:class:`~repro.serving.disagg.KVHandoff` coordinator at the top of every
step that moves finished prompt KV to a decode instance (migrated payloads
or a zero-copy ``RemoteLease``, ``handoff_mode`` choosing per request in
``auto``). See ``serving/disagg.py`` for the full design.

Clock semantics: with all-virtual children (SimBackend) the router is
event-driven — each ``step`` advances the laggard instance, and ``clock()``
reports the cluster frontier, so policy sweeps over many instances run in
milliseconds. With any wall-clock child, ``step`` fans out to every
instance with work and ``clock()`` stays ``None`` (caller time), matching
the single-engine contract.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.distkv.gmanager import GManager, Heartbeat
from repro.core.distkv.netmodel import NetworkModel
from repro.core.distkv.rmanager import RManager
from repro.core.scheduling.request import Request
from repro.core.telemetry import Tracer, merge_events
from repro.serving.disagg import (HANDOFF_MODES, InstanceSpec, KVHandoff,
                                  parse_role_spec)

SHARE_MODES = ("copy", "zero_copy", "auto")


def _load_of(child) -> Tuple[int, int]:
    """Load of a child backend, lexicographic: (queued + running requests,
    prefill backlog tokens). The second component counts **in-flight prefill
    work** — queued prompts plus the unprefilled remainder of running
    chunked prefills — so an instance grinding through a 100k-token prompt
    ranks busier than a peer with the same request count serving chats."""
    sched = child.scheduler
    backlog = sched.prefill_backlog_tokens() \
        if hasattr(sched, "prefill_backlog_tokens") else 0
    return (len(sched.waiting) + len(sched.running), backlog)


def _free_pages_of(child) -> int:
    return child.allocator.num_free


class RoundRobinPolicy:
    """Cycle through instances in submission order."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, req: Request, children: Sequence) -> int:
        i = self._next % len(children)
        self._next += 1
        return i


class LeastLoadedPolicy:
    """Fewest queued+running requests, then smallest in-flight prefill
    token backlog; remaining ties go to the most free KV pages."""

    name = "least_loaded"

    def choose(self, req: Request, children: Sequence) -> int:
        return min(range(len(children)),
                   key=lambda i: (_load_of(children[i]),
                                  -_free_pages_of(children[i]), i))


class PrefixAffinityPolicy:
    """Route to the instance whose radix tree holds the longest cached
    match for the prompt (side-effect-free probe). Ties between equally-good
    matches break by load, and a match below ``min_match_tokens`` (default:
    one page) falls back to least-loaded — cold prompts must not pile onto
    instance 0."""

    name = "prefix_affinity"

    def __init__(self, min_match_tokens: Optional[int] = None):
        self.min_match_tokens = min_match_tokens
        self._fallback = LeastLoadedPolicy()

    def _match_tokens(self, child, prompt) -> int:
        pc = getattr(child, "prefix_cache", None)
        if pc is None:
            return 0
        path = pc.match(prompt, max_tokens=len(prompt) - 1, probe=True)
        return len(path) * pc.page_size

    def choose(self, req: Request, children: Sequence) -> int:
        prompt = req.prompt
        if not prompt:  # length-only (simulator) request: nothing to match
            return self._fallback.choose(req, children)
        matches = [self._match_tokens(c, prompt) for c in children]
        best = max(matches)
        threshold = self.min_match_tokens
        if threshold is None:
            pcs = [getattr(c, "prefix_cache", None) for c in children]
            threshold = min((pc.page_size for pc in pcs if pc is not None),
                            default=1)
        if best < threshold:
            return self._fallback.choose(req, children)
        cands = [i for i, m in enumerate(matches) if m == best]
        return min(cands, key=lambda i: (_load_of(children[i]),
                                         -_free_pages_of(children[i]), i))


POLICIES = {
    "round_robin": RoundRobinPolicy,
    "least_loaded": LeastLoadedPolicy,
    "prefix_affinity": PrefixAffinityPolicy,
}


@dataclasses.dataclass
class _AggregateCacheStats:
    """Duck-typed stand-in for a single PrefixCache in ``LLMService.stats``:
    cluster-wide hit rate over all children's radix trees."""

    hit_tokens: int = 0
    lookup_tokens: int = 0
    num_pages: int = 0
    adopted_pages: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0


class RouterBackend:
    """ServingBackend over N child backends with pluggable placement.

    ``children`` are fully-constructed backends (``PagedEngine`` /
    ``SimBackend``, mixable). ``policy`` is a name from :data:`POLICIES` or
    a policy object with ``choose(req, children) -> int``.

    ``prefix_share=True`` enables cross-instance prefix sharing through the
    distkv publication board (children need ``prefix_cache`` attached):
    radix paths matched by >= ``hot_threshold`` later requests are published
    with their page payloads, and peers adopt them at admission.
    """

    def __init__(self, children: Sequence, *,
                 policy: Union[str, object] = "round_robin",
                 prefix_share: bool = False,
                 share_mode: str = "copy",
                 hot_threshold: int = 1,
                 board_pages: Optional[int] = None,
                 net: Optional[NetworkModel] = None,
                 gmanager: Optional[GManager] = None,
                 roles: Optional[Union[str, Sequence[str]]] = None,
                 handoff_mode: str = "auto",
                 handoff_defer_cap: int = 8,
                 promote_after: Optional[int] = None,
                 peer_spill: bool = False):
        if not children:
            raise ValueError("RouterBackend needs at least one child backend")
        if share_mode not in SHARE_MODES:
            raise ValueError(f"share_mode must be one of {SHARE_MODES}, "
                             f"got {share_mode!r}")
        if share_mode != "copy" and not prefix_share:
            raise ValueError("share_mode needs prefix_share=True "
                             "(there is nothing to serve without the board)")
        # role-tagged membership: children may be bare backends (role
        # "mixed" — the previous N-identical-children behavior) or
        # InstanceSpec wrappers; roles= applies a spec ("2p2d") on top
        specs = [c if isinstance(c, InstanceSpec) else InstanceSpec(c)
                 for c in children]
        if roles is not None:
            role_list = parse_role_spec(roles)
            if len(role_list) != len(specs):
                raise ValueError(
                    f"roles spec names {len(role_list)} instances but "
                    f"{len(specs)} children were supplied")
            specs = [InstanceSpec(s.backend, role)
                     for s, role in zip(specs, role_list)]
        self.children = [s.backend for s in specs]
        self.roles = [s.role for s in specs]
        self.disaggregated = any(r != "mixed" for r in self.roles)
        self.prefill_capable = [i for i, r in enumerate(self.roles)
                                if r in ("prefill", "mixed")]
        self.decode_capable = [i for i, r in enumerate(self.roles)
                               if r in ("decode", "mixed")]
        self.prefill_only = [i for i, r in enumerate(self.roles)
                             if r == "prefill"]
        self.policy = POLICIES[policy]() if isinstance(policy, str) else \
            policy
        self.prefix_share = prefix_share
        self.share_mode = share_mode
        self.promote_after = promote_after
        self.promotions = 0
        # auto needs a cost model to decide, and disaggregation charges the
        # handoff transfer; zero_copy/copy work without one (network then
        # costs nothing on virtual clocks)
        self.net = net or (NetworkModel()
                           if share_mode == "auto" or self.disaggregated
                           or peer_spill
                           else None)
        self.hot_threshold = hot_threshold
        # board_pages: size cap for the publication board (LRU page
        # eviction) — ignored when an explicit gmanager is supplied
        self.g = gmanager or GManager(len(self.children),
                                      prefix_board_pages=board_pages)
        self.requests_placed: List[int] = [0] * len(self.children)
        self._placement: Dict[int, int] = {}  # request id -> instance
        # last-seen prefix_cache.hit_tokens per child: hot-path publication
        # (draining the cache's recently-hit list) and heartbeats only run
        # after an iteration that committed new cache hits
        self._last_hits: List[int] = [0] * len(self.children)
        self._virtual = all(c.clock() is not None for c in self.children)
        # zero-copy lease stats (cumulative; the gManager ledger holds the
        # *outstanding* debt)
        self.leases_granted = 0
        self.pages_borrowed = 0
        self.rms: Dict[int, RManager] = {}
        if prefix_share:
            sizes = set()
            for i, child in enumerate(self.children):
                if getattr(child, "prefix_cache", None) is None:
                    raise ValueError(
                        f"prefix_share needs a prefix cache on every child; "
                        f"instance {i} has none")
                sizes.add(child.prefix_cache.page_size)
            if len(sizes) > 1:
                # adoption re-chunks published token keys by the adopter's
                # local page size — only sound when pages are interchangeable
                raise ValueError(
                    f"prefix_share needs one page size across instances, "
                    f"got {sorted(sizes)}")
            # page *size* alone does not make pages interchangeable: the
            # per-token payload schema (KVPageLayout) must match too — a
            # GQA home's K/V page adopted into an MLA peer's latent pool
            # (or vice versa) would be silently-reinterpreted garbage
            schemas = {c.kv_layout.schema for c in self.children
                       if getattr(c, "kv_layout", None) is not None}
            if len(schemas) > 1:
                raise ValueError(
                    f"KV layout schema mismatch across prefix_share "
                    f"instances: {sorted(schemas)} — cross-instance page "
                    "payloads are only interchangeable between identical "
                    "layouts")
            for i, child in enumerate(self.children):
                child.prefix_cache.track_hot = True
                if share_mode != "zero_copy":
                    child.scheduler.prefix_importer = self._make_importer(i)
            if share_mode != "copy":
                self._wire_zero_copy()
        # peer KV spill tier: a child's radix cache parks cold pages in a
        # neighbor's free device memory (lent rBlocks, NVLink lane) before
        # falling back to its host tier
        self.peer_spill = peer_spill
        if peer_spill:
            for i, child in enumerate(self.children):
                if getattr(child, "prefix_cache", None) is None:
                    raise ValueError(
                        f"peer_spill needs a prefix cache on every child; "
                        f"instance {i} has none")
                if not child.prefix_cache.spill_budget:
                    raise ValueError(
                        f"peer_spill needs cache_spill_pages > 0 on every "
                        f"child (the budget bounds peer+host spilled pages);"
                        f" instance {i} has 0")
            self._wire_peer_spill()
        # disaggregated prefill/decode: park prefill-only schedulers in
        # prefill_only mode and stand up the KV handoff coordinator
        self.handoff = None
        self.handoff_zc_ok = False
        if self.disaggregated:
            if not self.prefill_capable:
                raise ValueError(
                    "role spec has no prefill-capable (prefill/mixed) "
                    "instance to place prompts on")
            if not self.decode_capable:
                raise ValueError(
                    "role spec has no decode-capable (decode/mixed) "
                    "instance to hand finished KV to")
            if handoff_mode not in HANDOFF_MODES:
                raise ValueError(
                    f"handoff_mode must be one of {HANDOFF_MODES}, "
                    f"got {handoff_mode!r}")
            kinds = {hasattr(c, "k_pages") for c in self.children}
            if len(kinds) > 1:
                raise ValueError(
                    "disaggregated roles need homogeneous children (all "
                    "engines or all sims): prompt KV cannot move between "
                    "a cost-model sim and a real engine")
            zc_capable = all(getattr(c, "_window", None) is None
                             for c in self.children)
            if handoff_mode == "zero_copy" and not zc_capable:
                raise ValueError(
                    "zero_copy handoff is unsupported with sliding-window "
                    "attention children (the remote partial ignores the "
                    "window) — use handoff_mode='migrate' or 'auto'")
            self.handoff_zc_ok = zc_capable
            if handoff_mode != "migrate" and zc_capable:
                self._wire_rmanagers()
            for i in self.prefill_only:
                self.children[i].scheduler.prefill_only = True
            self.handoff = KVHandoff(self, mode=handoff_mode,
                                     defer_cap=handoff_defer_cap)
        # telemetry: children constructed with tracing enabled each carry a
        # Tracer — assign them per-instance track ids, give the router its
        # own track (placement, board, network events) one past the last
        # child, and point each rManager/board at the right tracer. All
        # merged onto one timeline by trace_events().
        self.trace = None
        traced = [getattr(c, "trace", None) for c in self.children]
        if any(t is not None for t in traced):
            for i, t in enumerate(traced):
                if t is not None:
                    t.instance = i
            # with all-virtual children clock() is the cluster frontier;
            # with wall-clock children it is None — router events then sit
            # at t=0 unless stamped explicitly (add_request passes ts)
            self.trace = Tracer(
                clock=lambda: self.clock() or 0.0,
                instance=len(self.children))
            self.g.prefix_board.trace = self.trace
            for i, rm in self.rms.items():
                rm.trace = traced[i]
        self._heartbeat_all()

    def _wire_rmanagers(self) -> None:
        """Per-instance rManagers over the shared gManager (debt ledger)
        plus creditor pool readers on engine children — the substrate both
        zero-copy prefix serving and leased KV handoffs run on. Idempotent:
        prefix sharing and disaggregation may each ask for it."""
        if self.rms:
            return
        self.rms = {i: RManager(i, c.allocator, self.g)
                    for i, c in enumerate(self.children)}
        for rm in self.rms.values():
            rm.register_peers(self.rms)
        for child in self.children:
            if hasattr(child, "k_pages"):  # engine: needs creditor pools
                child.remote_reader = self._read_pools

    def _wire_zero_copy(self) -> None:
        """Borrowed-rBlock serving: rManagers (:meth:`_wire_rmanagers`),
        board pins so a home cannot free a published (lendable) page, and
        the schedulers' remote_adopter hooks."""
        self._wire_rmanagers()
        board = self.g.prefix_board
        board.on_pin = \
            lambda home, block: self.children[home].allocator.incref(block)
        board.on_unpin = \
            lambda home, block: self.children[home].allocator.decref(block)
        for i, child in enumerate(self.children):
            if self.roles[i] == "prefill":
                # a prefill-only child never decodes a leased prefix; an
                # admission lease here would have to chain through the KV
                # handoff — keep its prefix reuse on the copy/local paths
                continue
            child.scheduler.remote_adopter = self._make_remote_adopter(i)

    def _read_pools(self, home: int):
        c = self.children[home]
        return c.k_pages, c.v_pages

    def _wire_peer_spill(self) -> None:
        """Attach the radix peer-spill hooks on every child: spill-out
        lends one block from the neighbor with the most free device memory
        (``RManager.try_lend``, debt in the gManager ledger) and ships the
        payload over the NVLink lane; restore copies it back onto a fresh
        local block and repays the loan. Payload copies are getattr-guarded
        so cost-model sims ride the same wiring bookkeeping-only."""
        self._wire_rmanagers()
        for i, child in enumerate(self.children):
            pc = child.prefix_cache
            pc.peer_spill_fn = self._make_peer_spiller(i)
            pc.peer_restore_fn = self._make_peer_restorer(i)
            pc.peer_drop_fn = self._make_peer_dropper(i)

    def _kv_page_bytes(self, i: int):
        """True bytes per KV page on child ``i`` (from its allocator's
        KVPageLayout), or None to fall back on the NetworkModel's default —
        compressed layouts (MLA latent pages) move ~10x fewer bytes than
        the GQA default would charge."""
        return getattr(getattr(self.children[i], "allocator", None),
                       "page_bytes", None)

    def _net_bytes(self, i: int, n_pages: int) -> int:
        pb = self._kv_page_bytes(i)
        return n_pages * (pb if pb is not None else self.net.page_bytes)

    def _charge_peer_copy(self, i: int, n_pages: int) -> None:
        if self.net is None:
            return
        charge = getattr(self.children[i], "charge_network", None)
        if charge is not None:
            charge(self.net.peer_copy_time(
                n_pages, page_bytes=self._kv_page_bytes(i)))

    def _make_peer_spiller(self, i: int):
        child = self.children[i]
        child_is_engine = hasattr(child, "k_pages")

        def spill(dev_block: int):
            # neighbor with the most free device pages (same backend kind:
            # a payload cannot move between a cost-model sim and an engine)
            best, best_free = None, -1
            for j, peer in enumerate(self.children):
                if j == i or hasattr(peer, "k_pages") != child_is_engine:
                    continue
                free = peer.allocator.num_free
                if free > self.g.safety_free and free > best_free:
                    best, best_free = j, free
            if best is None:
                return None
            blk = self.rms[best].try_lend(debtor=i)
            if blk is None:
                return None
            if child_is_engine:
                # copy while the source device page is still allocated
                self.children[best].import_page_payloads(
                    [blk], [child.export_page_payload(dev_block)])
            self._charge_peer_copy(i, 1)
            if self.trace is not None:
                self.trace.instant("net", "peer_spill", src=i, home=best,
                                   pages=1)
            return best, blk

        return spill

    def _make_peer_restorer(self, i: int):
        child = self.children[i]

        def restore(home: int, peer_block: int, dev_block: int) -> None:
            exp = getattr(self.children[home], "export_page_payload", None)
            write = getattr(child, "import_page_payloads", None)
            if exp is not None and write is not None:
                write([dev_block], [exp(peer_block)])
            self.rms[i].repay(home, peer_block)
            self._charge_peer_copy(i, 1)
            if self.trace is not None:
                self.trace.instant("net", "peer_restore", dst=i, home=home,
                                   pages=1)

        return restore

    def _make_peer_dropper(self, i: int):
        def drop(home: int, peer_block: int) -> None:
            # the spilled copy dies unread: repay the loan, no payload moves
            self.rms[i].repay(home, peer_block)

        return drop

    # -- distkv wiring ---------------------------------------------------------

    def _heartbeat_all(self) -> None:
        for i, child in enumerate(self.children):
            self.g.heartbeat(Heartbeat(i, child.allocator.num_free,
                                       child.allocator.num_blocks))

    def _export_payload(self, child, block):
        fn = getattr(child, "export_page_payload", None)
        return fn(block) if fn is not None else None

    def _publish_hot(self, i: int) -> None:
        """Export any radix path on instance ``i`` that just crossed the hit
        threshold to the cluster board (token keys + page payloads). Pages
        the board already holds are not re-exported — payload export is a
        device->host page copy on engine children. Under zero-copy serving
        no payload is exported at all (the whole point); the physical block
        ids are published instead so peers can borrow the pages in place
        (auto publishes both, since either path may win)."""
        child = self.children[i]
        pc = child.prefix_cache
        board = self.g.prefix_board
        lend = self.share_mode != "copy"
        for tokens, blocks in pc.take_hot_paths(self.hot_threshold):
            if self.share_mode == "zero_copy":
                payloads = [None] * len(blocks)
            else:
                have = board.covered(tokens)
                payloads = [None] * have + \
                    [self._export_payload(child, b) for b in blocks[have:]]
            layout = getattr(child, "kv_layout", None)
            board.publish(i, tokens, payloads, pc.page_size,
                          blocks=blocks if lend else None,
                          schema=layout.schema if layout is not None
                          else None)

    def _make_importer(self, i: int):
        """The child scheduler's adopt-imported-pages hook: given a prompt
        and the tokens already matched locally, adopt the longest published
        extension into instance ``i``'s own radix tree."""
        child = self.children[i]

        def importer(prompt: Sequence[int], local_tokens: int) -> int:
            pc = child.prefix_cache
            pages = self.g.prefix_board.match(prompt,
                                              max_tokens=len(prompt) - 1)
            write = getattr(child, "import_page_payloads", None)
            if write is not None:
                # a real engine can only adopt pages whose KV contents were
                # published (a cost-model sim publishes payload=None — its
                # pages are bookkeeping-only and unusable here). Keep the
                # longest payload-backed prefix.
                n_ok = 0
                for page in pages:
                    if page.payload is None:
                        break
                    n_ok += 1
                pages = pages[:n_ok]
            if len(pages) * pc.page_size <= local_tokens:
                return 0  # the local tree already matches at least as far
            tokens = [t for page in pages for t in page.key]
            adopted = pc.adopt(tokens)
            if write is not None and adopted:
                write([b for _, b in adopted],
                      [pages[idx].payload for idx, _ in adopted])
            if adopted:
                if self.net is not None:
                    # payload transfer is not free: serialization + wire
                    # time per copied page (virtual children advance their
                    # clock, engines record net_time)
                    charge = getattr(child, "charge_network", None)
                    if charge is not None:
                        charge(self.net.page_copy_time(
                            len(adopted),
                            page_bytes=self._kv_page_bytes(i)))
                    m = getattr(child, "metrics", None)
                    if m is not None:
                        m.count("net_bytes",
                                self._net_bytes(i, len(adopted)))
                if self.trace is not None:
                    self.trace.instant(
                        "net", "copy", dst=i, pages=len(adopted),
                        bytes=self._net_bytes(i, len(adopted))
                        if self.net is not None else 0)
            return len(adopted)

        return importer

    def _make_remote_adopter(self, i: int):
        """The child scheduler's zero-copy hook: offer a
        :class:`~repro.core.distkv.rmanager.RemoteLease` on the longest
        published single-home page chain that (a) strictly extends the
        local match, (b) has lendable block ids, and (c) the child can
        actually read (an engine needs an engine creditor's pools; a
        cost-model sim borrows from anyone — bookkeeping only). In ``auto``
        mode the NetworkModel decides borrow-vs-copy per request; declining
        here lets the copy importer run instead."""
        child = self.children[i]
        child_is_engine = hasattr(child, "k_pages")

        def adopter(req: Request, local_tokens: int):
            pc = child.prefix_cache
            pages = self.g.prefix_board.match(req.prompt,
                                              max_tokens=req.prompt_len - 1)
            usable, home = [], None
            for page in pages:
                if page.block is None:
                    break
                if home is None:
                    home = page.home
                elif page.home != home:
                    break  # one creditor per lease (one partial merge)
                usable.append(page)
            if home is None or home == i:
                return None  # nothing lendable / it lives here already
            if child_is_engine and \
                    not hasattr(self.children[home], "k_pages"):
                return None  # a sim home has no KV an engine could read
            if len(usable) * pc.page_size <= local_tokens:
                return None  # the local tree already matches at least as far
            board = self.g.prefix_board
            prior = board.lease_hits_of(i, usable)
            if self.promote_after is not None \
                    and prior >= self.promote_after \
                    and self._promote_to_copy(i, home, usable):
                return None  # prefix now lives here — serve it locally
            if self.share_mode == "auto" and not self.net.prefer_borrow(
                    len(usable), pc.page_size, req.max_new_tokens,
                    expected_reuse=prior + 1,
                    page_bytes=self._kv_page_bytes(i)):
                # copying pays off — let the importer run. The board's
                # (instance, prefix) lease hit-count is the reuse estimate:
                # the copy is paid once but amortized over the repeats this
                # prefix has already demonstrated on this instance.
                return None
            try:
                lease = self.rms[i].borrow_blocks(
                    home, [p.block for p in usable])
            except ValueError:
                return None  # stale board entry: fall back to copy/compute

            def on_commit(l):
                # fired only when an admission actually lands with the
                # lease — a failed admission releases it and must neither
                # inflate the stats nor re-charge the RPC on every retry
                self.leases_granted += 1
                self.pages_borrowed += l.num_pages
                board.record_lease(i, usable)
                if self.net is not None:
                    charge = getattr(child, "charge_network", None)
                    if charge is not None:
                        charge(self.net.lease_time(l.num_pages))
                m = getattr(child, "metrics", None)
                if m is not None:
                    m.count("borrowed_pages", l.num_pages)
                if self.trace is not None:
                    self.trace.instant("net", "lease",
                                       rid=req.request_id, debtor=i,
                                       home=l.home, pages=l.num_pages)

            lease._on_commit = on_commit
            return lease

        return adopter

    def _promote_to_copy(self, i: int, home: int, pages) -> int:
        """Promote a repeatedly-leased remote prefix to a local copy: adopt
        the chain into instance ``i``'s radix tree and fill the fresh blocks
        straight from the creditor's physical pages (board payloads are None
        under ``zero_copy`` publishing — the pages themselves are pinned, so
        they are the source of truth). One payload transfer ends the
        pay-the-merge-forever pathology; outstanding leases drain as their
        requests finish, and future admissions hit the local tree. Returns
        #pages materialized (0 = could not promote, fall back to leasing)."""
        child = self.children[i]
        home_child = self.children[home]
        write = getattr(child, "import_page_payloads", None)
        exp = getattr(home_child, "export_page_payload", None)
        if write is not None and exp is None:
            return 0  # an engine cannot materialize from a sim creditor
        pc = child.prefix_cache
        tokens = [t for page in pages for t in page.key]
        adopted = pc.adopt(tokens)
        if not adopted:
            return 0
        if write is not None:
            write([b for _, b in adopted],
                  [exp(pages[idx].block) for idx, _ in adopted])
        if self.net is not None:
            charge = getattr(child, "charge_network", None)
            if charge is not None:
                charge(self.net.page_copy_time(
                    len(adopted), page_bytes=self._kv_page_bytes(i)))
            m = getattr(child, "metrics", None)
            if m is not None:
                m.count("net_bytes", self._net_bytes(i, len(adopted)))
        self.promotions += 1
        if self.trace is not None:
            self.trace.instant("net", "promote", dst=i, home=home,
                               pages=len(adopted))
        return len(adopted)

    # -- placement -------------------------------------------------------------

    def place(self, req: Request) -> int:
        """Pick an instance for ``req`` (exposed for tests/benchmarks).
        With roles active only prefill-capable instances are candidates —
        decode-only instances receive work through the KV handoff, never
        from the front door."""
        cand = self.prefill_capable
        if len(cand) == len(self.children):
            return self.policy.choose(req, self.children)
        sub = [self.children[i] for i in cand]
        return cand[self.policy.choose(req, sub)]

    def add_request(self, req: Request) -> None:
        if req.parent_id is not None and req.parent_id in self._placement:
            # best-of-n sibling: co-locate with the parent so the child can
            # COW-fork the parent's prefill instead of prefilling again
            i = self._placement[req.parent_id]
        else:
            i = self.place(req)
        req.instance_id = i
        self._placement[req.request_id] = i
        self.requests_placed[i] += 1
        child = self.children[i]
        clk = child.clock()
        if clk is not None and clk < req.arrival_time:
            # virtual child idle in the past: it cannot serve a request
            # before the request exists
            child.advance_to(req.arrival_time)
        if self.trace is not None:
            clk = child.clock()
            self.trace.instant(
                "router", "place", rid=req.request_id,
                ts=clk if clk is not None else req.arrival_time,
                instance=i,
                policy=getattr(self.policy, "name",
                               type(self.policy).__name__))
        child.add_request(req)

    # -- ServingBackend protocol -------------------------------------------------

    @property
    def has_work(self) -> bool:
        return any(c.has_work for c in self.children)

    def clock(self) -> Optional[float]:
        if not self._virtual:
            return None
        busy = [c.clock() for c in self.children if c.has_work]
        if busy:
            return min(busy)
        return max(c.clock() for c in self.children)

    def advance_to(self, t: float) -> None:
        for c in self.children:
            if c.clock() is not None:
                c.advance_to(t)

    @property
    def iterations(self) -> int:
        return sum(getattr(c, "iterations", 0) for c in self.children)

    @property
    def preemptions(self) -> int:
        return sum(getattr(c, "preemptions", 0) for c in self.children)

    def step(self, now: Optional[float] = None) -> List[Request]:
        finished: List[Request] = []
        if self.handoff is not None:
            # prefill->decode handoffs drain before children step: a fully
            # parked prefill instance makes no progress of its own, so an
            # after-step hook would never see it
            self.handoff.drain()
        if self._virtual:
            # event-driven: advance the laggard instance that can actually
            # make progress (a stuck instance — e.g. a prompt that can never
            # fit — must not starve the others)
            order = sorted((i for i, c in enumerate(self.children)
                            if c.has_work),
                           key=lambda i: self.children[i].clock())
            for i in order:
                child = self.children[i]
                before = getattr(child, "iterations", None)
                got = child.step(now)
                finished.extend(got)
                if got or before is None or \
                        getattr(child, "iterations", None) != before:
                    self._after_step(i)
                    break
        else:
            for i, child in enumerate(self.children):
                if child.has_work:
                    finished.extend(child.step(now))
                    self._after_step(i)
        return finished

    def _after_step(self, i: int) -> None:
        if not self.prefix_share:
            return
        hits = self.children[i].prefix_cache.hit_tokens
        if hits != self._last_hits[i]:
            # only a committed admission hit can push a node over the hot
            # threshold — skip the tree walk (and gManager heartbeats) on
            # the vast majority of steps where nothing changed
            self._last_hits[i] = hits
            self._publish_hot(i)
            self._heartbeat_all()

    # -- aggregate stats ---------------------------------------------------------

    @property
    def prefix_cache(self) -> Optional[_AggregateCacheStats]:
        agg = _AggregateCacheStats()
        seen = False
        for c in self.children:
            pc = getattr(c, "prefix_cache", None)
            if pc is None:
                continue
            seen = True
            agg.hit_tokens += pc.hit_tokens
            agg.lookup_tokens += pc.lookup_tokens
            agg.num_pages += pc.num_pages
            agg.adopted_pages += pc.adopted_pages
        return agg if seen else None

    def trace_events(self):
        """All child tracers' events plus the router's own (placement,
        board, network) merged onto one timestamp-ordered timeline."""
        return merge_events(
            [getattr(c, "trace", None) for c in self.children] +
            [self.trace])

    def metrics_timelines(self) -> Dict[int, List[Dict]]:
        """Per-instance metric timelines (instance -> per-iteration rows)
        for traced children. With roles active each row is tagged with its
        instance's role, so one CSV export separates prefill iterations
        from decode iterations."""
        out: Dict[int, List[Dict]] = {}
        for i, c in enumerate(self.children):
            m = getattr(c, "metrics", None)
            if m is not None:
                rows = m.rows()
                if self.disaggregated:
                    rows = [dict(row, role=self.roles[i]) for row in rows]
                out[i] = rows
        return out

    def role_timelines(self) -> Dict[str, List[Dict]]:
        """Per-role metric split: every traced child's rows tagged with
        their instance and merged time-ordered under the instance's role.
        Under disaggregation the two shapes are the whole story — prefill
        tracks show budget-sized chunk iterations, decode tracks show small
        pure-decode iterations."""
        out: Dict[str, List[Dict]] = {}
        for i, rows in self.metrics_timelines().items():
            out.setdefault(self.roles[i], []).extend(
                dict(row, instance=i) for row in rows)
        for rows in out.values():
            rows.sort(key=lambda row: row.get("ts", 0.0))
        return out

    def instance_stats(self) -> Dict[int, Dict[str, float]]:
        """Per-instance breakdown for ``LLMService.stats``."""
        out = {}
        for i, c in enumerate(self.children):
            row = {
                "requests": self.requests_placed[i],
                "iterations": getattr(c, "iterations", 0),
                "preemptions": getattr(c, "preemptions", 0),
                "waiting": len(c.scheduler.waiting),
                "running": len(c.scheduler.running),
                "free_pages": c.allocator.num_free,
            }
            if self.disaggregated:
                row["role"] = self.roles[i]
            pc = getattr(c, "prefix_cache", None)
            if pc is not None:
                row["prefix_hit_rate"] = pc.hit_rate
                row["cached_pages"] = pc.num_pages
                row["adopted_pages"] = pc.adopted_pages
                if self.peer_spill:
                    row["peer_spilled_pages"] = pc.peer_spilled_pages
                    row["peer_restored_pages"] = pc.peer_restored_pages
            if self.share_mode != "copy":
                # outstanding rBlock debt from the gManager ledger
                row["lent_pages"] = self.g.lent_by(i)
                row["borrowed_pages"] = self.g.borrowed_by(i)
            out[i] = row
        return out
