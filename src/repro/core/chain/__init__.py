from repro.core.chain.registry import Fleet, ServerInfo, make_fleet  # noqa: F401
from repro.core.chain.baseline import Chain, find_best_chain  # noqa: F401
from repro.core.chain.nsga2 import nsga2, hypervolume_2d  # noqa: F401
from repro.core.chain.tradeoff import (  # noqa: F401
    ChainSequenceProblem, latency_throughput_tradeoff, decode_chain,
    knee_chain)
