"""Mamba2 block via SSD (state-space duality) [arXiv:2405.21060].

Chunked SSD: within-chunk terms are computed as masked attention-like
einsums; across chunks the state recurrence runs as an associative scan —
both XLA-native so the dry-run roofline sees true costs. Decode is the O(1)
recurrent update on a persistent ``(B, H, P, N)`` state plus a rolling
depthwise-conv buffer.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import NO_POLICY, ShardingPolicy, dense, dense_init


class SSMCache(NamedTuple):
    conv: jax.Array  # (B, W-1, conv_dim) rolling input window
    state: jax.Array  # (B, H, P, N) SSD state (fp32)


def ssm_init(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    din = cfg.ssm_d_inner
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    conv_dim = din + 2 * g * n
    return {
        # projects to [z (din), xBC (din + 2*g*n), dt (h)]
        "in_proj": dense_init(ks[0], d, 2 * din + 2 * g * n + h, dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, float(h), h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[2], din, d, dtype),
    }


def _split_proj(cfg, proj):
    din = cfg.ssm_d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    z = proj[..., :din]
    xbc = proj[..., din:din + din + 2 * gn]
    dt = proj[..., -cfg.ssm_heads:]
    return z, xbc, dt


def _causal_conv(cfg, p, xbc):
    """Depthwise causal conv, width W: (B, S, C) -> (B, S, C)."""
    w = cfg.ssm_conv_width
    pads = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + xbc.shape[1]] * p["conv_w"][i] for i in range(w))
    return jax.nn.silu(out + p["conv_b"])


def _segsum(a):
    """a: (..., L) -> (..., L, L) lower-triangular cumulative sums:
    out[i, j] = sum(a[j+1..i]) for j < i; -inf above the diagonal."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD scan. x: (b,l,h,p), dt: (b,l,h) fp32 post-softplus, A: (h,)<0,
    B,C: (b,l,g,n). Returns y: (b,l,h,p) and final state (b,h,p,n)."""
    b, l, h, pdim = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    nc = l // chunk
    rep = h // g

    # fold dt into x (the "discretized input")
    xdt = (x.astype(jnp.float32) * dt[..., None]).astype(jnp.float32)
    a = (dt * A).astype(jnp.float32)  # (b,l,h)

    def ch(t, lastdims):  # (b, l, ...) -> (b, nc, chunk, ...)
        return t.reshape((b, nc, chunk) + lastdims)

    xc = ch(xdt, (h, pdim))
    ac = ch(a, (h,))
    Bc = ch(B.astype(jnp.float32), (g, n))
    Cc = ch(C.astype(jnp.float32), (g, n))
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,nc,chunk,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    a_cum = jnp.cumsum(ac, axis=2)  # (b,nc,chunk,h)

    # 1) intra-chunk (quadratic within chunk)
    Lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (b,nc,h,chunk,chunk)
    scores = jnp.einsum("bzihn,bzjhn->bzhij", Ch, Bh) * Lmat
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", scores, xc)

    # 2) per-chunk outgoing state: sum_j decay(end-j) * B_j x_j^T
    decay_out = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b,nc,chunk,h)
    states = jnp.einsum("bzlh,bzlhn,bzlhp->bzhpn", decay_out, Bh, xc)

    # 3) inter-chunk recurrence: S_z = S_{z-1} * exp(sum a_z) + states_z
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b,nc,h)

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s2 + d2[..., None, None] * s1

    dec, st = lax.associative_scan(
        combine, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    st = st.transpose(1, 0, 2, 3, 4)  # inclusive: state at END of each chunk
    final_state = st[:, -1]
    # state entering each chunk = inclusive scan shifted right by one
    st_in = jnp.concatenate([jnp.zeros_like(st[:, :1]), st[:, :-1]], axis=1)

    # 4) inter-chunk contribution: C_i * decay(i) * S_in
    decay_in = jnp.exp(a_cum)  # (b,nc,chunk,h)
    y_off = jnp.einsum("bzlhn,bzlh,bzhpn->bzlhp", Ch, decay_in, st_in)

    y = (y_diag + y_off).reshape(b, l, h, pdim)
    return y, final_state


def ssm_forward(cfg, p, x, *, policy: ShardingPolicy = NO_POLICY,
                return_cache: bool = False):
    """Full-sequence Mamba2 block. x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    din = cfg.ssm_d_inner
    proj = dense(p["in_proj"], x)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(cfg, p, xbc)
    xin = xbc[..., :din].reshape(b, s, h, pdim)
    Bmat = xbc[..., din:din + g * n].reshape(b, s, g, n)
    Cmat = xbc[..., din + g * n:].reshape(b, s, g, n)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    xin = policy.act(xin, "ssm_bshp")
    chunk = min(cfg.ssm_chunk, s)
    y, final_state = ssd_chunked(xin, dtv, A, Bmat, Cmat, chunk)
    y = y + xin.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, din).astype(x.dtype)

    # gated RMSNorm (mamba2 norm-before-gate)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + cfg.norm_eps)
    y = (yf.astype(x.dtype)) * p["norm_scale"]
    out = dense(p["out_proj"], y, policy, "act_bsd")
    if return_cache:
        w = cfg.ssm_conv_width
        conv_tail_src = _split_proj(cfg, proj)[1]  # pre-conv xBC
        pad = max(w - 1 - s, 0)
        tail = jnp.pad(conv_tail_src, ((0, 0), (pad, 0), (0, 0)))[:, -(w - 1):]
        return out, SSMCache(conv=tail, state=final_state)
    return out


def ssm_decode(cfg, p, x, cache: SSMCache, *,
               policy: ShardingPolicy = NO_POLICY):
    """One-token recurrent update. x: (B,1,D)."""
    b = x.shape[0]
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    din = cfg.ssm_d_inner
    w = cfg.ssm_conv_width
    proj = dense(p["in_proj"], x)  # (B,1,*)
    z, xbc_new, dt = _split_proj(cfg, proj)

    # rolling conv buffer: window = [cache.conv, xbc_new]
    win = jnp.concatenate([cache.conv, xbc_new], axis=1)  # (B, W, C)
    conv_out = jax.nn.silu((win * p["conv_w"][None]).sum(1) + p["conv_b"])  # (B, C)
    new_conv = win[:, 1:]

    xin = conv_out[:, :din].reshape(b, h, pdim)
    Bmat = conv_out[:, din:din + g * n].reshape(b, g, n)
    Cmat = conv_out[:, din + g * n:].reshape(b, g, n)
    rep = h // g
    Bh = jnp.repeat(Bmat, rep, axis=1)  # (b,h,n)
    Ch = jnp.repeat(Cmat, rep, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])

    decay = jnp.exp(dtv * A)  # (b,h)
    state = cache.state * decay[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xin.astype(jnp.float32) * dtv[..., None],
        Bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + xin.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, 1, din)

    yf = y * jax.nn.silu(z.astype(jnp.float32))
    yf = yf * lax.rsqrt(jnp.mean(jnp.square(yf), -1, keepdims=True) + cfg.norm_eps)
    y = yf.astype(x.dtype) * p["norm_scale"]
    out = dense(p["out_proj"], y, policy, "act_bsd")
    return out, SSMCache(conv=new_conv, state=state)
