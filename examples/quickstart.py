"""Quickstart: train a small model on the synthetic corpus, checkpoint it,
and serve a few requests through the continuous-batching engine.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.scheduling.request import Request
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine
from repro.training import checkpoint
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, train


def main():
    cfg = smoke_config("h2o-danube-1.8b")

    print("== training 120 steps on the synthetic corpus ==")
    res = train(cfg, TrainConfig(
        steps=120, log_every=30,
        opt=OptConfig(lr=1e-3, warmup_steps=15, total_steps=120)))
    first, last = res["losses"][0][1], res["losses"][-1][1]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.3, "model failed to learn"

    path = checkpoint.save("/tmp/quickstart_ckpt", 120,
                           {"params": res["params"]})
    print(f"checkpoint written to {path}")

    print("\n== serving the trained model (continuous batching) ==")
    model = Model(cfg, remat=False)
    restored = checkpoint.restore("/tmp/quickstart_ckpt", 120,
                                  {"params": res["params"]})
    eng = PagedEngine(cfg, restored["params"],
                      EngineConfig(num_pages=128, page_size=8, max_slots=4))
    rng = np.random.default_rng(0)
    reqs = [Request(i, 0.0,
                    rng.integers(2, cfg.vocab_size, 8).tolist(),
                    max_new_tokens=8) for i in range(4)]
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion()
    for r in reqs:
        print(f"req {r.request_id}: prompt={r.prompt[:4]}... -> "
              f"{r.full_output}")
    print(f"kv pages free: {eng.allocator.num_free}/{eng.allocator.num_blocks}")


if __name__ == "__main__":
    main()
