from repro.core.paging.allocator import (  # noqa: F401
    BlockAllocator, BlockTable, ContiguousPreallocAllocator, OutOfBlocks,
    OutOfHostBlocks)
from repro.core.paging.layout import (  # noqa: F401
    KVPageLayout, PoolSpec, check_schema)
