"""Pure-JAX building blocks shared by every architecture.

No flax: parameters are explicit nested-dict pytrees built by ``init_*``
functions and consumed by pure ``apply``-style functions. Every function takes
an optional :class:`ShardingPolicy` that inserts ``with_sharding_constraint``
annotations — models stay mesh-agnostic, the launcher supplies the policy.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


class ShardingPolicy:
    """Identity policy — no constraints. Launch code subclasses this."""

    def act(self, x, kind: str):
        """Constrain an activation. ``kind`` names the logical layout:

        tokens_bs, act_bsd, heads_bshd, ffn_bsf, logits_bsv, kv_bskd,
        expert_ecd, expert_ecf, state_bhpn
        """
        return x

    def param(self, x, kind: str):
        return x


NO_POLICY = ShardingPolicy()


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x, policy: ShardingPolicy = NO_POLICY, kind: Optional[str] = None):
    w = policy.param(p["w"], "matmul_weight")
    y = x @ w
    if "b" in p:
        y = y + p["b"]
    if kind is not None:
        y = policy.act(y, kind)
    return y


def norm_init(d: int, dtype, *, bias: bool = False):
    p = {"scale": jnp.ones((d,), dtype)}
    if bias:
        p["b"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    y = x.astype(dt) * p["scale"]
    if "b" in p:
        y = y + p["b"]
    return y


def pad_last(x, target: int):
    """Zero-pad the trailing axis of ``x`` up to ``target`` elements.

    Used by the absorbed-MLA path to lift latent values to the effective
    key width (attention output is linear in v, so zero rows are inert)."""
    pad = target - x.shape[-1]
    if pad <= 0:
        return x
    return jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float, *, heads: bool = True):
    """x: (..., S, H, D) if ``heads`` else (..., S, D).

    ``positions``: (S,) shared across batch, or batched (..., S).
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    if heads:
        ang = ang[..., None, :]  # broadcast over the heads axis
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, dtype, *, gated: bool, bias: bool = False):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d, ff, dtype, bias=bias),
         "down": dense_init(ks[1], ff, d, dtype, bias=bias)}
    if gated:
        p["gate"] = dense_init(ks[2], d, ff, dtype, bias=bias)
    return p


def mlp(p, x, policy: ShardingPolicy = NO_POLICY):
    up = dense(p["up"], x, policy, "ffn_bsf")
    if "gate" in p:
        h = jax.nn.silu(dense(p["gate"], x, policy, "ffn_bsf")) * up
    else:
        h = jax.nn.gelu(up)
    return dense(p["down"], h, policy, "act_bsd")


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def pad_vocab(vocab: int, multiple: int = 512) -> int:
    """Megatron-style vocab padding so the vocab axis shards cleanly."""
    return ((vocab + multiple - 1) // multiple) * multiple


def embed_init(key, vocab: int, d: int, dtype):
    v = pad_vocab(vocab)
    return {"table": jax.random.normal(key, (v, d), dtype) * 0.02}


def embed(p, tokens, policy: ShardingPolicy = NO_POLICY):
    return policy.act(jnp.take(p["table"], tokens, axis=0), "act_bsd")


def unembed(p, x, vocab: int, policy: ShardingPolicy = NO_POLICY,
            fp32: bool = False):
    """Project hidden states to vocab logits. ``fp32`` computes the
    projection in float32: bf16 logits round near-equal candidates onto the
    same value, so greedy argmax between two implementations can diverge on
    the tie-break even when both are correct (ArchConfig.logits_fp32)."""
    if fp32:
        logits = x.astype(jnp.float32) @ p["table"].T.astype(jnp.float32)
    else:
        logits = x @ p["table"].T
    logits = policy.act(logits, "logits_bsv")
    # mask padded vocab entries so they never win a softmax/argmax
    v_pad = p["table"].shape[0]
    if v_pad != vocab:
        mask = jnp.arange(v_pad) < vocab
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def cross_entropy(logits, labels, vocab: int):
    """Mean token loss in fp32; labels < 0 are masked out.

    The gold logit is extracted with a masked sum over the vocab axis rather
    than ``take_along_axis`` — a gather along a *sharded* vocab dimension
    would force GSPMD to all-gather the full logits; the masked sum reduces
    locally and psums (Megatron-style vocab-parallel loss)."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    labels_c = jnp.clip(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels_c[..., None], logits, 0.0),
                   axis=-1)
    loss = (lse - gold) * valid
    return loss.sum() / jnp.maximum(valid.sum(), 1)
