"""Bench-regression guard: fresh BENCH_<slug>.json vs committed baseline.

CI copies the checkout's committed ``bench_out/BENCH_<slug>.json`` aside
BEFORE ``benchmarks/run.py`` overwrites the directory, then calls this tool
to compare the fresh artifact against it. The comparison dispatches on the
artifact's ``name`` field; two sweeps are guarded:

``swap_sweep``
  * **Tolerance band** — every metric key present in BOTH artifacts must
    not regress by more than ``--tolerance`` (relative): throughputs may
    not drop, P99 normalized latencies may not rise. The sim is
    virtual-clock deterministic, so the band only absorbs intentional
    model recalibration; improvements always pass.
  * **Overlap headline** — the long-point ``swap-overlap-cost`` row
    (overlapped PCIe transfers + cost-ranked victims) must beat the
    baseline's serial ``swap`` row: ≥ +5% throughput, OR lower P99
    normalized latency at equal-or-better throughput.

``mla_sweep``
  * **Tolerance band** — per-layout throughput may not drop, P99 may not
    rise, beyond ``--tolerance``.
  * **Latent headline** — the fresh run's latent layout must hold ≥ 5x
    fewer KV bytes/token than GQA (it is ~57x on the deepseek-v2-236b
    geometry) AND beat the GQA run's throughput at the long-context
    point. This is the PR acceptance criterion, kept green forever after.

    python tools/check_bench_regression.py BASELINE FRESH [--tolerance 0.02]

Exit status is non-zero on any regression; every comparison is printed.
"""

from __future__ import annotations

import argparse
import json
import sys

HEADLINE_GAIN = 1.05   # +5% throughput branch of the swap headline check
MLA_MIN_RATIO = 5.0    # latent layouts must compress at least this much


def _load(path):
    with open(path) as f:
        data = json.load(f)
    return data.get("name", ""), data["metrics"]


def _band(base, fresh, group, higher_is_better, tolerance, problems):
    b, f = base.get(group) or {}, fresh.get(group) or {}
    for key in sorted(set(b) & set(f)):
        bv, fv = b[key], f[key]
        if bv <= 0:
            continue
        rel = fv / bv - 1.0
        bad = rel < -tolerance if higher_is_better else rel > tolerance
        arrow = "REGRESSION" if bad else "ok"
        print(f"  {group}[{key}]: {bv:.6g} -> {fv:.6g} "
              f"({rel:+.2%}) {arrow}")
        if bad:
            problems.append(f"{group}[{key}] regressed {rel:+.2%} "
                            f"(tolerance {tolerance:.0%})")


def compare_swap(base: dict, fresh: dict, tolerance: float) -> list:
    """swap_sweep: tolerance bands + the overlap headline."""
    problems = []
    _band(base, fresh, "long_throughput", True, tolerance, problems)
    _band(base, fresh, "short_throughput", True, tolerance, problems)
    _band(base, fresh, "long_p99_norm_lat", False, tolerance, problems)

    if not fresh.get("reprefill_ok", False):
        problems.append("no-re-prefill proof failed in the fresh run")

    # overlap headline: fresh overlap+cost vs the baseline serial swap row
    base_thr = (base.get("long_throughput") or {}).get("swap")
    base_p99 = (base.get("long_p99_norm_lat") or {}).get("swap")
    ovl_thr = (fresh.get("long_throughput") or {}).get("swap-overlap-cost")
    ovl_p99 = (fresh.get("long_p99_norm_lat") or {}).get("swap-overlap-cost")
    if None in (base_thr, base_p99, ovl_thr, ovl_p99):
        problems.append("headline rows missing: need baseline long swap and "
                        "fresh long swap-overlap-cost metrics")
    else:
        gain = ovl_thr / base_thr
        print(f"  headline: overlap+cost {ovl_thr:.2f} tok/s vs baseline "
              f"swap {base_thr:.2f} ({gain - 1:+.2%}), "
              f"p99 {ovl_p99 * 1e3:.2f} vs {base_p99 * 1e3:.2f} ms/tok")
        if not (gain >= HEADLINE_GAIN
                or (gain >= 1.0 and ovl_p99 < base_p99)):
            problems.append(
                f"overlap+cost headline does not beat the baseline swap "
                f"row: thr {gain - 1:+.2%} (needs >= +{HEADLINE_GAIN - 1:.0%}"
                f") and p99 {ovl_p99:.6g} vs {base_p99:.6g} "
                f"(needs lower at equal-or-better throughput)")
    return problems


def compare_mla(base: dict, fresh: dict, tolerance: float) -> list:
    """mla_sweep: per-layout tolerance bands + the latent headline."""
    problems = []
    _band(base, fresh, "throughput", True, tolerance, problems)
    _band(base, fresh, "p99_norm_lat", False, tolerance, problems)

    ratio = fresh.get("compression_ratio") or 0.0
    print(f"  compression_ratio: {ratio:.1f}x (needs >= {MLA_MIN_RATIO:g}x)")
    if ratio < MLA_MIN_RATIO:
        problems.append(f"latent compression ratio {ratio:.2f}x is below "
                        f"the {MLA_MIN_RATIO:g}x acceptance floor")

    thr = fresh.get("throughput") or {}
    gqa_thr, mla_thr = thr.get("gqa"), thr.get("mla")
    done = fresh.get("completed") or {}
    if None in (gqa_thr, mla_thr):
        problems.append("headline rows missing: need fresh gqa and mla "
                        "throughput metrics")
    else:
        print(f"  headline: mla {mla_thr:.2f} tok/s vs gqa {gqa_thr:.2f} "
              f"({mla_thr / max(gqa_thr, 1e-9) - 1:+.2%})")
        if not mla_thr > gqa_thr:
            problems.append("latent layout does not beat GQA throughput at "
                            "the long-context point")
        if done.get("mla", 0) < done.get("gqa", 0):
            problems.append("latent run completed fewer requests than GQA")
    return problems


COMPARATORS = {"swap_sweep": compare_swap, "mla_sweep": compare_mla}


def main() -> None:
    ap = argparse.ArgumentParser(
        description="compare a fresh BENCH_<slug>.json to its baseline")
    ap.add_argument("baseline", help="committed baseline artifact")
    ap.add_argument("fresh", help="freshly produced artifact")
    ap.add_argument("--tolerance", type=float, default=0.02, metavar="FRAC",
                    help="relative regression band (default 0.02)")
    args = ap.parse_args()
    base_name, base = _load(args.baseline)
    fresh_name, fresh = _load(args.fresh)
    name = fresh_name or base_name
    if name not in COMPARATORS:
        print(f"no comparator for artifact {name!r} "
              f"(known: {sorted(COMPARATORS)})", file=sys.stderr)
        raise SystemExit(2)
    if base_name and fresh_name and base_name != fresh_name:
        print(f"artifact mismatch: baseline is {base_name!r}, fresh is "
              f"{fresh_name!r}", file=sys.stderr)
        raise SystemExit(2)
    print(f"comparing {args.fresh} against {args.baseline} "
          f"(tolerance {args.tolerance:.0%})")
    problems = COMPARATORS[name](base, fresh, args.tolerance)
    if problems:
        print("\nbench regressions:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        raise SystemExit(1)
    print("bench regression guard: ok")


if __name__ == "__main__":
    main()
