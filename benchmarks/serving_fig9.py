"""Paper Fig. 9: vLLM (paged) vs Orca (Oracle/Pow2/Max) — normalized latency
vs request rate, ShareGPT- and Alpaca-like workloads, OPT-13B cost model.

The paged system runs through the LLMService front-end over a SimBackend
(the same API the real engine serves behind); the Orca baselines keep their
contiguous-prealloc simulator, which has no paged backend to front."""

from __future__ import annotations

from repro.serving.api import LLMService
from repro.serving.simulator import (SimBackend, make_workload,
                                     simulate_prealloc)

# memory sized like the paper's A100-40G serving OPT-13B: ~13 GB free for KV
# at ~800 KiB/token -> ~16k token slots
TOKEN_SLOTS = 16_384
BLOCK_SIZE = 16


def run(n_requests: int = 400, verbose: bool = True):
    results = {}
    for dist, rates in (("sharegpt", (2.0, 4.0, 6.0, 8.0, 10.0, 14.0,
                                      18.0, 24.0)),
                        ("alpaca", (8.0, 16.0, 32.0, 48.0, 64.0, 96.0))):
        rows = []
        for rate in rates:
            def wl():
                return make_workload(n_requests, rate=rate, dist=dist,
                                     seed=7)
            row = {"rate": rate}
            svc = LLMService(SimBackend(
                num_blocks=TOKEN_SLOTS // BLOCK_SIZE,
                block_size=BLOCK_SIZE))
            _, stats = svc.replay(wl())
            row["vLLM-paged"] = stats.mean_normalized_latency
            for pol in ("oracle", "pow2", "max"):
                r = simulate_prealloc(wl(), total_slots=TOKEN_SLOTS,
                                      policy=pol)
                row[f"orca-{pol}"] = r.mean_normalized_latency
            rows.append(row)
            if verbose:
                print(f"{dist} rate={rate:5.1f} req/s: " + "  ".join(
                    f"{k}={1e3*v:7.1f}ms" for k, v in row.items()
                    if k != "rate"))
        results[dist] = rows
        if verbose:
            # sustainable rate at a latency SLO, the paper's headline ratio
            slo = 0.040  # 40 ms/token
            sus = {}
            for sysname in ("vLLM-paged", "orca-oracle", "orca-pow2",
                            "orca-max"):
                ok = [r["rate"] for r in rows if r[sysname] <= slo]
                sus[sysname] = max(ok) if ok else 0.0
            base = max(sus["orca-max"], 1e-9)
            print(f"  sustainable@{slo*1e3:.0f}ms/tok: "
                  + "  ".join(f"{k}={v:.0f}" for k, v in sus.items())
                  + f"  -> paged/orca-max = "
                    f"{sus['vLLM-paged']/base:.1f}x")
    return results


if __name__ == "__main__":
    run()
