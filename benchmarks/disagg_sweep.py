"""Disaggregated prefill/decode sweep: the P99-TBT-vs-throughput frontier.

Replays the same mixed traffic as ``chunked_prefill_sweep`` — decode-heavy
chat plus 8% long 12k-token document-ingest prompts — at a range of arrival
rates through two cluster configurations with identical totals (same
instance count, same per-instance KV pages, same iteration token budget):

* ``mixed-4m``     — 4 mixed instances, ``decode_first`` chunked prefill
  (PR 4's best policy): interference is *interleaved*, so a decode
  iteration still shares its budget with prefill chunks and the worst
  inter-token gap is bounded below by the full mixed-iteration time;
* ``disagg-2p2d``  — 2 prefill + 2 decode instances with leased/migrated
  KV handoff (``handoff_mode=auto``): interference is *eliminated* —
  decode instances run pure decode iterations — at the price of the
  handoff transfer (charged by the NetworkModel) and half the cluster
  doing no decoding.

The frontier is the headline: at every rate, disaggregation must cut the
P99 worst inter-token gap while keeping throughput within 10% of the mixed
baseline (prefill capacity halves, so heavy prefill load *can* cost
throughput — the guard bounds the price of the latency win). A second
table compares the three handoff modes at the middle rate.

    PYTHONPATH=src python benchmarks/disagg_sweep.py [--smoke]
"""

from __future__ import annotations

import argparse

from repro.serving.simulator import (make_workload, simulate_disagg,
                                     simulate_router)

MAX_TOKENS_PER_ITER = 2048
BLOCKS_PER_INSTANCE = 1500
BLOCK_SIZE = 16
LONG_LEN = 12_288  # 6x the iteration budget, as in chunked_prefill_sweep
RATES = (10.0, 14.0, 18.0, 22.0)
SMOKE_RATES = (14.0, 18.0)
HANDOFF_MODES = ("migrate", "zero_copy", "auto")


def _traffic(n_requests: int, rate: float):
    return make_workload(n_requests, rate=rate, dist="sharegpt", seed=7,
                         max_len=640, long_frac=0.08, long_len=LONG_LEN)


def run(n_requests: int = 200, rates=RATES, verbose: bool = True):
    rows = []

    def record(system, rate, res, **extra):
        rows.append(dict({
            "system": system,
            "rate": rate,
            "p99_tbt": res.p99_tbt,
            "mean_ttft": res.mean_ttft,
            "throughput": res.throughput_tokens_per_s,
            "completed": res.completed_frac,
            "net_time": res.net_time,
        }, **extra))
        if verbose:
            r = rows[-1]
            print(f"{system:16s} rate={rate:5.1f}  "
                  f"p99-gap={1e3 * r['p99_tbt']:8.1f}ms  "
                  f"ttft={1e3 * r['mean_ttft']:8.1f}ms  "
                  f"thr={r['throughput']:7.1f} tok/s  "
                  f"done={r['completed']:.0%}")

    for rate in rates:
        res = simulate_router(_traffic(n_requests, rate), n_instances=4,
                              policy="least_loaded",
                              blocks_per_instance=BLOCKS_PER_INSTANCE,
                              block_size=BLOCK_SIZE,
                              max_tokens_per_iter=MAX_TOKENS_PER_ITER,
                              chunk_policy="decode_first")
        record("mixed-4m", rate, res)
        res = simulate_disagg(_traffic(n_requests, rate), roles="2p2d",
                              handoff_mode="auto",
                              blocks_per_instance=BLOCKS_PER_INSTANCE,
                              block_size=BLOCK_SIZE,
                              max_tokens_per_iter=MAX_TOKENS_PER_ITER,
                              chunk_policy="decode_first")
        record("disagg-2p2d", rate, res,
               handoffs_migrated=res.handoffs_migrated,
               handoffs_leased=res.handoffs_leased)

    # handoff-mode detail at the middle rate: what auto is choosing between
    mid = rates[len(rates) // 2]
    for mode in HANDOFF_MODES:
        res = simulate_disagg(_traffic(n_requests, mid), roles="2p2d",
                              handoff_mode=mode,
                              blocks_per_instance=BLOCKS_PER_INSTANCE,
                              block_size=BLOCK_SIZE,
                              max_tokens_per_iter=MAX_TOKENS_PER_ITER,
                              chunk_policy="decode_first")
        record(f"handoff-{mode}", mid, res,
               handoffs_migrated=res.handoffs_migrated,
               handoffs_leased=res.handoffs_leased)
    return rows


def headline(rows) -> str:
    """The acceptance frontier: at every swept rate, disaggregation must
    beat mixed decode_first chunked prefill on P99 worst inter-token gap
    while finishing everything and holding >= 90% of its throughput."""
    rates = sorted({r["rate"] for r in rows if r["system"] == "mixed-4m"})

    def pick(system, rate):
        return next(r for r in rows if r["system"] == system
                    and r["rate"] == rate)

    ok = True
    gains, thr_fracs = [], []
    for rate in rates:
        mixed = pick("mixed-4m", rate)
        disagg = pick("disagg-2p2d", rate)
        gains.append(mixed["p99_tbt"] / max(disagg["p99_tbt"], 1e-12))
        thr_fracs.append(disagg["throughput"]
                         / max(mixed["throughput"], 1e-12))
        ok = ok and (disagg["p99_tbt"] < mixed["p99_tbt"]
                     and disagg["throughput"] >= 0.9 * mixed["throughput"]
                     and disagg["completed"] >= mixed["completed"])
    lo, hi = rates[0], rates[-1]
    m_lo, d_lo = pick("mixed-4m", lo), pick("disagg-2p2d", lo)
    m_hi, d_hi = pick("mixed-4m", hi), pick("disagg-2p2d", hi)
    return (f"disagg_vs_mixed_frontier: p99-gap "
            f"{1e3 * m_lo['p99_tbt']:.0f}->{1e3 * d_lo['p99_tbt']:.0f}ms "
            f"@rate{lo:.0f}, "
            f"{1e3 * m_hi['p99_tbt']:.0f}->{1e3 * d_hi['p99_tbt']:.0f}ms "
            f"@rate{hi:.0f} "
            f"(min gain {min(gains):.1f}x, thr frac {min(thr_fracs):.2f}) "
            f"guard={'ok' if ok else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exits nonzero unless disaggregation "
                         "beats mixed chunked prefill on the P99 decode-"
                         "stall tail at every rate without losing more "
                         "than 10% throughput")
    ap.add_argument("--requests", type=int, default=None)
    args = ap.parse_args()
    n = args.requests or (80 if args.smoke else 200)
    rows = run(n_requests=n, rates=SMOKE_RATES if args.smoke else RATES)
    line = headline(rows)
    print(line)
    if args.smoke and "FAIL" in line:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
