"""Attention variants: GQA (+MQA, sliding window) and MLA (DeepSeek-V2).

Two execution regimes:

* **train / prefill** — full-sequence blockwise attention (flash-style scan
  over query chunks; pure XLA ops so the dry-run's ``cost_analysis`` sees the
  true FLOPs/bytes). The Pallas kernels in ``repro.kernels`` implement the
  same math for the serving engine; ``ops.use_pallas`` switches paths.
* **decode** — one query token against a KV cache. The cache is a ring buffer
  of capacity ``Sc`` (``Sc < seq_len`` for sliding-window layers — this is what
  makes ``long_500k`` bounded-memory); each slot remembers the absolute
  position it holds so masking works after wraparound.

MLA decode uses the matrix-absorption trick: only the 512-d latent + 64-d
rope-key are cached (the paged "KV" for DeepSeek is the latent — see
DESIGN.md §2.3), and W_UK / W_UV are folded into the query/output sides.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import (NO_POLICY, ShardingPolicy, apply_rope, dense,
                                 dense_init, mlp, norm_init, pad_last,
                                 rms_norm)

# The dry-run's cost-model compiles set this so the query-chunk scan unrolls:
# XLA's cost analysis counts a while body once regardless of trip count, so
# attention FLOPs would otherwise be undercounted by the chunk count.
CHUNK_UNROLL = False


class KVCache(NamedTuple):
    """Ring-buffer KV cache for one layer group. Leaves may carry a leading
    stacked-layer axis when used under ``lax.scan``."""

    k: jax.Array  # (B, Sc, Hkv, Dh)
    v: jax.Array  # (B, Sc, Hkv, Dh)
    pos: jax.Array  # (B, Sc) absolute position per slot, -1 = empty


class MLACache(NamedTuple):
    ckv: jax.Array  # (B, Sc, r)       compressed kv latent
    krope: jax.Array  # (B, Sc, dr)    pre-roped shared rope key
    pos: jax.Array  # (B, Sc)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def gqa_init(cfg, key, dtype):
    ks = jax.random.split(key, 4)
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(ks[0], d, h * dh, dtype, bias=cfg.use_bias),
        "wk": dense_init(ks[1], d, hkv * dh, dtype, bias=cfg.use_bias),
        "wv": dense_init(ks[2], d, hkv * dh, dtype, bias=cfg.use_bias),
        "wo": dense_init(ks[3], h * dh, d, dtype, bias=cfg.use_bias),
    }


def mla_init(cfg, key, dtype):
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.num_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    p = {
        "wkv_a": dense_init(ks[0], d, r + dr, dtype),
        "kv_norm": norm_init(r, dtype),
        "wkv_b": dense_init(ks[1], r, h * (dn + dv), dtype),
        "wo": dense_init(ks[2], h * dv, d, dtype),
    }
    if qr:
        p["wq_a"] = dense_init(ks[3], d, qr, dtype)
        p["q_norm"] = norm_init(qr, dtype)
        p["wq_b"] = dense_init(ks[4], qr, h * (dn + dr), dtype)
    else:
        p["wq"] = dense_init(ks[5], d, h * (dn + dr), dtype)
    return p


# ---------------------------------------------------------------------------
# full-sequence blockwise attention (train / prefill)
# ---------------------------------------------------------------------------

def _chunk_size(s: int) -> int:
    for c in (512, 256, 128, 64):
        if s % c == 0 and s >= c:
            return c
    return s


def blockwise_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0,
                        scale: Optional[float] = None,
                        policy: ShardingPolicy = NO_POLICY):
    """q: (B,S,H,Dh); k,v: (B,Skv,Hkv,Dh). GQA broadcast, fp32 softmax.

    Scans over query chunks so the score matrix never materializes at
    (S x Skv); per-chunk live memory is (B, C, H, Skv).
    ``q_offset``: absolute position of q[0] relative to k[0] (cross-attention
    passes causal=False and ignores it). ``scale`` overrides the default
    ``1/sqrt(Dh)`` (the absorbed-MLA path scores in a lifted latent dim but
    must scale by the *conceptual* head dim).
    """
    b, s, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    c = _chunk_size(s)
    scale = (1.0 / math.sqrt(dh)) if scale is None else scale
    kg = k.astype(jnp.bfloat16)
    vg = v.astype(jnp.bfloat16)
    kv_pos = jnp.arange(skv)

    # GQA head layout for sharding: when the flat head count H divides the
    # model axis but Hkv does not (all 8-kv-head archs on a 16-way mesh),
    # broadcast K/V to H heads — the per-shard materialization is H_local
    # heads only, and scores then expose a shardable flat-h axis with a
    # fully local softmax. (Perf iteration 4.)
    flat_heads = bool(getattr(policy, "prefers_flat_heads", lambda a, b: False)(h, hkv))
    if flat_heads:
        kg = jnp.broadcast_to(kg[:, :, :, None, :], (b, skv, hkv, g, dh)
                              ).reshape(b, skv, h, dh)
        vg = jnp.broadcast_to(vg[:, :, :, None, :], (b, skv, hkv, g, dv)
                              ).reshape(b, skv, h, dv)
        kg = policy.act(kg, "kvrep_bshd")
        vg = policy.act(vg, "kvrep_bshd")

    def one_chunk(qc, qpos):
        mask = jnp.ones((qpos.shape[0], skv), dtype=bool)
        if causal:
            mask &= qpos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= kv_pos[None, :] > qpos[:, None] - window
        if flat_heads:
            scores = jnp.einsum("bchd,bshd->bchs", qc.astype(jnp.bfloat16),
                                kg, preferred_element_type=jnp.float32)
            scores = policy.act(scores * scale, "scores_bchs")
            scores = jnp.where(mask[None, :, None, :], scores, -jnp.inf)
            probs = policy.act(jax.nn.softmax(scores, -1), "scores_bchs")
            out = jnp.einsum("bchs,bshd->bchd", probs.astype(jnp.bfloat16),
                             vg, preferred_element_type=jnp.float32)
            return out.astype(q.dtype)
        # grouped path: (B,C,H,Dh) -> (B,C,Hkv,G,Dh)
        qc = qc.reshape(b, -1, hkv, g, dh)
        scores = jnp.einsum("bchgd,bshd->bchgs", qc.astype(jnp.bfloat16), kg,
                            preferred_element_type=jnp.float32) * scale
        scores = policy.act(scores, "scores_bchgs")
        scores = jnp.where(mask[None, :, None, None, :], scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = policy.act(probs, "scores_bchgs")
        out = jnp.einsum("bchgs,bshd->bchgd", probs.astype(jnp.bfloat16), vg,
                         preferred_element_type=jnp.float32)
        return out.reshape(b, -1, h, dv).astype(q.dtype)

    if s == c:
        return one_chunk(q, q_offset + jnp.arange(s))

    nq = s // c
    qs = q.reshape(b, nq, c, h, dh).transpose(1, 0, 2, 3, 4)
    qpos = (q_offset + jnp.arange(s)).reshape(nq, c)

    # flash-attention backward semantics: recompute scores per chunk instead
    # of saving every chunk's score residuals for the whole sequence
    chunk_fn = jax.checkpoint(one_chunk)

    def body(_, qc_pos):
        qc, pos = qc_pos
        return None, chunk_fn(qc, pos)

    _, outs = lax.scan(body, None, (qs, qpos),
                       unroll=nq if CHUNK_UNROLL else 1)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)


def gqa_layer(cfg, p, x, positions, attend, *,
              policy: ShardingPolicy = NO_POLICY, mlp_fn=None):
    """One full GQA transformer layer, parameterized by the attention
    callable — the single layer body shared by the models' full-sequence
    path, the engine's fused paged decode, and the cached-prefix suffix
    prefill (which previously hand-rolled three copies of it).

    ``x``: (B, S, D); ``positions``: (S,) or (B, S) absolute positions.
    ``attend(q, k, v) -> (ctx, carry)`` receives roped q (B, S, H, Dh) and
    roped k / raw v (B, S, Hkv, Dh), returns the attention context
    (B, S, H, Dv) plus an arbitrary carry (e.g. updated KV page buffers)
    threaded back to the caller. Layout: pre-norm, residual attention,
    pre-norm residual MLP. ``mlp_fn(p_mlp, h) -> out`` overrides the dense
    MLP (MoE segments pass their expert dispatch).
    """
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    hn = rms_norm(p["ln1"], x, cfg.norm_eps)
    q = dense(p["attn"]["wq"], hn).reshape(b, s, h, dh)
    k = dense(p["attn"]["wk"], hn).reshape(b, s, hkv, dh)
    v = dense(p["attn"]["wv"], hn).reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = policy.act(q, "heads_bshd")
    k = policy.act(k, "kv_bshd")
    v = policy.act(v, "kv_bshd")
    ctx, carry = attend(q, k, v)
    ctx = policy.act(ctx, "heads_bshd")
    y = x + dense(p["attn"]["wo"], ctx.reshape(b, s, -1), policy, "act_bsd")
    h2 = rms_norm(p["ln2"], y, cfg.norm_eps)
    y = y + (mlp(p["mlp"], h2, policy) if mlp_fn is None
             else mlp_fn(p["mlp"], h2))
    return y, carry


def gqa_forward(cfg, p, x, positions, *, window=None, causal=True,
                policy: ShardingPolicy = NO_POLICY, kv_override=None,
                return_kv: bool = False):
    """Full-sequence GQA. ``kv_override=(k,v)`` implements cross-attention.

    Returns (out, (k, v) roped) — k/v for cache seeding during prefill.
    """
    b, s, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, dh)
    if kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)  # no rope for cross-attn
    q = policy.act(q, "heads_bshd")
    if kv_override is None:
        k = dense(p["wk"], x).reshape(b, s, hkv, dh)
        v = dense(p["wv"], x).reshape(b, s, hkv, dh)
        k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
    k = policy.act(k, "kv_bshd")
    v = policy.act(v, "kv_bshd")
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              policy=policy)
    out = policy.act(out, "heads_bshd")
    y = dense(p["wo"], out.reshape(b, s, h * dh), policy, "act_bsd")
    if return_kv:
        return y, (k, v)
    return y


def encode_kv(cfg, p, x):
    """Project encoder output to cross-attention K/V (no rope for cross-attn)."""
    b, s, _ = x.shape
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    k = dense(p["wk"], x).reshape(b, s, hkv, dh)
    v = dense(p["wv"], x).reshape(b, s, hkv, dh)
    return k, v


# ---------------------------------------------------------------------------
# decode (one token, ring-buffer cache)
# ---------------------------------------------------------------------------

def cache_update(cache_pos, pos):
    """slot index for absolute position ``pos`` in a ring of capacity Sc."""
    sc = cache_pos.shape[-1]
    return pos % sc


def _write_slot(buf, slot, new):
    """buf: (B, Sc, ...); new: (B, ...) written at per-batch ``slot``."""
    b = buf.shape[0]
    return buf.at[jnp.arange(b), slot].set(new.astype(buf.dtype))


def _decode_mask(cache_pos, pos, window):
    """(B, Sc) validity of each cache slot for query at absolute ``pos``."""
    valid = (cache_pos >= 0) & (cache_pos <= pos[:, None])
    if window is not None:
        valid &= cache_pos > (pos[:, None] - window)
    return valid


def gqa_decode(cfg, p, x, cache: KVCache, pos, *, window=None,
               policy: ShardingPolicy = NO_POLICY, kv_override=None):
    """x: (B,1,D); pos: (B,) absolute position of the new token.

    Returns (y (B,1,D), new_cache). With ``kv_override`` (cross-attention) the
    cache is the static encoder KV and is returned unchanged.
    """
    b = x.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, 1, h, dh)
    if kv_override is None:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        knew = dense(p["wk"], x).reshape(b, 1, hkv, dh)
        vnew = dense(p["wv"], x).reshape(b, 1, hkv, dh)
        knew = apply_rope(knew, pos[:, None], cfg.rope_theta)
        slot = cache_update(cache.pos, pos)
        cache = KVCache(
            k=_write_slot(cache.k, slot, knew[:, 0]),
            v=_write_slot(cache.v, slot, vnew[:, 0]),
            pos=_write_slot(cache.pos, slot, pos),
        )
        mask = _decode_mask(cache.pos, pos, window)  # (B, Sc)
        k, v = cache.k, cache.v
    else:
        k, v = kv_override
        mask = jnp.ones((b, k.shape[1]), dtype=bool)

    k = policy.act(k, "kvcache_bskd")
    v = policy.act(v, "kvcache_bskd")
    g = h // hkv
    qg = q.reshape(b, hkv, g, dh)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.bfloat16),
                        k.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dh)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(jnp.bfloat16),
                     v.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, h * dh).astype(x.dtype)
    y = dense(p["wo"], out, policy, "act_bsd")
    return y, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(cfg, p, x):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "wq_a" in p:
        ql = rms_norm(p["q_norm"], dense(p["wq_a"], x), cfg.norm_eps)
        q = dense(p["wq_b"], ql)
    else:
        q = dense(p["wq"], x)
    q = q.reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def _mla_scale(cfg):
    return 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)


def mla_forward(cfg, p, x, positions, *, policy: ShardingPolicy = NO_POLICY,
                return_latent: bool = False):
    """Full-sequence MLA: decompress K/V and run standard MHA.

    With ``return_latent`` also returns ``(ckv_normed, krope_roped)`` — the
    compressed cache seed for absorbed decode."""
    b, s, _ = x.shape
    h = cfg.num_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    qn, qr = _mla_q(cfg, p, x)
    qr = apply_rope(qr, positions, cfg.rope_theta)

    kv = dense(p["wkv_a"], x)
    ckv, krope = kv[..., :r], kv[..., r:]
    ckv = rms_norm(p["kv_norm"], ckv, cfg.norm_eps)
    krope = apply_rope(krope, positions, cfg.rope_theta, heads=False)  # (b,s,dr) shared
    kvb = dense(p["wkv_b"], ckv).reshape(b, s, h, dn + dv)
    kn, v = kvb[..., :dn], kvb[..., dn:]

    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(krope[:, :, None, :], (b, s, h, dr))],
                        axis=-1)
    q = policy.act(q, "heads_bshd")
    # blockwise_attention scales by 1/sqrt(dn+dr) via head_dim of concat — correct.
    out = blockwise_attention(q, k, v[..., :dv], causal=True, policy=policy)
    y = dense(p["wo"], out.reshape(b, s, h * dv), policy, "act_bsd")
    if return_latent:
        return y, (ckv, krope)
    return y


def mla_decode(cfg, p, x, cache: MLACache, pos, *,
               policy: ShardingPolicy = NO_POLICY):
    """Matrix-absorbed MLA decode: score against the latent cache directly."""
    b = x.shape[0]
    h = cfg.num_heads
    r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_head_dim,
                     cfg.qk_rope_head_dim, cfg.v_head_dim)
    qn, qr = _mla_q(cfg, p, x)  # (b,1,h,dn), (b,1,h,dr)
    qr = apply_rope(qr, pos[:, None], cfg.rope_theta)

    kv = dense(p["wkv_a"], x)  # (b,1,r+dr)
    ckv_new = rms_norm(p["kv_norm"], kv[..., :r], cfg.norm_eps)
    krope_new = apply_rope(kv[..., r:], pos[:, None], cfg.rope_theta, heads=False)
    slot = cache_update(cache.pos, pos)
    cache = MLACache(
        ckv=_write_slot(cache.ckv, slot, ckv_new[:, 0]),
        krope=_write_slot(cache.krope, slot, krope_new[:, 0]),
        pos=_write_slot(cache.pos, slot, pos),
    )
    mask = _decode_mask(cache.pos, pos, None)  # (b, Sc)

    wkv_b = p["wkv_b"]["w"].reshape(r, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]  # (r,h,dn), (r,h,dv)
    # absorb W_UK into q: (b,1,h,dn) x (r,h,dn) -> (b,h,r)
    q_lat = jnp.einsum("bhd,rhd->bhr", qn[:, 0].astype(jnp.bfloat16),
                       w_uk.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32)
    ckv = policy.act(cache.ckv, "mlacache_bsr")
    scores = jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.bfloat16),
                        ckv.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)
    scores += jnp.einsum("bhd,bsd->bhs", qr[:, 0].astype(jnp.bfloat16),
                         cache.krope.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
    scores = scores * _mla_scale(cfg)
    scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs.astype(jnp.bfloat16),
                     ckv.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bhr,rhd->bhd", ctx.astype(jnp.bfloat16),
                     w_uv.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    y = dense(p["wo"], out.reshape(b, 1, h * dv).astype(x.dtype), policy, "act_bsd")
    return y, cache


def mla_absorb(cfg, p):
    """Split ``wkv_b`` into the absorbed matrices: ``(w_uk, w_uv)`` with
    shapes ``(r, h, dn)`` / ``(r, h, dv)``. W_UK folds into the query path
    (queries lifted to the latent dim), W_UV into the output projection —
    decode then attends *directly over latent pages*, never materializing
    per-head K/V."""
    r, h = cfg.kv_lora_rank, cfg.num_heads
    dn, dv = cfg.qk_nope_head_dim, cfg.v_head_dim
    wkv_b = p["wkv_b"]["w"].reshape(r, h, dn + dv)
    return wkv_b[..., :dn], wkv_b[..., dn:]


def mla_effective_ctx(ckv, krope):
    """Latent context as single-kv-head effective K/V: keys are
    ``concat(ckv, krope)`` with ``Hkv = 1`` (the latent is shared across
    heads — MQA in the latent space), values are ``ckv`` zero-padded to the
    key width (attention is linear in v, so the pad columns stay zero —
    slice the context back to ``[..., :r]`` after attending).

    ckv: (B,T,r); krope: (B,T,dr) -> k_eff, v_eff: (B,T,1,r+dr)."""
    k_eff = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]
    v_eff = pad_last(ckv, k_eff.shape[-1])[:, :, None, :]
    return k_eff, v_eff


def mla_effective_kv(q_lat, qr, ckv, krope):
    """Express absorbed-MLA attention as single-kv-head MHA so the generic
    machinery (``blockwise_attention``, ``attention_partial`` merges) runs
    it unchanged: queries are ``concat(q_lat, qr)`` — scores decompose as
    ``q_lat . ckv + qr . krope`` — and K/V come from
    :func:`mla_effective_ctx`.

    q_lat: (B,S,H,r); qr: (B,S,H,dr); ckv: (B,T,r); krope: (B,T,dr).
    Callers must pass ``scale=_mla_scale(cfg)`` — the conceptual head dim is
    ``dn + dr``, not the lifted ``r + dr``.
    """
    q_eff = jnp.concatenate([q_lat, qr], axis=-1)
    k_eff, v_eff = mla_effective_ctx(ckv, krope)
    return q_eff, k_eff, v_eff


def mla_layer(cfg, p, x, positions, attend_latent, *,
              policy: ShardingPolicy = NO_POLICY, mlp_fn=None):
    """One full MLA transformer layer parameterized by the latent attention
    callable — the MLA sibling of :func:`gqa_layer`, shared by the engine's
    paged prefill/decode paths.

    ``attend_latent(q_lat, qr, ckv_new, krope_new) -> (ctx_lat, carry)``
    receives absorbed queries ``q_lat`` (B,S,H,r), roped rope-queries ``qr``
    (B,S,H,dr), and this chunk's latent page payloads ``ckv_new`` (B,S,r) /
    ``krope_new`` (B,S,dr) (normed / pre-roped — exactly what the pools
    store); it returns the latent-space context (B,S,H,r) plus a carry
    (e.g. updated latent page buffers). The output projection absorbs W_UV.
    """
    b, s, _ = x.shape
    h, r, dv = cfg.num_heads, cfg.kv_lora_rank, cfg.v_head_dim
    hn = rms_norm(p["ln1"], x, cfg.norm_eps)
    qn, qr = _mla_q(cfg, p["attn"], hn)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    kv = dense(p["attn"]["wkv_a"], hn)
    ckv_new = rms_norm(p["attn"]["kv_norm"], kv[..., :r], cfg.norm_eps)
    krope_new = apply_rope(kv[..., r:], positions, cfg.rope_theta,
                           heads=False)
    w_uk, w_uv = mla_absorb(cfg, p["attn"])
    q_lat = jnp.einsum("bshd,rhd->bshr", qn.astype(jnp.bfloat16),
                       w_uk.astype(jnp.bfloat16),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    ctx_lat, carry = attend_latent(q_lat, qr, ckv_new, krope_new)
    out = jnp.einsum("bshr,rhd->bshd", ctx_lat.astype(jnp.bfloat16),
                     w_uv.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    y = x + dense(p["attn"]["wo"], out.reshape(b, s, h * dv).astype(x.dtype),
                  policy, "act_bsd")
    h2 = rms_norm(p["ln2"], y, cfg.norm_eps)
    y = y + (mlp(p["mlp"], h2, policy) if mlp_fn is None
             else mlp_fn(p["mlp"], h2))
    return y, carry


def mla_prefill_cache(cfg, p, x, positions, capacity: int):
    """Build the latent cache from a full prefill pass (used by the engine)."""
    b, s, _ = x.shape
    r = cfg.kv_lora_rank
    kv = dense(p["wkv_a"], x)
    ckv = rms_norm(p["kv_norm"], kv[..., :r], cfg.norm_eps)
    krope = apply_rope(kv[..., r:], positions, cfg.rope_theta, heads=False)
    pad = capacity - s
    return MLACache(
        ckv=jnp.pad(ckv, ((0, 0), (0, pad), (0, 0))),
        krope=jnp.pad(krope, ((0, 0), (0, pad), (0, 0))),
        pos=jnp.pad(jnp.broadcast_to(positions, (b, s)), ((0, 0), (0, pad)),
                    constant_values=-1),
    )
