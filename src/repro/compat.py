"""Shims over JAX API renames across the supported version range."""

import jax

try:
    shard_map = jax.shard_map  # newer JAX exposes it at top level
except AttributeError:  # older JAX: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401

from jax.experimental.pallas import tpu as _pltpu

# newer JAX names this pltpu.CompilerParams, older TPUCompilerParams
CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    _pltpu.TPUCompilerParams
