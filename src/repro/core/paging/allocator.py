"""vLLM-style block allocator (paper §III.C): free list + refcounts + COW.

Physical KV pages are fixed-size blocks; sequences map logical→physical via a
block table. Reference counting enables parallel-sampling / beam-search
sharing: forked sequences share prompt pages until a write triggers
copy-on-write. Utilization statistics feed the paper's "ORCA uses only
20.4–38.2% of KV memory" comparison (benchmarks/kv_utilization.py).

Host swap tier. With ``host_blocks > 0`` the allocator also tracks a pool of
host-memory pages so preemption can *swap* a victim's KV out over PCIe
instead of sacrificing it to recompute: :meth:`swap_out` moves a table's
device pages to host blocks (device refs dropped — pages a radix tree or a
fork sibling still references survive on device for those holders; the host
copy is this table's private snapshot) and :meth:`swap_in` re-materializes
them onto fresh device blocks. The bookkeeping distinguishes *swapped* from
*freed*: ``swapped_pages`` counts host blocks in use, ``num_free`` never
includes them, and a table is either device-resident (``blocks``) or
host-resident (``host_blocks``) — never both. The data movement itself is the
execution backend's job (the engine copies page payloads, the simulator
charges PCIe time); the allocator only keeps the ledgers honest.

Pending-out ledger (overlapped swaps). A synchronous :meth:`swap_out` frees
device pages in the same scheduling step the copy is issued, which forces the
backend to complete the DMA before compute. The split
:meth:`swap_out_issue` / :meth:`swap_out_complete` /
:meth:`swap_out_cancel` API lets a *speculative* swap-out overlap the next
iteration's compute: issue moves the table's device references into an
in-flight ledger (the pages stay allocated — ``num_free`` does NOT grow — so
nothing can reallocate-and-clobber a DMA source mid-flight), complete drops
those references one iteration later (pages free then, exactly as a
synchronous swap-out would have left them), and cancel puts the references
back on the table and releases the host blocks (the pages never left).
Conservation holds throughout: ``num_used + num_free == num_blocks`` with
in-flight pages counted used, and ``pending_out_pages`` exposes the
in-flight count for invariant checks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


class OutOfBlocks(Exception):
    pass


class OutOfHostBlocks(Exception):
    pass


@dataclasses.dataclass
class BlockTable:
    """Logical pages (in order) -> physical block ids for one sequence.

    While swapped out, ``blocks`` is empty and ``host_blocks`` holds the
    host-tier page per logical page (same order); ``num_tokens`` is
    unchanged — the tokens still exist, just not on device."""
    blocks: List[int] = dataclasses.field(default_factory=list)
    num_tokens: int = 0  # tokens actually stored
    host_blocks: List[int] = dataclasses.field(default_factory=list)

    def capacity(self, block_size: int) -> int:
        return len(self.blocks) * block_size

    @property
    def on_host(self) -> bool:
        return bool(self.host_blocks)


class BlockAllocator:
    def __init__(self, num_blocks: int, block_size: int,
                 host_blocks: int = 0, layout=None):
        self.num_blocks = num_blocks
        self.block_size = block_size
        # the KVPageLayout whose pages these blocks index (None = unknown,
        # e.g. pure-sim backends); cost models read ``page_bytes`` off it
        self.layout = layout
        self.free_list: List[int] = list(range(num_blocks - 1, -1, -1))
        self.refcount: Dict[int, int] = {}
        # host swap tier (0 = disabled): host pages are snapshots owned by
        # exactly one table or spilled cache node, so a plain free list
        # suffices — no refcounts, no COW
        self.num_host_blocks = host_blocks
        self.host_free_list: List[int] = list(range(host_blocks - 1, -1, -1))
        # in-flight swap-outs: ticket -> (device, host) pairs whose device
        # references the ledger owns until complete/cancel resolves them
        self._pending_out: Dict[int, List[Tuple[int, int]]] = {}
        self._pending_seq = 0
        self.pending_out_pages = 0

    @property
    def page_bytes(self) -> Optional[int]:
        """Serialized bytes of one page, from the layout (None if unknown)."""
        if self.layout is None:
            return None
        return self.layout.page_bytes(self.block_size)

    # -- raw blocks -----------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free_list)

    @property
    def num_used(self) -> int:
        return self.num_blocks - self.num_free

    def alloc_block(self) -> int:
        if not self.free_list:
            raise OutOfBlocks
        b = self.free_list.pop()
        self.refcount[b] = 1
        return b

    def incref(self, block: int) -> None:
        if block not in self.refcount:
            raise ValueError(
                f"incref of unallocated block {block} (free or never "
                f"allocated) — references may only be added to live blocks")
        self.refcount[block] += 1

    def decref(self, block: int) -> None:
        if block not in self.refcount:
            raise ValueError(
                f"decref of unallocated block {block} — double free or "
                f"unknown block")
        self.refcount[block] -= 1
        if self.refcount[block] == 0:
            del self.refcount[block]
            self.free_list.append(block)

    def refcount_of(self, block: int) -> int:
        """Live reference count of ``block`` (0 = free / never allocated)."""
        return self.refcount.get(block, 0)

    # -- host swap tier ---------------------------------------------------------
    @property
    def host_num_free(self) -> int:
        return len(self.host_free_list)

    @property
    def swapped_pages(self) -> int:
        """Host pages in use (swapped-out tables + spilled cache pages)."""
        return self.num_host_blocks - len(self.host_free_list)

    def alloc_host_block(self) -> int:
        if not self.host_free_list:
            raise OutOfHostBlocks
        return self.host_free_list.pop()

    def free_host_block(self, block: int) -> None:
        if block in self.host_free_list or not \
                (0 <= block < self.num_host_blocks):
            raise ValueError(f"free of host block {block} that is not live "
                             f"— double free or unknown block")
        self.host_free_list.append(block)

    def can_swap_out(self, table: BlockTable) -> bool:
        return not table.on_host and \
            len(table.blocks) <= len(self.host_free_list)

    def swap_out(self, table: BlockTable) -> List[Tuple[int, int]]:
        """Move ``table``'s pages device -> host. Returns ``(device, host)``
        pairs — the execution backend must copy each device page's payload
        into its host page BEFORE any same-iteration write can touch a
        reallocated device block (the scheduler orders swap-out copies
        first). Device refs are dropped (a tree-shared page survives on
        device for its other holders; the host copy is this table's private
        snapshot), so ``num_free`` grows by the exclusively-owned pages."""
        if table.on_host:
            raise ValueError("swap_out of an already-swapped table")
        if len(table.blocks) > len(self.host_free_list):
            raise OutOfHostBlocks
        pairs = []
        for dev in table.blocks:
            host = self.alloc_host_block()
            pairs.append((dev, host))
            table.host_blocks.append(host)
            self.decref(dev)
        table.blocks.clear()
        return pairs

    def swap_out_issue(self, table: BlockTable
                       ) -> Tuple[int, List[Tuple[int, int]]]:
        """Start an overlapped swap-out: allocate host pages and move the
        table's device references into the pending ledger WITHOUT freeing
        them — the DMA sources stay allocated until
        :meth:`swap_out_complete` so no same- or next-iteration write can
        land on them. Returns ``(ticket, (device, host) pairs)``; the table
        is host-resident immediately (``blocks`` empty, ``host_blocks``
        set), exactly as after a synchronous :meth:`swap_out`."""
        if table.on_host:
            raise ValueError("swap_out of an already-swapped table")
        if len(table.blocks) > len(self.host_free_list):
            raise OutOfHostBlocks
        pairs = []
        for dev in table.blocks:
            host = self.alloc_host_block()
            pairs.append((dev, host))
            table.host_blocks.append(host)
        table.blocks.clear()  # the ledger owns the device refs now
        ticket = self._pending_seq
        self._pending_seq += 1
        self._pending_out[ticket] = pairs
        self.pending_out_pages += len(pairs)
        return ticket, pairs

    def swap_out_complete(self, ticket: int) -> List[Tuple[int, int]]:
        """Resolve an issued swap-out: the copy landed, drop the ledger's
        device references (pages free now for exclusive owners; tree-shared
        pages survive for their other holders)."""
        pairs = self._pending_out.pop(ticket)
        self.pending_out_pages -= len(pairs)
        for dev, _ in pairs:
            self.decref(dev)
        return pairs

    def swap_out_cancel(self, ticket: int, table: BlockTable
                        ) -> List[Tuple[int, int]]:
        """Abort an issued swap-out: pressure receded before the copy was
        needed. Device references move back onto ``table`` (the pages never
        left — no payload was lost) and the host pages are released."""
        pairs = self._pending_out.pop(ticket)
        self.pending_out_pages -= len(pairs)
        table.blocks.extend(dev for dev, _ in pairs)
        for _, host in pairs:
            self.free_host_block(host)
        table.host_blocks.clear()
        return pairs

    def can_swap_in(self, table: BlockTable) -> bool:
        return table.on_host and len(table.host_blocks) <= self.num_free

    def swap_in(self, table: BlockTable) -> List[Tuple[int, int]]:
        """Move ``table``'s pages host -> device onto fresh blocks. Returns
        ``(host, device)`` pairs for the backend's copies; host pages are
        released (their snapshot is consumed). Raises OutOfBlocks with the
        table untouched when the device pool cannot supply every page."""
        if not table.on_host:
            raise ValueError("swap_in of a device-resident table")
        if len(table.host_blocks) > self.num_free:
            raise OutOfBlocks
        pairs = []
        for host in table.host_blocks:
            dev = self.alloc_block()
            pairs.append((host, dev))
            table.blocks.append(dev)
            self.free_host_block(host)
        table.host_blocks.clear()
        return pairs

    # -- sequence-level API ----------------------------------------------------
    def blocks_needed(self, table: BlockTable, new_tokens: int) -> int:
        total = table.num_tokens + new_tokens
        need = -(-total // self.block_size)  # ceil
        return max(0, need - len(table.blocks))

    def can_append(self, table: BlockTable, new_tokens: int) -> bool:
        return self.blocks_needed(table, new_tokens) <= self.num_free

    def append_tokens(self, table: BlockTable,
                      new_tokens: int) -> List[Tuple[int, int]]:
        """Grow ``table`` to hold ``new_tokens`` more tokens, applying COW to
        the tail block if it is shared. Returns the ``(old, new)`` block
        pairs of any copy-on-write replacement — the engine must copy the
        old physical page's contents into the new page before writing."""
        cow: List[Tuple[int, int]] = []
        if new_tokens <= 0:
            return cow
        # copy-on-write: the block being written must be exclusively owned
        if table.blocks and table.num_tokens % self.block_size != 0:
            tail = table.blocks[-1]
            if self.refcount[tail] > 1:
                fresh = self.alloc_block()
                self.decref(tail)
                table.blocks[-1] = fresh
                cow.append((tail, fresh))
        for _ in range(self.blocks_needed(table, new_tokens)):
            table.blocks.append(self.alloc_block())
        table.num_tokens += new_tokens
        return cow

    def fork(self, table: BlockTable) -> BlockTable:
        """Share all pages (parallel sampling / beam search)."""
        for b in table.blocks:
            self.incref(b)
        return BlockTable(blocks=list(table.blocks),
                          num_tokens=table.num_tokens)

    def free_table(self, table: BlockTable) -> None:
        for b in table.blocks:
            self.decref(b)
        table.blocks.clear()
        # a table freed while swapped out (finished-while-swapped, or
        # preempted-dropped) must return its host pages too, or the host
        # tier leaks a snapshot nobody can ever reach again
        for h in table.host_blocks:
            self.free_host_block(h)
        table.host_blocks.clear()
        table.num_tokens = 0

    # -- stats -----------------------------------------------------------------
    def utilization(self, tables: List[BlockTable]) -> float:
        """Fraction of *allocated* KV slots holding real tokens (the paper's
        internal-fragmentation metric)."""
        alloc = sum(t.capacity(self.block_size) for t in tables)
        used = sum(t.num_tokens for t in tables)
        return used / alloc if alloc else 1.0


class ContiguousPreallocAllocator:
    """The paper's baseline (ORCA-style): reserve a contiguous max-length
    region per request up front. ``reserve_policy``:

    * "max"    — always ``max_len`` (Orca (Max))
    * "pow2"   — round the true total length up to a power of two (Orca (Pow2))
    * "oracle" — exactly the true total length (Orca (Oracle))
    """

    def __init__(self, total_slots: int, max_len: int, policy: str = "max"):
        self.total_slots = total_slots
        self.max_len = max_len
        self.policy = policy
        self.used_slots = 0
        self.live: Dict[int, int] = {}  # request id -> reserved slots
        self.stored: Dict[int, int] = {}  # request id -> actual tokens

    def reservation(self, true_total_len: int) -> int:
        if self.policy == "max":
            return self.max_len
        if self.policy == "pow2":
            r = 1
            while r < true_total_len:
                r *= 2
            return min(r, self.max_len)
        if self.policy == "oracle":
            return true_total_len
        raise ValueError(self.policy)

    def can_admit(self, true_total_len: int) -> bool:
        return self.used_slots + self.reservation(true_total_len) \
            <= self.total_slots

    def admit(self, rid: int, true_total_len: int) -> None:
        r = self.reservation(true_total_len)
        if self.used_slots + r > self.total_slots:
            raise OutOfBlocks
        self.used_slots += r
        self.live[rid] = r
        self.stored[rid] = 0

    def store(self, rid: int, tokens: int) -> None:
        self.stored[rid] = self.stored.get(rid, 0) + tokens

    def release(self, rid: int) -> None:
        self.used_slots -= self.live.pop(rid)
        self.stored.pop(rid, None)

    def utilization(self) -> float:
        reserved = sum(self.live.values())
        return sum(self.stored.values()) / reserved if reserved else 1.0
