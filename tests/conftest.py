import os

# Tests see the real single CPU device; only launch/dryrun.py (run as its own
# process) forces 512 host devices. A couple of distributed tests spawn their
# own subprocess with a small device count.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
