"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json)."""

from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCH_IDS, INPUT_SHAPES


def load(outdir: str = "experiments/dryrun"):
    recs = {}
    for f in glob.glob(os.path.join(outdir, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def run(outdir: str = "experiments/dryrun", verbose: bool = True):
    recs = load(outdir)
    rows = []
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            r = recs.get((arch, shape, "16x16"))
            if r is None:
                continue
            if r["status"] == "skipped":
                rows.append(dict(arch=arch, shape=shape, status="skipped"))
                continue
            roof = r["roofline"]
            rows.append(dict(
                arch=arch, shape=shape, status="ok",
                t_comp=roof["t_compute_s"], t_mem=roof["t_memory_s"],
                t_coll=roof["t_collective_s"],
                bottleneck=roof["bottleneck"],
                useful=r.get("useful_flop_frac", float("nan")),
                hbm=r.get("hbm_per_device_gib", float("nan")),
                multi_pod_ok=(arch, shape, "2x16x16") in recs and
                recs[(arch, shape, "2x16x16")]["status"] in ("ok", "skipped"),
            ))
    if verbose:
        hdr = (f"{'arch':24s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
               f"{'t_coll':>9s} {'bound':>10s} {'useful':>7s} {'HBM/dev':>8s} mp")
        print(hdr)
        print("-" * len(hdr))
        for r in rows:
            if r["status"] == "skipped":
                print(f"{r['arch']:24s} {r['shape']:12s} "
                      f"{'(skipped: long-ctx n/a)':>40s}")
                continue
            print(f"{r['arch']:24s} {r['shape']:12s} {r['t_comp']:9.4f} "
                  f"{r['t_mem']:9.4f} {r['t_coll']:9.4f} "
                  f"{r['bottleneck']:>10s} {r['useful']:7.2f} "
                  f"{r['hbm']:7.1f}G {'Y' if r['multi_pod_ok'] else '-'}")
    return rows


if __name__ == "__main__":
    run()
