"""Cross-instance prefix publication board (InfiniteLLM-style cluster KV).

The radix prefix cache (``core.prefixcache``) shares KV pages *within* one
LLM service instance. Under a multi-instance router the same hot system
prompt is otherwise recomputed once per instance; this module is the piece
of the distkv layer that closes that gap:

* an instance whose radix tree crosses a hit-count threshold on a path
  exports ``(token keys, page payloads)`` for that path
  (:meth:`PrefixCache.take_hot_paths`) and **publishes** it here, through
  its gManager (the publication board is global-coordinator state, like the
  debt ledger);
* a peer instance, at admission time, asks the board for the longest
  published extension of its own local radix match and **adopts** those
  pages into its own tree (:meth:`PrefixCache.adopt`) — fresh local blocks
  filled from the published payloads, so the shared prefix is computed once
  cluster-wide.

Payloads are opaque to the board: the real engine publishes the per-layer
K/V page contents (host numpy, one copy per page), the cost-model simulator
publishes ``None``. The board mirrors the radix tree's shape — one node per
page, keyed by the page's token tuple — so lookup is the same page-aligned
walk. This is the *copy* flavor of cross-instance sharing; serving the
prefix remotely via borrowed rBlocks + DistAttention partial merges (no
copy, per-token remote penalty) is the recorded alternative.

Eviction. Published payloads are real memory on the coordinator (an engine
page is per-layer K/V host arrays), so the board is **size-capped**:
``max_pages`` bounds the resident page count and publishing past it evicts
least-recently-used *leaf* pages first (a leaf-only policy keeps every
surviving path intact, mirroring the radix cache's eviction). A lookup
touches every page on its matched path, so hot prefixes stay resident while
one-off publications age out. ``max_pages=None`` keeps the previous
unbounded behavior. An evicted page may still be flagged ``published`` in
its home instance's radix tree — it simply stops being adoptable (a
graceful miss) until some instance's hot path crosses the threshold again
and republishes it.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)


@dataclasses.dataclass
class PublishedPage:
    """One published page: token key, opaque KV payload, home instance.

    ``block`` is the physical page id on the home instance — the handle a
    peer needs to *borrow* the page (zero-copy rBlock serving) instead of
    copying its payload. ``None`` when the publisher did not offer its pages
    for lending (copy-only sharing)."""
    key: Tuple[int, ...]
    payload: Any
    home: int
    block: Optional[int] = None
    children: Dict[Tuple[int, ...], "PublishedPage"] = \
        dataclasses.field(default_factory=dict)
    parent: Optional["PublishedPage"] = None
    last_access: int = 0
    # per-borrower lease hit-count: instance id -> #times that instance has
    # served this page through a RemoteLease. The router's auto decision
    # reads it as the expected-reuse estimate (repeat traffic amortizes a
    # copy), and promote-to-copy triggers off it.
    lease_hits: Dict[int, int] = dataclasses.field(default_factory=dict)


class PrefixShareBoard:
    """Global radix of published pages. Lives on the gManager.

    ``max_pages`` caps the resident page count (LRU leaf eviction on
    publish); ``None`` = unbounded."""

    def __init__(self, max_pages: Optional[int] = None):
        self._root = PublishedPage(key=(), payload=None, home=-1)
        self.page_size: Optional[int] = None
        # KVPageLayout schema tag of every payload on this board (first
        # publisher pins it). Payloads with a different schema are refused:
        # a GQA page adopted into an MLA pool (or vice versa) would be
        # silently-reinterpreted garbage, not a graceful miss.
        self.schema: Optional[str] = None
        self.max_pages = max_pages
        # zero-copy lending hooks, set by the cluster router when borrowed
        # rBlock serving is enabled: ``on_pin(home, block)`` fires when a
        # page's home block becomes referenced by the board (the router
        # increfs it on the home allocator so neither the home's cache
        # eviction nor request teardown can free a lendable page);
        # ``on_unpin`` fires when board eviction drops the page.
        self.on_pin: Optional[Callable[[int, int], None]] = None
        self.on_unpin: Optional[Callable[[int, int], None]] = None
        self._clock = 0
        self.num_pages = 0
        # telemetry: the cluster's Tracer (wired by the router — the board
        # is coordinator state, so its events land on the router track)
        self.trace = None
        # stats
        self.published_pages = 0
        self.publications = 0
        self.lookups = 0
        self.hit_pages = 0
        self.evicted_pages = 0

    def publish(self, instance_id: int, tokens: Sequence[int],
                payloads: Sequence[Any], page_size: int,
                blocks: Optional[Sequence[int]] = None,
                schema: Optional[str] = None) -> int:
        """Publish a page-aligned path: page ``i`` holds
        ``tokens[i*ps:(i+1)*ps]`` with KV contents ``payloads[i]``.
        Pages already on the board are kept (first publisher wins — the
        payloads are equivalent by construction). ``blocks`` (optional)
        offers the publisher's physical page ids for zero-copy lending;
        each newly-recorded block is pinned via :attr:`on_pin`. ``schema``
        is the publisher's ``KVPageLayout.schema`` tag; like ``page_size``
        it must match across all publishers of one board. Returns
        #pages added."""
        if self.page_size is None:
            self.page_size = page_size
        elif self.page_size != page_size:
            raise ValueError(
                f"mixed page sizes on one board: {self.page_size} vs "
                f"{page_size} — cross-instance pages must be interchangeable")
        if schema is not None:
            if self.schema is None:
                self.schema = schema
            elif self.schema != schema:
                raise ValueError(
                    f"KV layout schema mismatch on one board: "
                    f"{self.schema!r} vs {schema!r} — refusing to publish "
                    "pages a peer with a different layout could adopt as "
                    "garbage")
        node, new = self._root, 0
        self._clock += 1
        for i in range(len(tokens) // page_size):
            key = tuple(tokens[i * page_size:(i + 1) * page_size])
            block = blocks[i] if blocks is not None else None
            child = node.children.get(key)
            if child is None:
                child = PublishedPage(key=key, payload=payloads[i],
                                      home=instance_id, parent=node)
                node.children[key] = child
                new += 1
                self.num_pages += 1
            elif child.payload is None and payloads[i] is not None:
                # a bookkeeping-only publication (sim) upgraded with real
                # page contents: engine adopters can now use the page. The
                # lendable block moves with the new home — unpin the old
                # lender's page first so its pin is returned.
                if child.block is not None and self.on_unpin is not None:
                    self.on_unpin(child.home, child.block)
                child.block = None
                child.payload = payloads[i]
                child.home = instance_id
            if block is not None and child.block is None \
                    and child.home == instance_id:
                # the home offers this page for lending: pin it so the home
                # side cannot free a block a peer may borrow
                child.block = block
                if self.on_pin is not None:
                    self.on_pin(child.home, block)
            child.last_access = self._clock
            node = child
        self.published_pages += new
        self.publications += 1
        if self.trace is not None:
            self.trace.instant("board", "publish", home=instance_id, new=new,
                               resident=self.num_pages)
        if self.max_pages is not None and self.num_pages > self.max_pages:
            self._evict(self.num_pages - self.max_pages)
        return new

    def covered(self, tokens: Sequence[int]) -> int:
        """#leading pages of ``tokens`` already on the board *with a
        payload* (stat-free). Publishers skip exporting those — payload
        export is a device->host page copy on engines — but still supply
        payloads for payload-less pages so a bookkeeping-only (sim)
        publication gets upgraded."""
        if self.page_size is None:
            return 0
        ps = self.page_size
        node, n = self._root, 0
        for i in range(len(tokens) // ps):
            node = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if node is None or node.payload is None:
                break
            n += 1
        return n

    def match(self, tokens: Sequence[int], *,
              max_tokens: Optional[int] = None) -> List[PublishedPage]:
        """Longest published page chain prefixing ``tokens`` (may be empty)."""
        if self.page_size is None:
            return []
        ps = self.page_size
        limit = len(tokens) if max_tokens is None else \
            min(max_tokens, len(tokens))
        node, path = self._root, []
        self._clock += 1
        for i in range(limit // ps):
            child = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            child.last_access = self._clock
            path.append(child)
            node = child
        self.lookups += 1
        self.hit_pages += len(path)
        if self.trace is not None:
            self.trace.instant("board", "lookup", hit_pages=len(path))
        return path

    def record_lease(self, instance_id: int,
                     pages: Sequence[PublishedPage]) -> int:
        """Count one committed lease by ``instance_id`` over ``pages``.

        Returns the updated hit-count of the *deepest* page — the value the
        router uses as the (instance, prefix) reuse estimate, since the
        deepest page identifies the full leased prefix."""
        n = 0
        for page in pages:
            n = page.lease_hits.get(instance_id, 0) + 1
            page.lease_hits[instance_id] = n
        if self.trace is not None:
            self.trace.instant("board", "lease_hit", instance=instance_id,
                               pages=len(pages), hits=n)
        return n

    def lease_hits_of(self, instance_id: int,
                      pages: Sequence[PublishedPage]) -> int:
        """Prior lease count of ``instance_id`` on a matched chain (the
        deepest page's count — 0 if the chain is empty or never leased)."""
        if not pages:
            return 0
        return pages[-1].lease_hits.get(instance_id, 0)

    # -- eviction ---------------------------------------------------------------
    def _evict(self, n: int) -> int:
        """Drop ``n`` least-recently-used leaf pages (payloads freed with
        them). Leaf-only eviction keeps every surviving root path intact;
        evicting a leaf can expose its parent as the new oldest leaf, so a
        min-heap over the dynamic leaf set implements strict LRU — a cold
        path ages out tail-first until it is gone — in one tree walk plus
        O(log) per drop, not a walk per dropped page."""
        heap: List[Tuple[int, int, PublishedPage]] = []
        seq = 0  # heap tiebreak: PublishedPage is not orderable
        stack = [self._root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                if ch.children:
                    stack.append(ch)
                else:
                    heap.append((ch.last_access, seq, ch))
                    seq += 1
        heapq.heapify(heap)
        dropped = 0
        while dropped < n and heap:
            _, _, leaf = heapq.heappop(heap)
            parent = leaf.parent
            del parent.children[leaf.key]
            leaf.parent = None
            if leaf.block is not None and self.on_unpin is not None:
                # return the lending pin: the home may free the page again
                # (outstanding leases hold their own references)
                self.on_unpin(leaf.home, leaf.block)
            self.num_pages -= 1
            dropped += 1
            if parent is not self._root and not parent.children:
                heapq.heappush(heap, (parent.last_access, seq, parent))
                seq += 1
        self.evicted_pages += dropped
        if self.trace is not None:
            self.trace.instant("board", "evict", dropped=dropped,
                               resident=self.num_pages)
        return dropped

    def stats(self) -> Dict[str, int]:
        return {
            "published_pages": self.published_pages,
            "publications": self.publications,
            "lookups": self.lookups,
            "hit_pages": self.hit_pages,
            "resident_pages": self.num_pages,
            "evicted_pages": self.evicted_pages,
        }
