"""vLLM paging + ORCA scheduling: unit + hypothesis property tests."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.paging import (BlockAllocator, BlockTable,
                               ContiguousPreallocAllocator, OutOfBlocks)
from repro.core.scheduling import (BatchScheduler, IterationScheduler, Phase,
                                   Request)


# -- allocator ----------------------------------------------------------------

def test_alloc_free_roundtrip():
    a = BlockAllocator(4, 16)
    t = BlockTable()
    a.append_tokens(t, 40)  # 3 blocks
    assert len(t.blocks) == 3 and a.num_free == 1
    a.free_table(t)
    assert a.num_free == 4 and not a.refcount


def test_out_of_blocks():
    a = BlockAllocator(2, 16)
    t = BlockTable()
    with pytest.raises(OutOfBlocks):
        a.append_tokens(t, 33)  # needs 3 blocks


def test_fork_shares_and_cow():
    a = BlockAllocator(8, 16)
    t = BlockTable()
    a.append_tokens(t, 24)  # 2 blocks, 2nd half-full
    f = a.fork(t)
    assert f.blocks == t.blocks
    assert a.refcount[t.blocks[0]] == 2
    # writing to the fork's shared half-full tail must COW
    tail_before = f.blocks[-1]
    a.append_tokens(f, 1)
    assert f.blocks[-1] != tail_before, "tail block must be copied on write"
    assert a.refcount[t.blocks[-1]] == 1
    a.free_table(t)
    a.free_table(f)
    assert a.num_free == 8


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["grow", "fork", "free"]),
                          st.integers(1, 40)), min_size=1, max_size=60))
def test_allocator_conservation_property(ops):
    """Property: used+free == total; refcounts positive; utilization <= 1."""
    a = BlockAllocator(64, 8)
    tables = [BlockTable()]
    a.append_tokens(tables[0], 8)
    for op, arg in ops:
        t = tables[arg % len(tables)]
        try:
            if op == "grow":
                a.append_tokens(t, arg)
            elif op == "fork":
                tables.append(a.fork(t))
            elif op == "free" and len(tables) > 1:
                a.free_table(t)
                tables.remove(t)
        except OutOfBlocks:
            pass
        assert a.num_free + len(a.refcount) == 64
        assert all(v > 0 for v in a.refcount.values())
        assert 0.0 <= a.utilization(tables) <= 1.0
    for t in tables:
        a.free_table(t)
    assert a.num_free == 64


def test_fork_family_cow_tail_and_refcounted_free():
    """Three-way fork family: every fork writing into the shared half-full
    tail COWs to its own copy; frees in any order return every block."""
    a = BlockAllocator(16, 8)
    root = BlockTable()
    a.append_tokens(root, 12)  # 2 blocks, tail half-full
    forks = [a.fork(root) for _ in range(2)]
    assert a.refcount_of(root.blocks[0]) == 3
    shared_tail = root.blocks[-1]
    for f in forks:
        a.append_tokens(f, 2)
        assert f.blocks[-1] != shared_tail, "fork write must COW the tail"
    # root still owns the original tail and may write it in place now that
    # the forks have moved off it
    assert a.refcount_of(shared_tail) == 1
    a.append_tokens(root, 2)
    assert root.blocks[-1] == shared_tail
    a.free_table(forks[0])
    a.free_table(root)
    assert a.refcount_of(forks[1].blocks[0]) == 1, \
        "surviving fork keeps the shared prompt block alive"
    a.free_table(forks[1])
    assert a.num_free == 16 and not a.refcount


def test_decref_double_free_raises():
    a = BlockAllocator(4, 8)
    t = BlockTable()
    a.append_tokens(t, 8)
    b = t.blocks[0]
    a.decref(b)
    with pytest.raises(ValueError, match="double free|unknown"):
        a.decref(b)


def test_incref_unknown_block_raises():
    a = BlockAllocator(4, 8)
    with pytest.raises(ValueError, match="unallocated"):
        a.incref(3)
    with pytest.raises(ValueError, match="unallocated"):
        a.incref(99)


def test_refcount_of():
    a = BlockAllocator(4, 8)
    t = BlockTable()
    a.append_tokens(t, 8)
    b = t.blocks[0]
    assert a.refcount_of(b) == 1
    a.incref(b)
    assert a.refcount_of(b) == 2
    a.decref(b)
    a.decref(b)
    assert a.refcount_of(b) == 0  # free blocks report 0, no KeyError


def test_prealloc_policies():
    p = ContiguousPreallocAllocator(10_000, 2048, "max")
    assert p.reservation(100) == 2048
    p = ContiguousPreallocAllocator(10_000, 2048, "pow2")
    assert p.reservation(100) == 128
    p = ContiguousPreallocAllocator(10_000, 2048, "oracle")
    assert p.reservation(100) == 100


# -- iteration scheduler -------------------------------------------------------

def _reqs(n, plen=8, out=4):
    return [Request(i, 0.0, list(range(plen)), max_new_tokens=out)
            for i in range(n)]


def test_iteration_scheduler_basic_flow():
    a = BlockAllocator(64, 8)
    s = IterationScheduler(a, max_running=4, max_tokens_per_iter=64)
    for r in _reqs(2):
        s.add_request(r)
    plan = s.schedule()
    assert len(plan.prefill) == 2 and not plan.decode
    for r in plan.prefill:
        r.output.append(0)
    s.complete_iteration(plan, now=1.0)
    plan2 = s.schedule()
    assert len(plan2.decode) == 2 and not plan2.prefill


def test_early_finish_leaves_immediately():
    """ORCA C1: a finished request frees its slot for a late-joiner."""
    a = BlockAllocator(64, 8)
    s = IterationScheduler(a, max_running=1, max_tokens_per_iter=64)
    short = Request(0, 0.0, [1, 2], max_new_tokens=1)
    s.add_request(short)
    plan = s.schedule()
    short.output.append(0)
    finished = s.complete_iteration(plan, 1.0)
    assert finished == [short]
    late = Request(1, 1.0, [1, 2, 3], max_new_tokens=2)
    s.add_request(late)
    plan = s.schedule()
    assert plan.prefill == [late], "late joiner admitted right away"


def test_preemption_recompute_preserves_output():
    # 12 blocks x 8 = 96 token slots: each request needs 80 at completion,
    # so both can't stay resident (preemption) but each alone fits
    a = BlockAllocator(12, 8)
    s = IterationScheduler(a, max_running=4, max_tokens_per_iter=999)
    r1 = Request(0, 0.0, list(range(16)), max_new_tokens=64)
    r2 = Request(1, 0.0, list(range(16)), max_new_tokens=64)
    s.add_request(r1)
    s.add_request(r2)
    preempted_seen = 0
    for it in range(200):
        plan = s.schedule()
        if plan.empty:
            break
        preempted_seen += len(plan.preempted)
        for r in plan.prefill + plan.decode:
            r.output.append(it)
        s.complete_iteration(plan, float(it))
        if r1.phase == Phase.FINISHED and r2.phase == Phase.FINISHED:
            break
    assert r1.total_generated >= 64 and r2.total_generated >= 64
    assert preempted_seen > 0, "test config should force preemption"


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_scheduler_never_leaks_blocks(seed):
    """Property: after all requests finish, every block is free."""
    rng = np.random.default_rng(seed)
    a = BlockAllocator(32, 8)
    s = IterationScheduler(a, max_running=4, max_tokens_per_iter=128)
    reqs = [Request(i, 0.0, list(range(int(rng.integers(1, 30)))),
                    max_new_tokens=int(rng.integers(1, 20)))
            for i in range(6)]
    for r in reqs:
        s.add_request(r)
    for it in range(500):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            break
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, float(it))
    assert all(r.phase == Phase.FINISHED for r in reqs)
    assert a.num_free == 32 and not a.refcount


def test_preemption_victim_leaves_decode_plan():
    """A victim picked after it already joined this iteration's decode batch
    must be rescinded from the plan — otherwise the engine decodes a request
    whose block table was just freed (KeyError downstream)."""
    a = BlockAllocator(6, 4)
    s = IterationScheduler(a, max_running=4, max_tokens_per_iter=999)
    ra = Request(0, 0.0, list(range(7)), max_new_tokens=50)
    rb = Request(1, 0.0, list(range(8)), max_new_tokens=50)
    s.add_request(ra)
    s.add_request(rb)
    preempted_seen = 0
    for it in range(60):  # joint demand exceeds the pool -> steady thrash
        plan = s.schedule()
        if plan.empty and not s.waiting:
            break
        preempted_seen += len(plan.preempted)
        assert not (set(r.request_id for r in plan.decode)
                    & set(r.request_id for r in plan.preempted)), \
            "request scheduled to decode AND preempted in one iteration"
        for r in plan.decode:
            assert r.request_id in s.tables, "decode entry with freed table"
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, float(it))
    assert preempted_seen > 0, "test config should force preemption"


def test_batch_scheduler_holds_until_batch_done():
    s = BatchScheduler(max_batch=2)
    for r in _reqs(3):
        s.add_request(r)
    plan = s.schedule()
    assert len(plan.batch) == 2
    # scheduling again before completion returns the same batch
    assert s.schedule().batch == plan.batch
    s.complete_batch(now=5.0)
    assert len(s.schedule().batch) == 1
