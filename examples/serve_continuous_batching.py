"""ORCA iteration-level scheduling + vLLM paging on a real model, behind the
LLMService front-end: requests arrive over time, join mid-flight, finish
early, and (with tight memory) get preempted and recomputed — watch the
service stream chunks as the engine iterates.

  PYTHONPATH=src python examples/serve_continuous_batching.py
"""

import numpy as np

import jax

from repro.configs import smoke_config
from repro.models import Model
from repro.serving.api import LLMService, SamplingParams
from repro.serving.engine import EngineConfig, PagedEngine


def main():
    cfg = smoke_config("h2o-danube-1.8b")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=48, page_size=8, max_slots=3,  # tight: shows preemption
        max_tokens_per_iter=256))
    svc = LLMService(eng)

    rng = np.random.default_rng(7)
    for i in range(8):
        plen = int(rng.integers(6, 20))
        rid = svc.submit(
            rng.integers(2, cfg.vocab_size, plen).tolist(),
            SamplingParams(max_new_tokens=int(rng.integers(4, 16))),
            arrival_time=i * 0.5)
        print(f"submitted request {rid} (prompt {plen} tok)")

    it = 0
    while svc.pending and it < 500:
        # virtual time: 2 engine iterations ~ 1 "second" of arrivals
        for ch in svc.poll(now=it / 2):
            if ch.finished:
                print(f"[iter {it:3d}] - request {ch.request_id} done: "
                      f"{ch.n_generated} tokens ({ch.finish_reason})")
        it += 1
    print(f"\n{it} iterations, kv pages free "
          f"{eng.allocator.num_free}/{eng.allocator.num_blocks}")
    out = svc.stats()
    print(f"served {out.n_finished}/{out.n_requests} requests, "
          f"{out.preemptions} preemptions")


if __name__ == "__main__":
    main()
