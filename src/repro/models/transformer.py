"""Composable decoder(-encoder) transformer covering all assigned families.

A model is a sequence of **segments**: runs of structurally-identical layers
whose stacked parameters run under one ``lax.scan`` (keeps HLO compact for the
88-layer dry-runs) while *different* segments may differ in layer type, attn
kind, or cache capacity. Examples:

* deepseek-v2: ``[1 x (mla+dense-mlp), 59 x (mla+moe)]``
* hymba:       ``[1 x global-hybrid, 15 x swa-hybrid] x 2``
* mamba2:      ``[48 x ssm]``
* danube:      ``[24 x swa-dense]``

Sliding-window segments allocate ring-buffer caches of capacity
``min(window, seq)`` — this is what bounds ``long_500k`` memory.

Decode caches are lists (one entry per segment) of:

* gqa:    ``KVCache``            (ring buffer; +``{"ck","cv"}`` cross-KV for enc-dec)
* mla:    ``MLACache``           (compressed latent — the paged-MLA cache)
* ssm:    ``SSMCache``           (conv window + SSD state)
* hybrid: ``(KVCache, SSMCache)``
"""

from __future__ import annotations

import dataclasses
from typing import Any, List

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (NO_POLICY, ShardingPolicy, cross_entropy,
                                 embed, embed_init, mlp, mlp_init, norm_init,
                                 rms_norm, unembed)


@dataclasses.dataclass(frozen=True)
class Segment:
    n: int
    mixer: str  # "gqa" | "mla" | "ssm" | "hybrid"
    mlp_kind: str  # "dense" | "moe" | "none"
    attn_kind: str  # "global" | "swa" | "none"
    cross: bool = False  # decoder cross-attention (enc-dec)


def stack_plan(cfg: ArchConfig) -> List[Segment]:
    if cfg.family == "ssm":
        return [Segment(cfg.num_layers, "ssm",
                        "dense" if cfg.d_ff else "none", "none")]
    if cfg.is_hybrid:
        segs, i = [], 0
        every = cfg.global_attn_every or cfg.num_layers
        while i < cfg.num_layers:
            segs.append(Segment(1, "hybrid", "dense", "global"))
            run = min(every - 1, cfg.num_layers - i - 1)
            if run > 0:
                segs.append(Segment(run, "hybrid", "dense", "swa"))
            i += 1 + run
        return segs
    mixer = "mla" if cfg.attention == "mla" else "gqa"
    attn_kind = "swa" if cfg.sliding_window else "global"
    if cfg.is_moe:
        segs = []
        if cfg.first_k_dense:
            segs.append(Segment(cfg.first_k_dense, mixer, "dense", attn_kind,
                                cross=cfg.is_encdec))
        segs.append(Segment(cfg.num_layers - cfg.first_k_dense, mixer, "moe",
                            attn_kind, cross=cfg.is_encdec))
        return segs
    return [Segment(cfg.num_layers, mixer, "dense", attn_kind,
                    cross=cfg.is_encdec)]


def encoder_plan(cfg: ArchConfig) -> List[Segment]:
    return [Segment(cfg.encoder_layers, "gqa", "dense", "global")]


# ---------------------------------------------------------------------------
# per-layer init / forward
# ---------------------------------------------------------------------------

def _layer_init(cfg: ArchConfig, seg: Segment, key, dtype):
    ks = jax.random.split(key, 8)
    p: dict = {"ln1": norm_init(cfg.d_model, dtype, bias=cfg.use_bias)}
    if seg.mixer in ("gqa", "hybrid"):
        p["attn"] = attn.gqa_init(cfg, ks[0], dtype)
    if seg.mixer == "mla":
        p["attn"] = attn.mla_init(cfg, ks[0], dtype)
    if seg.mixer in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.ssm_init(cfg, ks[1], dtype)
    if seg.cross:
        p["ln_cross"] = norm_init(cfg.d_model, dtype, bias=cfg.use_bias)
        p["cross"] = attn.gqa_init(cfg, ks[2], dtype)
    if seg.mlp_kind == "moe":
        p["ln2"] = norm_init(cfg.d_model, dtype, bias=cfg.use_bias)
        p["mlp"] = moe_mod.moe_init(cfg, ks[3], dtype)
    elif seg.mlp_kind == "dense":
        p["ln2"] = norm_init(cfg.d_model, dtype, bias=cfg.use_bias)
        p["mlp"] = mlp_init(ks[3], cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.gated_mlp, bias=cfg.use_bias)
    return p


def _layer_forward(cfg, seg: Segment, p, x, positions, *, policy,
                   enc_out=None, causal=True, collect_cache=False):
    """Full-sequence layer. Returns (x, aux, cache_seed).

    ``cache_seed`` (when ``collect_cache``) carries what decode needs:
    gqa -> (k, v); mla -> (ckv, krope); ssm -> SSMCache;
    hybrid -> ((k, v), SSMCache); +(ck, cv) appended for cross layers.
    """
    window = cfg.sliding_window if seg.attn_kind == "swa" else None
    if seg.mixer == "gqa" and seg.mlp_kind == "dense" and not seg.cross:
        # plain GQA layer: share the one layer body with the serving
        # engine's decode / suffix-prefill paths (attend = full-sequence
        # blockwise attention; carry = the prefill cache seed)
        def attend(q, k, v):
            ctx = attn.blockwise_attention(q, k, v, causal=causal,
                                           window=window, policy=policy)
            return ctx, ((k, v) if collect_cache else None)

        x, seed = attn.gqa_layer(cfg, p, x, positions, attend, policy=policy)
        return x, jnp.zeros((), jnp.float32), seed
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    seed = None
    if seg.mixer == "gqa":
        out = attn.gqa_forward(cfg, p["attn"], h, positions, window=window,
                               causal=causal, policy=policy,
                               return_kv=collect_cache)
        if collect_cache:
            out, seed = out
        x = x + out
    elif seg.mixer == "mla":
        out = attn.mla_forward(cfg, p["attn"], h, positions, policy=policy,
                               return_latent=collect_cache)
        if collect_cache:
            out, seed = out
        x = x + out
    elif seg.mixer == "ssm":
        out = ssm_mod.ssm_forward(cfg, p["ssm"], h, policy=policy,
                                  return_cache=collect_cache)
        if collect_cache:
            out, seed = out
        x = x + out
    elif seg.mixer == "hybrid":
        a = attn.gqa_forward(cfg, p["attn"], h, positions, window=window,
                             causal=causal, policy=policy,
                             return_kv=collect_cache)
        m = ssm_mod.ssm_forward(cfg, p["ssm"], h, policy=policy,
                                return_cache=collect_cache)
        if collect_cache:
            a, kv = a
            m, sc = m
            seed = (kv, sc)
        x = x + 0.5 * (a + m)  # hymba: parallel heads, averaged fusion
    if seg.cross and enc_out is not None:
        hc = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        ckv = attn.encode_kv(cfg, p["cross"], enc_out)
        x = x + attn.gqa_forward(cfg, p["cross"], hc, positions, causal=False,
                                 policy=policy, kv_override=ckv)
        if collect_cache:
            seed = (seed, ckv)
    if seg.mlp_kind == "moe":
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        out, aux = moe_mod.moe_forward(cfg, p["mlp"], h2, policy=policy,
                                       return_aux=True)
        x = x + out
    elif seg.mlp_kind == "dense":
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, policy)
    return x, aux, seed


def _layer_decode(cfg, seg: Segment, p, x, pos, cache, *, policy):
    """One-token layer step against the cache. Returns (x, new_cache)."""
    window = cfg.sliding_window if seg.attn_kind == "swa" else None
    cross_kv = None
    if seg.cross:
        cross_kv = (cache["ck"], cache["cv"])
        cache_self = cache["self"]
    else:
        cache_self = cache
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    if seg.mixer == "gqa":
        out, cache_self = attn.gqa_decode(cfg, p["attn"], h, cache_self, pos,
                                          window=window, policy=policy)
        x = x + out
    elif seg.mixer == "mla":
        out, cache_self = attn.mla_decode(cfg, p["attn"], h, cache_self, pos,
                                          policy=policy)
        x = x + out
    elif seg.mixer == "ssm":
        out, cache_self = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache_self,
                                             policy=policy)
        x = x + out
    elif seg.mixer == "hybrid":
        kv_c, ssm_c = cache_self
        a, kv_c = attn.gqa_decode(cfg, p["attn"], h, kv_c, pos, window=window,
                                  policy=policy)
        m, ssm_c = ssm_mod.ssm_decode(cfg, p["ssm"], h, ssm_c, policy=policy)
        x = x + 0.5 * (a + m)
        cache_self = (kv_c, ssm_c)
    if seg.cross:
        hc = rms_norm(p["ln_cross"], x, cfg.norm_eps)
        out, _ = attn.gqa_decode(cfg, p["cross"], hc, None, pos,
                                 policy=policy, kv_override=cross_kv)
        x = x + out
        cache = dict(cache, self=cache_self)
    else:
        cache = cache_self
    if seg.mlp_kind == "moe":
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        x = x + moe_mod.moe_forward(cfg, p["mlp"], h2, policy=policy)
    elif seg.mlp_kind == "dense":
        h2 = rms_norm(p["ln2"], x, cfg.norm_eps)
        x = x + mlp(p["mlp"], h2, policy)
    return x, cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def segment_cache_capacity(cfg, seg: Segment, seq_len: int) -> int:
    if seg.attn_kind == "swa" and cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def _empty_segment_cache(cfg, seg: Segment, batch: int, seq_len: int, dtype,
                         as_specs: bool, enc_len: int = 0):
    cap = segment_cache_capacity(cfg, seg, seq_len)

    def mk(shape, dt, stack=True):
        shape = ((seg.n,) + shape) if (seg.n > 1 and stack) else shape
        if as_specs:
            return jax.ShapeDtypeStruct(shape, dt)
        fill = -1 if dt == jnp.int32 else 0
        return jnp.full(shape, fill, dt)

    def kv():
        return attn.KVCache(
            k=mk((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
            v=mk((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
            pos=mk((batch, cap), jnp.int32))

    def mla():
        return attn.MLACache(
            ckv=mk((batch, cap, cfg.kv_lora_rank), dtype),
            krope=mk((batch, cap, cfg.qk_rope_head_dim), dtype),
            pos=mk((batch, cap), jnp.int32))

    def ssmc():
        return ssm_mod.SSMCache(
            conv=mk((batch, cfg.ssm_conv_width - 1,
                     cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state),
                    dtype),
            state=mk((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                     jnp.float32))

    base = {"gqa": kv, "mla": mla, "ssm": ssmc,
            "hybrid": lambda: (kv(), ssmc())}[seg.mixer]()
    if seg.cross:
        ck = mk((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        cv = mk((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        return {"self": base, "ck": ck, "cv": cv}
    return base


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ArchConfig, *, remat: bool = True,
                 unroll_layers: bool = False):
        """``unroll_layers``: fully unroll the layer scans. The dry-run uses
        this so ``cost_analysis`` counts every layer (XLA costs a while-loop
        body once regardless of trip count)."""
        self.cfg = cfg
        self.plan = stack_plan(cfg)
        self.enc_plan = encoder_plan(cfg) if cfg.is_encdec else []
        self.remat = remat
        self.unroll_layers = unroll_layers

    def _unroll(self, seg_n: int) -> int:
        return seg_n if self.unroll_layers else 1

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Any:
        cfg = self.cfg
        dtype = cfg.param_dtype
        k_embed, k_dec, k_enc, _ = jax.random.split(key, 4)
        params = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
            "final_norm": norm_init(cfg.d_model, dtype, bias=cfg.use_bias),
            "segments": self._init_segments(self.plan, k_dec, dtype),
        }
        if cfg.is_encdec:
            params["encoder"] = {
                "segments": self._init_segments(self.enc_plan, k_enc, dtype),
                "final_norm": norm_init(cfg.d_model, dtype, bias=cfg.use_bias),
            }
        return params

    def _init_segments(self, plan, key, dtype):
        segs = []
        keys = jax.random.split(key, max(len(plan), 1))
        for seg, k in zip(plan, keys):
            if seg.n == 1:
                segs.append(_layer_init(self.cfg, seg, k, dtype))
            else:
                segs.append(jax.vmap(
                    lambda kk, seg=seg: _layer_init(self.cfg, seg, kk, dtype))(
                        jax.random.split(k, seg.n)))
        return segs

    # -- stacks ---------------------------------------------------------------
    def _run_stack(self, plan, seg_params, x, positions, *, policy,
                   enc_out=None, causal=True, collect_cache=False):
        aux_total = jnp.zeros((), jnp.float32)
        seeds = []
        for seg, p in zip(plan, seg_params):
            if seg.n == 1:
                x, aux, seed = _layer_forward(
                    self.cfg, seg, p, x, positions, policy=policy,
                    enc_out=enc_out, causal=causal,
                    collect_cache=collect_cache)
                aux_total += aux
                seeds.append(seed)
                continue

            def body(carry, p_i, seg=seg):
                xx, aux_acc = carry
                xx, aux, seed = _layer_forward(
                    self.cfg, seg, p_i, xx, positions, policy=policy,
                    enc_out=enc_out, causal=causal,
                    collect_cache=collect_cache)
                return (xx, aux_acc + aux), seed

            fn = jax.checkpoint(body) if self.remat else body
            (x, aux_total), seed = lax.scan(fn, (x, aux_total), p,
                                            unroll=self._unroll(seg.n))
            seeds.append(seed)
        return x, aux_total, seeds

    def _embed_with_media(self, params, tokens, media, policy):
        x = embed(params["embed"], tokens, policy)
        if media is not None:
            m = media.shape[1]
            x = jnp.concatenate([media.astype(x.dtype), x[:, m:]], axis=1)
        return x

    def encode(self, params, encoder_tokens, media, policy):
        x = self._embed_with_media(params, encoder_tokens, media, policy)
        positions = jnp.arange(x.shape[1])
        x, _, _ = self._run_stack(self.enc_plan, params["encoder"]["segments"],
                                  x, positions, policy=policy, causal=False)
        return rms_norm(params["encoder"]["final_norm"], x, self.cfg.norm_eps)

    # -- public API ----------------------------------------------------------
    def forward(self, params, tokens, *, media=None, encoder_tokens=None,
                policy: ShardingPolicy = NO_POLICY):
        """Teacher-forced logits (B, S, V) + MoE aux loss."""
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, encoder_tokens,
                                  media if cfg.frontend == "audio" else None,
                                  policy)
            media = None if cfg.frontend == "audio" else media
        x = self._embed_with_media(params, tokens, media, policy)
        positions = jnp.arange(x.shape[1])
        x, aux, _ = self._run_stack(self.plan, params["segments"], x,
                                    positions, policy=policy, enc_out=enc_out)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size, policy,
                         fp32=cfg.logits_fp32)
        return logits, aux

    def loss(self, params, batch, *, policy: ShardingPolicy = NO_POLICY):
        logits, aux = self.forward(
            params, batch["tokens"], media=batch.get("media"),
            encoder_tokens=batch.get("encoder_tokens"), policy=policy)
        ce = cross_entropy(logits[:, :-1], batch["labels"][:, 1:],
                           self.cfg.vocab_size)
        return ce + 0.01 * aux

    # -- caches ---------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, *, as_specs: bool = False,
                   enc_len: int = 0):
        dtype = self.cfg.param_dtype
        return [_empty_segment_cache(self.cfg, seg, batch, seq_len, dtype,
                                     as_specs, enc_len)
                for seg in self.plan]

    def prefill(self, params, tokens, *, seq_capacity: int, media=None,
                encoder_tokens=None, last_idx=None, return_raw_kv=False,
                policy: ShardingPolicy = NO_POLICY):
        """Full prompt pass. Returns (last-pos logits (B,V), decode caches).

        ``return_raw_kv``: return the raw full-length per-segment cache seeds
        instead of ring-buffer caches (the paged engine scatters these into
        physical pages itself)."""
        cfg = self.cfg
        b, s = tokens.shape
        enc_out = None
        if cfg.is_encdec:
            enc_out = self.encode(params, encoder_tokens,
                                  media if cfg.frontend == "audio" else None,
                                  policy)
            media = None if cfg.frontend == "audio" else media
        x = self._embed_with_media(params, tokens, media, policy)
        positions = jnp.arange(s)
        x, _, seeds = self._run_stack(self.plan, params["segments"], x,
                                      positions, policy=policy,
                                      enc_out=enc_out, collect_cache=True)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        if last_idx is None:
            last_idx = jnp.full((b,), s - 1, jnp.int32)
        last_h = x[jnp.arange(b), last_idx]
        logits = unembed(params["embed"], last_h[:, None, :], cfg.vocab_size,
                         policy, fp32=cfg.logits_fp32)
        if return_raw_kv:
            return logits[:, 0], seeds
        caches = self._seed_caches(seeds, b, s, seq_capacity)
        return logits[:, 0], caches

    def _seed_caches(self, seeds, b, s, capacity):
        """Convert prefill seeds into ring-buffer decode caches."""
        cfg = self.cfg
        positions = jnp.arange(s)
        caches = []
        for seg, seed in zip(self.plan, seeds):
            cross_kv = None
            if seg.cross:
                seed, cross_kv = seed
                seg = dataclasses.replace(seg, cross=False)  # base cache only
            cap = segment_cache_capacity(cfg, seg, capacity)
            take = min(cap, s)
            posvec = positions[s - take:]
            slots = posvec % cap

            if seg.mixer == "gqa":
                k, v = seed
                c = _empty_segment_cache(cfg, seg, b, capacity,
                                         cfg.param_dtype, False)
                c = attn.KVCache(
                    k=_ring_set(c.k, k, slots, take, s),
                    v=_ring_set(c.v, v, slots, take, s),
                    pos=_ring_set_pos(c.pos, posvec, slots, b))
            elif seg.mixer == "mla":
                ckv, krope = seed
                c = _empty_segment_cache(cfg, seg, b, capacity,
                                         cfg.param_dtype, False)
                c = attn.MLACache(
                    ckv=_ring_set(c.ckv, ckv, slots, take, s, ndims=1),
                    krope=_ring_set(c.krope, krope, slots, take, s, ndims=1),
                    pos=_ring_set_pos(c.pos, posvec, slots, b))
            elif seg.mixer == "ssm":
                c = seed  # SSMCache straight from the forward pass
            elif seg.mixer == "hybrid":
                (k, v), ssc = seed
                e = _empty_segment_cache(cfg, seg, b, capacity,
                                         cfg.param_dtype, False)
                kvc = attn.KVCache(
                    k=_ring_set(e[0].k, k, slots, take, s),
                    v=_ring_set(e[0].v, v, slots, take, s),
                    pos=_ring_set_pos(e[0].pos, posvec, slots, b))
                c = (kvc, ssc)
            if cross_kv is not None:
                ck, cv = cross_kv
                c = {"self": c, "ck": ck, "cv": cv}
            caches.append(c)
        return caches

    def decode_step(self, params, tokens, pos, caches, *,
                    policy: ShardingPolicy = NO_POLICY):
        """tokens: (B,1); pos: (B,). Returns (logits (B,V), new caches)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens, policy)
        new_caches = []
        for seg, p, cache in zip(self.plan, params["segments"], caches):
            if seg.n == 1:
                x, c = _layer_decode(cfg, seg, p, x, pos, cache,
                                     policy=policy)
                new_caches.append(c)
                continue

            def body(xx, pc, seg=seg):
                p_i, c_i = pc
                xx, c = _layer_decode(cfg, seg, p_i, xx, pos, c_i,
                                      policy=policy)
                return xx, c

            x, c = lax.scan(body, x, (p, cache),
                            unroll=self._unroll(seg.n))
            new_caches.append(c)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size, policy,
                         fp32=cfg.logits_fp32)
        return logits[:, 0], new_caches


def _ring_set(buf, new, slots, take, s, ndims: int = 2):
    """buf: ([n,]B,cap,T...); new: ([n,]B,s,T...) — write last ``take`` tokens
    of ``new`` into ring slots. ``ndims`` = trailing dims after the seq axis."""
    new = new.astype(buf.dtype)
    sl = (Ellipsis, slice(s - take, s)) + (slice(None),) * ndims
    dst = (Ellipsis, slots) + (slice(None),) * ndims
    return buf.at[dst].set(new[sl])


def _ring_set_pos(buf, posvec, slots, b):
    """buf: ([n,]B,cap) int32; write absolute positions into ring slots."""
    val = jnp.broadcast_to(posvec, (b, posvec.shape[0]))
    if buf.ndim == 3:
        val = jnp.broadcast_to(val, (buf.shape[0],) + val.shape)
    return buf.at[..., slots].set(val)
