"""Paged-attention decode kernel (vLLM PagedAttention, TPU-native).

GPU vLLM walks a block table per warp and gathers KV from scattered global
memory. The TPU adaptation (DESIGN.md §2.3): the block table is a
**scalar-prefetch operand**; each grid step DMAs one logical KV page
(``(page_size, kv_heads, head_dim)``) HBM→VMEM via the ``BlockSpec`` index_map,
and an **online-softmax accumulator** in VMEM scratch merges pages — the same
math as flash-decoding, driven by the page table.

Grid: ``(batch, pages_per_seq)``; the page axis is ``arbitrary`` (sequential)
so the scratch accumulator carries across pages of one sequence.

Outputs optionally include the ``(m, l)`` partials instead of the normalized
value — that is the *Micro Attention* interface of InfiniteLLM's
DistAttention: shard-local partial results merged later with a stable
log-sum-exp (see ``repro.core.distkv.dist_attention``).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _paged_attn_kernel(
    # scalar prefetch
    block_tables_ref,  # (B, pages_per_seq) int32
    context_lens_ref,  # (B,) int32
    # inputs
    q_ref,       # (1, Hkv, G, Dh)
    k_page_ref,  # (1, page_size, Hkv, Dh)
    v_page_ref,  # (1, page_size, Hkv, Dh)
    # outputs
    o_ref,       # (1, Hkv, G, Dh)
    m_out_ref,   # (1, Hkv, G)   running max   (partials)
    l_out_ref,   # (1, Hkv, G)   running sum-exp (partials)
    # scratch
    m_ref,   # (Hkv, G)
    l_ref,   # (Hkv, G)
    acc_ref,  # (Hkv, G, Dh)
    *,
    page_size: int,
    pages_per_seq: int,
    window: Optional[int],
    scale: float,
):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = context_lens_ref[b]
    # absolute token positions held by this logical page
    pos = i * page_size + jax.lax.iota(jnp.int32, page_size)
    valid = pos < ctx
    if window is not None:
        valid &= pos > ctx - 1 - window

    q = q_ref[0].astype(jnp.float32)         # (Hkv, G, Dh)
    k = k_page_ref[0].astype(jnp.float32)    # (P, Hkv, Dh)
    v = v_page_ref[0].astype(jnp.float32)

    s = jnp.einsum("hgd,phd->hgp", q, k) * scale  # (Hkv, G, P)
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_cur = jnp.max(s, axis=-1)                     # (Hkv, G)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])               # (Hkv, G, P)
    p = jnp.where(valid[None, None, :], p, 0.0)
    l_new = l_prev * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "hgp,phd->hgd", p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(i == pages_per_seq - 1)
    def _finish():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-9)[..., None]
                    ).astype(o_ref.dtype)
        m_out_ref[0] = m_ref[...]
        l_out_ref[0] = l


@functools.partial(
    jax.jit,
    static_argnames=("page_size", "window", "return_partials", "interpret"))
def paged_attention(
    q,             # (B, H, Dh)
    k_pages,       # (num_pages, page_size, Hkv, Dh)
    v_pages,       # (num_pages, page_size, Hkv, Dh)
    block_tables,  # (B, pages_per_seq) int32 physical page ids
    context_lens,  # (B,) int32
    *,
    page_size: int,
    window: Optional[int] = None,
    return_partials: bool = False,
    interpret: bool = True,
):
    """Decode attention over a paged KV cache. Returns (B, H, Dh), or with
    ``return_partials`` the tuple ``(o_unnormalized?, m, l)`` — note ``o`` IS
    normalized here; partials additionally expose (m, l) so a DistAttention
    combiner can merge shards: o_merged = Σ l_i·exp(m_i−m)·o_i / Σ l_i·exp(m_i−m).
    """
    b, h, dh = q.shape
    _, ps, hkv, _ = k_pages.shape
    assert ps == page_size
    g = h // hkv
    pages_per_seq = block_tables.shape[1]
    scale = 1.0 / (dh ** 0.5)

    qg = q.reshape(b, hkv, g, dh)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, pages_per_seq),
        in_specs=[
            pl.BlockSpec((1, hkv, g, dh), lambda bb, i, bt, cl: (bb, 0, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, dh),
                         lambda bb, i, bt, cl: (bt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, dh),
                         lambda bb, i, bt, cl: (bt[bb, i], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hkv, g, dh), lambda bb, i, bt, cl: (bb, 0, 0, 0)),
            pl.BlockSpec((1, hkv, g), lambda bb, i, bt, cl: (bb, 0, 0)),
            pl.BlockSpec((1, hkv, g), lambda bb, i, bt, cl: (bb, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(
        _paged_attn_kernel, page_size=page_size, pages_per_seq=pages_per_seq,
        window=window, scale=scale)
    out, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, dh), q.dtype),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables, context_lens, qg, k_pages, v_pages)
    out = out.reshape(b, h, dh)
    if return_partials:
        return out, m.reshape(b, h), l.reshape(b, h)
    return out
