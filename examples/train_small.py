"""End-to-end training driver: ~100M-parameter dense model, a few hundred
steps on the packed synthetic corpus, with checkpointing and resume.

  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.training import checkpoint
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M params: danube family scaled down (8L, d=768)
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b"),
        num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000, sliding_window=512)
    n = cfg.n_params()
    print(f"model: {n/1e6:.0f}M params")

    tcfg = TrainConfig(
        steps=args.steps, log_every=20, ckpt_every=100,
        ckpt_dir=args.ckpt_dir,
        opt=OptConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps))
    res = train(cfg, tcfg, batch_override={"seq_len": 256, "global_batch": 8})
    first, last = res["losses"][0][1], res["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} in {res['wall_s']:.0f}s")
    step = checkpoint.latest_step(args.ckpt_dir)
    print(f"latest checkpoint: step {step}")


if __name__ == "__main__":
    main()
