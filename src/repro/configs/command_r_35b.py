"""Command-R 35B — dense GQA, no biases [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    attention="gqa",
    rope_theta=8_000_000.0,
    use_bias=False,
    tie_embeddings=True,
)
