"""Radix prefix-cache sweep: hit-rate, TTFT, and throughput vs the no-cache
paged baseline across the shared-prefix serving scenarios.

Four workloads replayed through the LLMService front-end over SimBackend —
the *real* scheduler + allocator + radix tree (`core.prefixcache`) with the
OPT-13B iteration cost model:

* shared-prefix — a handful of system prompts fan out over all requests
* few-shot     — one long in-context template, short questions
* multi-turn   — chat sessions resending their full history each turn
* unique       — ShareGPT-like one-off prompts (the no-sharing control: the
                 cache must not regress it)
"""

from __future__ import annotations

from repro.serving.api import LLMService
from repro.serving.simulator import (SimBackend, make_few_shot_workload,
                                     make_multi_turn_workload,
                                     make_shared_prefix_workload,
                                     make_workload)

TOKEN_SLOTS = 16_384
BLOCK_SIZE = 16


def _scenarios(n_requests: int):
    n_sessions = max(4, n_requests // 5)
    return [
        ("shared-prefix", lambda: make_shared_prefix_workload(
            n_requests, rate=60.0, n_groups=4, prefix_len=512,
            suffix_len=64, out_len=96, seed=11)),
        ("few-shot", lambda: make_few_shot_workload(
            n_requests, rate=60.0, template_len=1024, question_len=48,
            out_len=32, seed=11)),
        ("multi-turn", lambda: make_multi_turn_workload(
            n_sessions, 5, rate=12.0, system_len=128, user_len=48,
            reply_len=96, seed=11)),
        ("unique", lambda: make_workload(
            n_requests, rate=30.0, dist="sharegpt", seed=11,
            materialize_tokens=True)),
    ]


def _replay(wl, prefix_cache: bool):
    svc = LLMService(SimBackend(num_blocks=TOKEN_SLOTS // BLOCK_SIZE,
                                block_size=BLOCK_SIZE,
                                prefix_cache=prefix_cache))
    # fresh Request objects per run — the backend mutates them
    _, stats = svc.replay(wl())
    return stats


def run(n_requests: int = 200, verbose: bool = True):
    rows = []
    for name, wl in _scenarios(n_requests):
        base = _replay(wl, prefix_cache=False)
        pc = _replay(wl, prefix_cache=True)
        rows.append({
            "workload": name,
            "hit_rate": pc.prefix_hit_rate,
            "ttft_base": base.mean_ttft,
            "ttft_pc": pc.mean_ttft,
            "thr_base": base.throughput_tokens_per_s,
            "thr_pc": pc.throughput_tokens_per_s,
            "speedup": pc.throughput_tokens_per_s /
            max(base.throughput_tokens_per_s, 1e-9),
            "preempt_base": base.preemptions,
            "preempt_pc": pc.preemptions,
        })
        if verbose:
            r = rows[-1]
            print(f"{name:14s} hit={r['hit_rate']:6.1%}  "
                  f"ttft {1e3*r['ttft_base']:7.2f}ms -> "
                  f"{1e3*r['ttft_pc']:7.2f}ms  "
                  f"thr {r['thr_base']:8.1f} -> {r['thr_pc']:8.1f} tok/s "
                  f"({r['speedup']:.3f}x)")
    return rows


if __name__ == "__main__":
    run()
