"""End-to-end serving engine: continuous batching on a real model must match
per-request sequential decoding exactly (greedy)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.scheduling.request import Request
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine


@pytest.fixture(scope="module")
def model_setup():
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, cfg, prompt, n):
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def test_engine_matches_sequential_oracle(model_setup):
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(5):
        plen = int(rng.integers(3, 12))
        reqs.append(Request(i, 0.0,
                            rng.integers(0, cfg.vocab_size, plen).tolist(),
                            max_new_tokens=int(rng.integers(2, 7))))
        eng.add_request(reqs[-1])
    eng.run_to_completion()
    for r in reqs:
        want = _oracle(model, params, cfg, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"


def test_engine_pallas_kernel_path(model_setup):
    """Same engine with the Pallas paged-attention kernel (interpret)."""
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=32, page_size=8,
                                                max_slots=2, use_kernel=True))
    r = Request(0, 0.0, [5, 9, 2, 7], max_new_tokens=3)
    eng.add_request(r)
    eng.run_to_completion()
    want = _oracle(model, params, cfg, r.prompt, 3)
    assert r.full_output == want


def test_engine_swa_arch(model_setup):
    cfg = smoke_config("h2o-danube-1.8b")  # window=64 active
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=2))
    r = Request(0, 0.0, list(np.random.default_rng(2).integers(
        0, cfg.vocab_size, 10)), max_new_tokens=4)
    eng.add_request(r)
    eng.run_to_completion()
    want = _oracle(model, params, cfg, r.prompt, 4)
    assert r.full_output == want


def test_engine_continuous_batching_admits_late_request(model_setup):
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    r1 = Request(0, 0.0, [1, 2, 3], max_new_tokens=6)
    eng.add_request(r1)
    eng.step()  # r1 prefilled
    r2 = Request(1, 0.0, [4, 5], max_new_tokens=2)
    eng.add_request(r2)  # joins while r1 decodes
    eng.run_to_completion()
    assert r1.full_output == _oracle(model, params, cfg, r1.prompt, 6)
    assert r2.full_output == _oracle(model, params, cfg, r2.prompt, 2)


def test_engine_kv_utilization_reported(model_setup):
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    eng.add_request(Request(0, 0.0, [1] * 9, max_new_tokens=3))
    eng.step()
    util = eng.kv_utilization()
    assert 0.5 <= util <= 1.0  # 9 tokens in 2 pages of 8 = 0.5625
