"""Synthetic data pipeline: deterministic corpus stream + packing + sharding.

Offline container => no real corpora; we generate a *structured* synthetic
language (Zipf-distributed unigrams + a Markov backbone so the model has
something learnable — loss decreases measurably within a few hundred steps,
which the quickstart example asserts) and pack documents into fixed-length
training sequences with EOS separators, exactly like a production loader.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos: int = 1
    zipf_a: float = 1.3
    markov_order: int = 1
    doc_len_mean: float = 180.0


class SyntheticCorpus:
    """Deterministic, seekable synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # Zipf marginals over the vocab (ids 2.. ; 0=pad, 1=eos)
        ranks = np.arange(2, v)
        p = 1.0 / ranks.astype(np.float64) ** cfg.zipf_a
        self.marginal = p / p.sum()
        # sparse Markov backbone: each token has ~8 likely successors
        self.n_succ = 8
        self.succ = rng.integers(2, v, size=(v, self.n_succ))

    def documents(self, seed: int) -> Iterator[np.ndarray]:
        rng = np.random.default_rng((self.cfg.seed, seed))
        v = self.cfg.vocab_size
        while True:
            n = max(8, int(rng.exponential(self.cfg.doc_len_mean)))
            toks = np.empty(n, np.int64)
            toks[0] = rng.choice(v - 2, p=self.marginal) + 2
            for i in range(1, n):
                if rng.random() < 0.75:  # follow the backbone
                    toks[i] = self.succ[toks[i - 1], rng.integers(self.n_succ)]
                else:
                    toks[i] = rng.choice(v - 2, p=self.marginal) + 2
            yield toks

    def batches(self, *, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Packed (tokens, labels) batches; labels == tokens (shift happens
        in the loss); EOS separates packed documents."""
        cfg = self.cfg
        docs = self.documents(seed=start_step)
        buf = np.empty(0, np.int64)
        step = start_step
        while True:
            need = cfg.global_batch * cfg.seq_len
            while len(buf) < need:
                d = next(docs)
                buf = np.concatenate([buf, d, [cfg.eos]])
            batch = buf[:need].reshape(cfg.global_batch, cfg.seq_len)
            buf = buf[need:]
            yield {"tokens": batch.astype(np.int32),
                   "labels": batch.astype(np.int32)}
            step += 1
