"""Telemetry layer: trace conservation invariants, exporter structure, and
the zero-cost-when-disabled guarantee.

Conservation properties checked over traced sim runs (example-based and,
when hypothesis is installed, over random router policies/share modes):

- every request span opened (``b``) is closed exactly once (``e``) —
  finish or drop, never both, never neither;
- every ``lease.acquire`` has a matching ``lease.release`` with a cause;
- per iteration, prefill chunk tokens minus rescinded chunk tokens plus
  decode tokens equals the iteration event's ``tokens`` (the scheduler's
  ``plan.token_count()``).
"""

import json
import tracemalloc
from collections import Counter, defaultdict

from hypothesis_compat import given, settings, st

from repro.core.telemetry import (Tracer, merge_events, percentile,
                                  to_chrome_trace, validate_trace_events)
from repro.serving.simulator import (SimBackend, make_shared_prefix_workload,
                                     make_workload, simulate_paged,
                                     simulate_router)


def _traced_paged(n=60, **kw):
    kw.setdefault("num_blocks", 300)
    kw.setdefault("block_size", 16)
    kw.setdefault("max_tokens_per_iter", 512)
    reqs = make_workload(n, rate=30.0, seed=3, max_len=512)
    return simulate_paged(reqs, trace=True, **kw)


# ---------------------------------------------------------------- invariants


def check_span_conservation(events):
    """Every request span begins once and ends once."""
    opened = Counter(e.rid for e in events
                     if e.cat == "request" and e.ph == "b")
    closed = Counter(e.rid for e in events
                     if e.cat == "request" and e.ph == "e")
    assert opened, "no request spans traced"
    for rid, n in opened.items():
        assert n == 1, f"request {rid} opened {n} times"
        assert closed[rid] == 1, \
            f"request {rid} opened once but closed {closed[rid]} times"
    assert set(closed) == set(opened), "span closed without a begin"


def check_lease_conservation(events):
    acq = Counter((e.instance, e.rid) for e in events
                  if e.cat == "lease" and e.name == "acquire")
    rel = Counter((e.instance, e.rid) for e in events
                  if e.cat == "lease" and e.name == "release")
    assert acq == rel, f"unbalanced leases: acquired {acq - rel or '{}'} " \
                       f"never released; released {rel - acq or '{}'} " \
                       f"never acquired"
    for e in events:
        if e.cat == "lease" and e.name == "release":
            assert e.args["cause"] in ("finish", "preempt")


def check_token_conservation(events):
    """chunk tokens - rescinded chunk tokens + decodes == iteration tokens,
    per (instance, iteration)."""
    chunks = defaultdict(int)
    rescinds = defaultdict(int)
    iters = {}
    for e in events:
        key = (e.instance, e.it)
        if e.cat == "req" and e.name == "chunk":
            chunks[key] += e.args["length"]
        elif e.cat == "req" and e.name == "chunk_rescind":
            rescinds[key] += e.args["length"]
        elif e.name == "iteration" and e.cat == "engine":
            iters[key] = (e.args["tokens"], e.args["decodes"])
    assert iters, "no iteration events traced"
    seen_keys = set(chunks) | set(rescinds) | set(iters)
    for key in seen_keys:
        tokens, decodes = iters.get(key, (0, 0))
        planned = chunks[key] - rescinds[key] + decodes
        assert planned == tokens, \
            f"instance {key[0]} iteration {key[1]}: chunks {chunks[key]} " \
            f"- rescinds {rescinds[key]} + decodes {decodes} != " \
            f"iteration tokens {tokens}"


def check_all(events):
    check_span_conservation(events)
    check_lease_conservation(events)
    check_token_conservation(events)


# ------------------------------------------------------------- example-based


def test_paged_trace_conservation():
    res = _traced_paged()
    assert res.events and res.timelines
    check_all(res.events)


def test_paged_trace_has_preemption_with_cause():
    # tight page budget forces preemptions; each must name its trigger
    res = _traced_paged(n=80, num_blocks=120)
    pre = [e for e in res.events if e.cat == "sched" and e.name == "preempt"]
    assert pre, "tight-memory run produced no preemption events"
    for e in pre:
        assert e.args["kind"] in ("victim", "self")
        assert e.args["trigger"] is not None
        assert e.rid is not None  # the victim
    check_all(res.events)  # rescinds/preempts keep the invariants


def test_refusal_events_carry_why():
    res = _traced_paged(n=80, num_blocks=120)
    whys = {e.args["why"] for e in res.events if e.cat == "sched" and e.name == "refuse"}
    assert whys <= {"solo_wait", "budget_sliver", "no_pages"}
    assert whys, "constrained run never refused an admission"


def test_router_trace_conservation_and_tracks():
    reqs = make_shared_prefix_workload(50, rate=30.0, n_groups=3, seed=5)
    res = simulate_router(reqs, n_instances=3, policy="round_robin",
                          prefix_share=True, blocks_per_instance=400,
                          trace=True)
    check_all(res.events)
    instances = {e.instance for e in res.events}
    assert {0, 1, 2} <= instances  # one track per child
    assert 3 in instances  # plus the router's own track
    assert any(e.cat == "router" and e.name == "place"
               for e in res.events)
    assert any(e.cat == "board" and e.name == "publish"
               for e in res.events)
    assert any(e.cat == "board" and e.name == "lookup"
               for e in res.events)
    assert set(res.timelines) == {0, 1, 2}
    assert all(rows for rows in res.timelines.values())


def test_zero_copy_router_emits_lease_lifecycle():
    # round_robin scatters a shared prefix, so somebody must borrow
    reqs = make_shared_prefix_workload(40, rate=100.0, n_groups=2,
                                       prefix_len=64, suffix_len=16,
                                       out_len=16, seed=3,
                                       group_draw="random")
    from repro.core.distkv.netmodel import NetworkModel
    res = simulate_router(reqs, n_instances=3, policy="round_robin",
                          prefix_share=True, share_mode="zero_copy",
                          blocks_per_instance=128, net=NetworkModel(),
                          trace=True)
    assert res.borrowed_pages > 0, "zero_copy must actually borrow"
    names = {(e.cat, e.name) for e in res.events}
    assert ("net", "lease") in names
    assert ("lease", "borrow") in names
    assert ("lease", "lend") in names
    check_all(res.events)


# ------------------------------------------------------------------ property


@settings(max_examples=8, deadline=None)
@given(policy=st.sampled_from(["round_robin", "least_loaded",
                               "prefix_affinity"]),
       share_mode=st.sampled_from(["copy", "zero_copy"]),
       seed=st.integers(min_value=0, max_value=40),
       n_instances=st.integers(min_value=2, max_value=4))
def test_router_trace_conservation_property(policy, share_mode, seed,
                                            n_instances):
    from repro.core.distkv.netmodel import NetworkModel
    reqs = make_shared_prefix_workload(30, rate=40.0, n_groups=2, seed=seed)
    res = simulate_router(reqs, n_instances=n_instances, policy=policy,
                          prefix_share=True, share_mode=share_mode,
                          blocks_per_instance=300, net=NetworkModel(),
                          trace=True)
    check_all(res.events)
    assert not validate_trace_events(to_chrome_trace(res.events))


# ------------------------------------------------------------------ exporter


def test_chrome_trace_structure(tmp_path):
    res = _traced_paged(n=20)
    obj = to_chrome_trace(res.events)
    assert validate_trace_events(obj) == []
    evs = obj["traceEvents"]
    # metadata names the instance's track
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["name"] == "process_name" and e["pid"] == 0 for e in meta)
    # ts is µs of virtual time; spans carry the request id
    span = next(e for e in evs if e["ph"] == "b")
    assert span["id"] == span["args"]["rid"]
    ev = next(e for e in evs if e["ph"] == "X" and e["name"] == "iteration")
    assert ev["dur"] >= 0 and isinstance(ev["ts"], float)
    # round-trips through the file exporter
    from repro.core.telemetry import export_chrome_trace
    out = tmp_path / "t.json"
    export_chrome_trace(res.events, out)
    assert validate_trace_events(json.loads(out.read_text())) == []


def test_validate_trace_events_catches_problems():
    assert validate_trace_events("nope")
    assert validate_trace_events([{"ph": "X", "name": "a", "ts": 0.0,
                                  "pid": 0}])  # X without dur
    assert validate_trace_events([{"ph": "b", "name": "a", "cat": "r",
                                   "ts": 0.0, "pid": 0, "id": 1}])  # no end
    good = [{"ph": "i", "name": "a", "ts": 0.0, "pid": 0, "s": "t"}]
    assert validate_trace_events(good) == []


def test_metrics_csv_and_json_export(tmp_path):
    res = _traced_paged(n=20)
    from repro.core.telemetry import export_metrics_csv, export_metrics_json
    csv_path = tmp_path / "m.csv"
    n = export_metrics_csv(res.timelines, csv_path)
    assert n == sum(len(r) for r in res.timelines.values())
    header = csv_path.read_text().splitlines()[0].split(",")
    assert header[:3] == ["instance", "ts", "iteration"]
    assert "kv_util_frac" in header and "tokens" in header
    export_metrics_json(res.timelines, tmp_path / "m.json")
    rows = json.loads((tmp_path / "m.json").read_text())
    assert len(rows) == n and rows[0]["instance"] == 0


def test_tracer_ring_buffer_overwrites_oldest():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("t", f"e{i}", ts=float(i))
    evs = tr.events()
    assert len(evs) == 4
    assert [e.name for e in evs] == ["e6", "e7", "e8", "e9"]
    assert tr.emitted == 10 and tr.dropped == 6


def test_merge_events_sorts_by_ts():
    a, b = Tracer(instance=0), Tracer(instance=1)
    a.instant("t", "x", ts=2.0)
    b.instant("t", "y", ts=1.0)
    merged = merge_events([a, None, b])
    assert [e.name for e in merged] == ["y", "x"]


# ----------------------------------------------------------------- percentile


def test_percentile_shared_helper():
    assert percentile([], 99) == float("inf")
    assert percentile([5.0], 99) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0  # no index overflow
    assert percentile([3.0, 1.0, 2.0], 50) == 2.0
    assert percentile([1.0, 2.0], 200) == 2.0  # q clamped


def test_service_stats_p99_uses_helper():
    res = _traced_paged(n=20)
    assert res.p99_tbt == percentile(res.max_tbts, 99)


# ------------------------------------------------------------- zero overhead


def test_disabled_tracer_constructs_nothing():
    """With trace=False no Event/args objects may be built: tracemalloc,
    filtered to the telemetry module files, must see zero allocations."""
    import repro.core.telemetry.metrics as metrics_mod
    import repro.core.telemetry.tracer as tracer_mod
    reqs = make_workload(30, rate=30.0, seed=1, max_len=256)
    simulate_paged(reqs, num_blocks=200, trace=False)  # warm caches
    flt = [tracemalloc.Filter(True, m.__file__)
           for m in (tracer_mod, metrics_mod)]
    tracemalloc.start(5)
    try:
        simulate_paged(reqs, num_blocks=200, trace=False)
        snap = tracemalloc.take_snapshot().filter_traces(flt)
    finally:
        tracemalloc.stop()
    leaked = sum(s.size for s in snap.statistics("filename"))
    assert leaked == 0, f"disabled path allocated {leaked} bytes " \
                        f"inside the telemetry layer"


def test_backend_telemetry_attrs_default_none():
    b = SimBackend(num_blocks=100)
    assert b.trace is None and b.metrics is None
    assert b.scheduler.trace is None
