"""ORCA iteration-level scheduler (paper §III.B Sol1) with selective batching.

Each call to :meth:`schedule` plans exactly ONE engine iteration: which
waiting requests to prefill (initiation phase) and which running requests to
advance by one token (increment phase). Early-finished requests leave the
batch immediately; late-joining requests enter at the next iteration — the
exact fix for ORCA's challenge C1.

Selective batching (Sol2) shows up as the *token budget*: attention is
per-sequence (paged cache), while MLP/linear layers run over the flattened
token buffer, so the scheduler bounds ``sum(prompt lens) + #decodes`` per
iteration rather than the sequence count.

Memory is delegated to a :class:`BlockAllocator` (vLLM §III.C) or any object
with the same interface; preemption-by-recompute evicts the youngest request
when pages run out (vLLM's recompute policy).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.paging.allocator import BlockAllocator, BlockTable
from repro.core.scheduling.request import Phase, Request


@dataclasses.dataclass
class IterationPlan:
    prefill: List[Request]
    decode: List[Request]
    preempted: List[Request]

    @property
    def empty(self) -> bool:
        return not (self.prefill or self.decode)

    def token_count(self) -> int:
        return sum(r.prompt_len for r in self.prefill) + len(self.decode)


class IterationScheduler:
    def __init__(self, allocator: BlockAllocator, *,
                 max_running: int = 64,
                 max_tokens_per_iter: int = 8192,
                 watermark: float = 0.01):
        self.allocator = allocator
        self.max_running = max_running
        self.max_tokens = max_tokens_per_iter
        self.watermark_blocks = max(1, int(allocator.num_blocks * watermark))
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.tables: Dict[int, BlockTable] = {}

    # -- client API -------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        req.phase = Phase.WAITING
        self.waiting.append(req)

    def finish(self, req: Request, now: float) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = now
        if req.request_id in self.tables:
            self.allocator.free_table(self.tables.pop(req.request_id))
        if req in self.running:
            self.running.remove(req)

    # -- one iteration ------------------------------------------------------------
    def schedule(self) -> IterationPlan:
        prefill: List[Request] = []
        decode: List[Request] = []
        preempted: List[Request] = []
        budget = self.max_tokens

        # 1) running decodes first (latency priority), preempting if needed
        for req in list(self.running):
            if budget <= 0:
                break
            if req.request_id not in self.tables:
                continue  # became a preemption victim earlier this iteration
            table = self.tables[req.request_id]
            if not self.allocator.can_append(table, 1):
                victim = self._preempt_youngest(exclude=req)
                if victim is None or not self.allocator.can_append(table, 1):
                    # preempt this request itself
                    self._preempt(req)
                    preempted.append(req)
                    continue
                preempted.append(victim)
            self.allocator.append_tokens(table, 1)
            decode.append(req)
            budget -= 1

        # 2) admit waiting requests (FCFS) into leftover budget + memory
        while (self.waiting and budget > 0
               and len(self.running) < self.max_running):
            req = self.waiting[0]
            need_tokens = req.prompt_len
            if need_tokens > budget:
                break
            table = BlockTable()
            if (self.allocator.blocks_needed(table, need_tokens)
                    > self.allocator.num_free - self.watermark_blocks):
                break
            self.waiting.pop(0)
            self.allocator.append_tokens(table, need_tokens)
            self.tables[req.request_id] = table
            req.phase = Phase.INITIATION
            self.running.append(req)
            prefill.append(req)
            budget -= need_tokens

        return IterationPlan(prefill=prefill, decode=decode,
                             preempted=preempted)

    def complete_iteration(self, plan: IterationPlan, now: float) -> List[Request]:
        """Mark phases + retire finished requests. Returns finished list."""
        finished = []
        for req in plan.prefill:
            req.phase = Phase.INCREMENT
            if req.first_token_time is None:
                req.first_token_time = now
        for req in plan.prefill + plan.decode:
            if req.done:
                self.finish(req, now)
                finished.append(req)
        return finished

    # -- preemption ----------------------------------------------------------------
    def _preempt(self, req: Request) -> None:
        req.phase = Phase.PREEMPTED
        req.preemptions += 1
        # recompute policy: drop pages; generated tokens move into the prompt
        req.prompt = (req.prompt + req.output) if req.prompt else req.prompt
        req.prompt_len = req.context_len
        req.max_new_tokens -= req.n_generated
        req.committed_output.extend(req.output)
        req.output = []
        self.allocator.free_table(self.tables.pop(req.request_id))
        if req in self.running:
            self.running.remove(req)
        self.waiting.insert(0, req)

    def _preempt_youngest(self, exclude: Request) -> Optional[Request]:
        for req in reversed(self.running):
            if req is not exclude:
                self._preempt(req)
                return req
        return None
