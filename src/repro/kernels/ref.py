"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens, *,
                        page_size: int, window: Optional[int] = None):
    """q: (B,H,Dh); pages: (P, ps, Hkv, Dh); block_tables: (B, n); lens: (B,)."""
    b, h, dh = q.shape
    hkv = k_pages.shape[2]
    g = h // hkv
    n = block_tables.shape[1]
    smax = n * page_size

    # gather the logical KV for each sequence
    k = k_pages[block_tables]  # (B, n, ps, Hkv, Dh)
    v = v_pages[block_tables]
    k = k.reshape(b, smax, hkv, dh).astype(jnp.float32)
    v = v.reshape(b, smax, hkv, dh).astype(jnp.float32)

    pos = jnp.arange(smax)
    valid = pos[None, :] < context_lens[:, None]
    if window is not None:
        valid &= pos[None, :] > context_lens[:, None] - 1 - window

    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) / (dh ** 0.5)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return o.reshape(b, h, dh).astype(q.dtype)


def flash_prefill_ref(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None):
    """q: (B,S,H,Dh); k,v: (B,Skv,Hkv,Dh)."""
    b, s, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) / (dh ** 0.5)
    qpos, kpos = jnp.arange(s), jnp.arange(skv)
    mask = jnp.ones((s, skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen with tiny windows) -> zeros, like the kernel
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(b, s, h, dh).astype(q.dtype)


def ssd_scan_ref(x, dt, A, B, C):
    """Sequential (non-chunked) SSD recurrence oracle.

    x: (b,l,h,p); dt: (b,l,h) fp32 post-softplus; A: (h,); B,C: (b,l,g,n).
    Returns y: (b,l,h,p), final_state: (b,h,p,n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dtt * A)  # (b,h)
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt * dtt[..., None], Bt)
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), final
