"""Cluster router: placement policies, ServingBackend conformance, request
conservation, N=1 equivalence with a bare backend, and cross-instance prefix
sharing through the distkv publication board (the PR's acceptance test: a
prefix computed on instance A hits the cache on instance B)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.distkv.prefixshare import PrefixShareBoard
from repro.core.scheduling.request import Request
from repro.serving.api import (FINISH_REASONS, LLMService, SamplingParams,
                               ServingBackend)
from repro.serving.router import (POLICIES, LeastLoadedPolicy,
                                  PrefixAffinityPolicy, RouterBackend,
                                  RoundRobinPolicy)
from repro.serving.simulator import (SimBackend, make_shared_prefix_workload,
                                     make_workload, simulate_router)

PS = 8  # page size for the engine tests


class ScriptedPolicy:
    """Test helper: place request k on ``script[k]`` (order of submission)."""

    def __init__(self, script):
        self.script = list(script)
        self._i = 0

    def choose(self, req, children):
        i = self.script[self._i]
        self._i += 1
        return i


def _sim_children(n, **kw):
    kw.setdefault("num_blocks", 256)
    kw.setdefault("block_size", 16)
    kw.setdefault("max_running", 16)
    kw.setdefault("prefix_cache", True)
    return [SimBackend(**kw) for _ in range(n)]


def _drain(router, max_steps=10_000):
    for _ in range(max_steps):
        if not router.has_work:
            return
        router.step()
    raise RuntimeError("router did not drain")


# -- protocol + clock semantics -------------------------------------------------

def test_router_is_a_serving_backend():
    router = RouterBackend(_sim_children(3))
    assert isinstance(router, ServingBackend)
    assert router.clock() == 0.0  # all-virtual cluster: virtual frontier
    assert not router.has_work


def test_router_event_driven_clock_advances_laggard():
    router = RouterBackend(_sim_children(2), policy="round_robin")
    a, b = router.children
    router.add_request(Request(0, 0.0, [], max_new_tokens=4, prompt_len=8))
    router.add_request(Request(1, 0.0, [], max_new_tokens=4, prompt_len=8))
    router.step()  # advances exactly one (the laggard) instance
    stepped = sorted([a.iterations, b.iterations])
    assert stepped == [0, 1]
    _drain(router)
    assert router.iterations == a.iterations + b.iterations
    # frontier clock: no work left -> max of children
    assert router.clock() == max(a.clock(), b.clock())


def test_router_add_request_advances_idle_instance_to_arrival():
    router = RouterBackend(_sim_children(2))
    req = Request(0, 5.0, [], max_new_tokens=2, prompt_len=4)
    router.add_request(req)
    # the instance serving it cannot run before the request exists
    assert router.children[req.instance_id].clock() >= 5.0


# -- placement policies ---------------------------------------------------------

def test_round_robin_cycles():
    router = RouterBackend(_sim_children(3), policy="round_robin")
    reqs = [Request(i, 0.0, [], max_new_tokens=1, prompt_len=4)
            for i in range(6)]
    for r in reqs:
        router.add_request(r)
    assert [r.instance_id for r in reqs] == [0, 1, 2, 0, 1, 2]


def test_least_loaded_prefers_idle_instance():
    router = RouterBackend(_sim_children(3), policy="least_loaded")
    r0 = Request(0, 0.0, [], max_new_tokens=8, prompt_len=16)
    router.add_request(r0)
    r1 = Request(1, 0.0, [], max_new_tokens=8, prompt_len=16)
    router.add_request(r1)
    assert r1.instance_id != r0.instance_id  # instance 0 already has load


def test_prefix_affinity_routes_to_cached_instance():
    router = RouterBackend(_sim_children(2, block_size=8),
                           policy="prefix_affinity")
    prefix = list(range(100, 132))  # 4 pages of 8
    warm = Request(0, 0.0, prefix + [1, 2, 3], max_new_tokens=2)
    router.add_request(warm)
    _drain(router)
    warm_inst = warm.instance_id
    # the warm instance now holds the prefix pages; a same-prefix request
    # must follow them even though the other instance is emptier
    follow = Request(1, 10.0, prefix + [7, 8, 9], max_new_tokens=2)
    router.add_request(follow)
    assert follow.instance_id == warm_inst
    # a cold prompt falls back to least-loaded, not instance 0 by default
    cold = Request(2, 10.0, list(range(900, 940)), max_new_tokens=2)
    router.add_request(cold)
    assert cold.instance_id != warm_inst or \
        len(router.children[warm_inst].scheduler.waiting) == 0


def test_best_of_n_siblings_co_located():
    router = RouterBackend(_sim_children(4), policy="round_robin")
    svc = LLMService(router)
    svc.submit(list(range(32)), SamplingParams(
        temperature=1.0, n=3, max_new_tokens=2, seed=1))
    svc.drain()
    placed = [n for n in router.requests_placed if n]
    assert placed == [3]  # the whole fork family on one instance


def test_policy_registry_complete():
    assert set(POLICIES) == {"round_robin", "least_loaded",
                             "prefix_affinity"}
    assert isinstance(POLICIES["round_robin"](), RoundRobinPolicy)
    assert isinstance(POLICIES["least_loaded"](), LeastLoadedPolicy)
    assert isinstance(POLICIES["prefix_affinity"](), PrefixAffinityPolicy)


def test_affinity_probe_has_no_side_effects():
    """Routing probes must not perturb LRU order or hit counters — probing
    every instance per request would otherwise publish never-reused paths."""
    child = SimBackend(num_blocks=32, block_size=8, prefix_cache=True)
    pc = child.prefix_cache
    svc = LLMService(child)
    svc.generate([list(range(24))], SamplingParams(max_new_tokens=2))
    clock_before = pc._clock
    hits_before = sum(n.hit_count for n in pc.root.children.values())
    pol = PrefixAffinityPolicy()
    probe_req = Request(99, 0.0, list(range(24)), max_new_tokens=1)
    pol.choose(probe_req, [child])
    assert pc._clock == clock_before
    assert sum(n.hit_count for n in pc.root.children.values()) == hits_before


# -- request conservation -------------------------------------------------------

def _check_conservation(requests, n_instances, policy, **sim_kw):
    """Every submitted request reaches exactly one terminal finish_reason,
    exactly once, and leaves no pages referenced by dead block tables."""
    children = _sim_children(n_instances, **sim_kw)
    router = RouterBackend(children, policy=policy)
    svc = LLMService(router)
    for r in requests:
        svc.submit_request(r)
    finish_events = {}
    idle = 0
    while svc.pending and idle < 4:
        chunks = svc.poll()
        idle = 0 if svc._progressed else idle + 1
        for ch in chunks:
            if ch.finished:
                finish_events[ch.request_id] = \
                    finish_events.get(ch.request_id, 0) + 1
    assert not svc.pending, "router stalled with work left"
    assert sorted(finish_events) == sorted(r.request_id for r in requests)
    assert all(v == 1 for v in finish_events.values()), finish_events
    for r in requests:
        assert r.finish_reason in FINISH_REASONS, r.finish_reason
    # every request was placed exactly once
    assert sum(router.requests_placed) == len(requests)
    # no leaked per-request state: all block tables freed (tree-held cache
    # pages may legitimately remain allocated)
    for c in children:
        assert not c.scheduler.tables


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("n_instances", [1, 3])
def test_request_conservation_examples(policy, n_instances):
    wl = make_shared_prefix_workload(40, rate=200.0, n_groups=3,
                                    prefix_len=96, suffix_len=24,
                                    out_len=16, seed=7, group_draw="random")
    _check_conservation(wl, n_instances, policy)


def test_request_conservation_under_drops():
    """Terminal exactly-once also under preempted-dropped finishes."""
    reqs = [Request(i, 0.0, [], max_new_tokens=40, prompt_len=30)
            for i in range(6)]
    _check_conservation(reqs, 2, "least_loaded", num_blocks=8, block_size=8,
                        prefix_cache=False, max_preemptions=0)


if HAVE_HYPOTHESIS:
    policy_st = st.sampled_from(sorted(POLICIES))
else:  # shim: strategies are inert, @given skips
    policy_st = st.none()


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 5), policy_st,
       st.booleans())
def test_request_conservation_property(seed, n_instances, policy, shuffle):
    """PROPERTY: under any policy, instance count, and arrival order, every
    request finishes exactly once with a terminal reason."""
    rng = np.random.default_rng(seed)
    wl = make_workload(20, rate=float(rng.uniform(20, 400)), seed=seed,
                       max_len=256, materialize_tokens=True)
    if shuffle:  # submission order need not match arrival order
        rng.shuffle(wl)
    _check_conservation(wl, n_instances, policy)


# -- distkv publication board ---------------------------------------------------

def test_board_publish_and_match():
    b = PrefixShareBoard()
    toks = list(range(32))
    assert b.publish(0, toks, [f"p{i}" for i in range(4)], 8) == 4
    # republication of a shorter overlapping path adds nothing
    assert b.publish(1, toks[:16], ["x", "y"], 8) == 0
    hit = b.match(toks[:24] + [999] * 8)
    assert [p.payload for p in hit] == ["p0", "p1", "p2"]
    assert all(p.home == 0 for p in hit)  # first publisher wins
    assert b.match([7] * 32) == []
    assert b.stats()["published_pages"] == 4


def test_router_share_rejects_mixed_page_sizes():
    """Adoption re-chunks published token keys by the adopter's page size —
    a cluster mixing page sizes must be rejected up front, not crash when
    the first cross-size payload is written."""
    children = [SimBackend(num_blocks=64, block_size=8, prefix_cache=True),
                SimBackend(num_blocks=64, block_size=16, prefix_cache=True)]
    with pytest.raises(ValueError, match="page size"):
        RouterBackend(children, prefix_share=True)
    RouterBackend(children)  # without sharing, mixing is fine


def test_board_rejects_mixed_page_sizes():
    b = PrefixShareBoard()
    b.publish(0, list(range(8)), ["p"], 8)
    with pytest.raises(ValueError):
        b.publish(1, list(range(16)), ["q"], 16)


def test_rmanager_prefix_passthrough():
    from repro.core.distkv import GManager, RManager
    from repro.core.paging import BlockAllocator
    g = GManager(2)
    rms = {i: RManager(i, BlockAllocator(8, 8), g) for i in range(2)}
    for r in rms.values():
        r.register_peers(rms)
    rms[0].publish_prefix(list(range(16)), ["a", "b"])
    hit = rms[1].lookup_prefix(list(range(16)))
    assert [p.payload for p in hit] == ["a", "b"]
    assert g.prefix_board.published_pages == 2


# -- cross-instance prefix sharing (sim) ----------------------------------------

def test_cross_instance_prefix_adoption_sim():
    """ACCEPTANCE: a prefix computed on instance A is adopted by instance B
    through the publication board, and B's request is admitted with the
    prefix already cached (no recompute)."""
    children = _sim_children(2, block_size=8)
    router = RouterBackend(children, policy=ScriptedPolicy([0, 0, 1]),
                           prefix_share=True, hot_threshold=1)
    a, b = children
    prefix = list(range(200, 232))  # 4 pages of 8
    r0 = Request(0, 0.0, prefix + [1, 2, 3], max_new_tokens=2)
    router.add_request(r0)
    _drain(router)  # A computes + inserts the prefix
    assert router.g.prefix_board.published_pages == 0  # not hot yet
    r1 = Request(1, 1.0, prefix + [4, 5, 6], max_new_tokens=2)
    router.add_request(r1)
    _drain(router)  # A hits its own cache -> path is hot -> published
    assert router.g.prefix_board.published_pages >= 4
    assert b.prefix_cache.adopted_pages == 0
    r2 = Request(2, 2.0, prefix + [7, 8, 9], max_new_tokens=2)
    router.add_request(r2)
    _drain(router)
    # B adopted A's pages instead of recomputing the prefix
    assert r2.instance_id == 1
    assert b.prefix_cache.adopted_pages == 4
    assert r2.num_cached_tokens == 32
    assert b.prefix_cache.hit_tokens >= 32


def test_adoption_shrinks_prefill_cost_sim():
    """The adopted prefix must not be recomputed: B's prefill charges only
    the suffix tokens (visible as fewer flattened tokens -> faster iter)."""
    def run(share):
        children = _sim_children(2, block_size=8)
        router = RouterBackend(children, policy=ScriptedPolicy([0, 0, 1]),
                               prefix_share=share, hot_threshold=1)
        svc = LLMService(router)
        prefix = list(range(500, 564))  # 8 pages
        for k, t in enumerate([0.0, 1.0, 2.0]):
            svc.submit(prefix + [k] * 5,
                       SamplingParams(max_new_tokens=2), arrival_time=t)
        svc.drain()
        return svc.stats()

    base, shared = run(False), run(True)
    assert shared.prefix_hit_rate > base.prefix_hit_rate
    assert shared.per_instance[1]["adopted_pages"] == 8
    assert base.per_instance[1].get("adopted_pages", 0) == 0


def test_simulate_router_smoke_and_stats():
    wl = make_shared_prefix_workload(30, rate=100.0, n_groups=2,
                                    prefix_len=64, suffix_len=16,
                                    out_len=8, seed=3, group_draw="random")
    res = simulate_router(wl, n_instances=3, policy="prefix_affinity",
                          blocks_per_instance=128, block_size=16)
    assert res.completed_frac == 1.0
    assert res.prefix_hit_rate is not None and res.prefix_hit_rate > 0
    assert set(res.per_instance) == {0, 1, 2}
    assert sum(r["requests"] for r in res.per_instance.values()) == 30


def test_router_metrics_carry_instance_id():
    router = RouterBackend(_sim_children(2), policy="round_robin")
    svc = LLMService(router)
    outs, _ = svc.replay(make_workload(6, rate=100.0, seed=5, max_len=128,
                                       materialize_tokens=True))
    assert [o.metrics.instance_id for o in outs] == [0, 1, 0, 1, 0, 1]


def test_hit_count_only_on_committed_admissions():
    """A request retrying admission under memory pressure must not inflate
    hit counters (and thus must not trigger spurious hot-path publication):
    counters move only via record_admission on a committed admission."""
    from repro.core.paging import BlockAllocator
    from repro.core.prefixcache import PrefixCache
    from repro.core.scheduling import IterationScheduler
    a = BlockAllocator(6, 8)
    pc = PrefixCache(a)
    sched = IterationScheduler(a, prefix_cache=pc, max_tokens_per_iter=64)
    prefix = list(range(16))  # 2 pages
    r0 = Request(0, 0.0, prefix + [1], max_new_tokens=1)
    sched.add_request(r0)
    sched.complete_iteration(sched.schedule(), 0.0)  # insert, no reuse yet
    top = next(iter(pc.root.children.values()))
    assert top.hit_count == 0
    # too big to ever admit (5 suffix pages > 3 usable): every schedule()
    # matches + locks + rolls back — counters must not move
    big = Request(1, 0.0, prefix + list(range(100, 140)), max_new_tokens=1)
    sched.add_request(big)
    for _ in range(5):
        sched.schedule()
    assert top.hit_count == 0
    # a request that actually commits bumps exactly once
    ok = Request(2, 0.0, prefix + [7], max_new_tokens=1)
    sched.add_request(ok)
    sched.waiting.remove(big)
    sched.schedule()
    assert top.hit_count == 1
    assert ok.num_cached_tokens == 16


# -- engine integration ----------------------------------------------------------

@pytest.fixture(scope="module")
def model_setup():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None, logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(cfg, params, **kw):
    from repro.serving.engine import EngineConfig, PagedEngine
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PS)
    kw.setdefault("max_slots", 4)
    return PagedEngine(cfg, params, EngineConfig(**kw))


def _oracle(model, params, prompt, n):
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def test_router_n1_token_identical_to_bare_backend(model_setup):
    """ACCEPTANCE: RouterBackend([backend]) is a transparent wrapper — a
    seeded mixed greedy+sampled batch produces token-identical outputs and
    finish reasons to the bare backend."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 12)))
               .tolist() for _ in range(4)]
    sp = [SamplingParams(max_new_tokens=5),
          SamplingParams(max_new_tokens=5, temperature=0.9, top_p=0.9,
                         seed=3),
          SamplingParams(max_new_tokens=5, temperature=1.2, top_k=40,
                         seed=4),
          SamplingParams(max_new_tokens=3, eos_token=None)]

    def run(make_backend):
        svc = LLMService(make_backend())
        rids = [svc.submit(p, s) for p, s in zip(prompts, sp)]
        svc.drain()
        return [svc._results[r] for r in rids]

    bare = run(lambda: _engine(cfg, params))
    routed = run(lambda: RouterBackend([_engine(cfg, params)],
                                       policy="least_loaded"))
    for o_b, o_r in zip(bare, routed):
        assert o_r.token_ids == o_b.token_ids
        assert o_r.finish_reason == o_b.finish_reason
    assert all(o.metrics.instance_id == 0 for o in routed)


def test_cross_instance_prefix_adoption_engine(model_setup):
    """ACCEPTANCE (real engines): instance B adopts A's published page
    payloads and decodes token-identically to the oracle — proving the
    transferred KV contents are the real thing, not just bookkeeping."""
    cfg, model, params = model_setup
    engines = [_engine(cfg, params, enable_prefix_cache=True)
               for _ in range(2)]
    router = RouterBackend(engines, policy=ScriptedPolicy([0, 0, 1]),
                           prefix_share=True, hot_threshold=1)
    rng = np.random.default_rng(12)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    reqs = [Request(i, 0.0, prefix +
                    rng.integers(0, cfg.vocab_size, 4).tolist(),
                    max_new_tokens=3) for i in range(3)]
    for i in (0, 1):
        router.add_request(reqs[i])
        _drain(router)
    assert router.g.prefix_board.published_pages >= 2
    router.add_request(reqs[2])
    _drain(router)
    assert reqs[2].instance_id == 1
    assert engines[1].prefix_cache.adopted_pages == 2
    assert reqs[2].num_cached_tokens == 2 * PS
    # adopted KV is numerically right: greedy continuation matches the
    # from-scratch oracle
    for r in reqs:
        assert r.full_output == _oracle(model, params, r.prompt, 3), \
            f"req {r.request_id}"


def test_mixed_cluster_share_engine_skips_payloadless_pages(model_setup):
    """A sim child publishes bookkeeping-only pages (payload None); an
    engine peer must neither crash on them nor adopt them — it recomputes
    the prefix and still decodes correctly."""
    cfg, model, params = model_setup
    sim = SimBackend(num_blocks=64, block_size=PS, prefix_cache=True)
    eng = _engine(cfg, params, enable_prefix_cache=True)
    router = RouterBackend([sim, eng], policy=ScriptedPolicy([0, 0, 1]),
                           prefix_share=True, hot_threshold=1)
    rng = np.random.default_rng(21)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    reqs = [Request(i, 0.0, prefix +
                    rng.integers(0, cfg.vocab_size, 3).tolist(),
                    max_new_tokens=2) for i in range(3)]
    for i in (0, 1):
        router.add_request(reqs[i])
        _drain(router)
    assert router.g.prefix_board.published_pages >= 2  # sim, payload None
    router.add_request(reqs[2])
    _drain(router)  # engine request: must not crash on None payloads
    assert reqs[2].instance_id == 1
    assert eng.prefix_cache.adopted_pages == 0
    assert reqs[2].num_cached_tokens == 0
    assert reqs[2].full_output == _oracle(model, params, reqs[2].prompt, 2)


def test_router_mixed_engine_and_sim_children(model_setup):
    """Engine + sim children behind one router (wall-clock semantics)."""
    cfg, model, params = model_setup
    router = RouterBackend(
        [_engine(cfg, params), SimBackend(num_blocks=64, block_size=8)],
        policy="round_robin")
    assert router.clock() is None  # any wall-clock child -> caller time
    svc = LLMService(router)
    rng = np.random.default_rng(9)
    outs = svc.generate([rng.integers(0, cfg.vocab_size, 6).tolist()
                         for _ in range(4)],
                        SamplingParams(max_new_tokens=3))
    assert [o.metrics.instance_id for o in outs] == [0, 1, 0, 1]
    assert all(o.finish_reason == "length" for o in outs)
