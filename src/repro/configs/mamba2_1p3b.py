"""Mamba2-1.3B — SSD state-space duality, attention-free [arXiv:2405.21060].

48L d_model=2048 vocab=50280, ssm_state=128, d_inner=4096, head_dim=64
(64 SSD heads). No attention, no MLP (the Mamba2 block subsumes both) —
d_ff=0 per the assignment. KV paging is inapplicable (constant-size state);
iteration-level scheduling still applies. ``long_500k`` runs at O(1) memory.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    tie_embeddings=True,
)
