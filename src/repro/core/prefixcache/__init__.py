from repro.core.prefixcache.radix import PrefixCache, RadixNode  # noqa: F401
