"""The paper's §II contribution, end to end: build a PETALS-style swarm,
find chains with the Dijkstra baseline and with the NSGA-II
Latency-Throughput-Tradeoff mode, and print the Pareto front.

  PYTHONPATH=src python examples/chain_nsga2.py
"""

from repro.core.chain import (find_best_chain, knee_chain,
                              latency_throughput_tradeoff, make_fleet)


def describe(chain, label):
    spans = " -> ".join(f"s{s.server_id}[{a}:{b}]" for s, a, b in chain)
    print(f"{label:18s} time={chain.total_time:6.2f}s "
          f"lat={chain.total_latency:5.2f}s "
          f"thr(bottleneck)={chain.bottleneck_throughput:6.1f} blk/s  "
          f"{spans}")


def main():
    fleet = make_fleet(num_blocks=24, num_servers=20, seed=42)
    print(f"swarm: {len(fleet.servers)} servers hosting "
          f"{fleet.num_blocks} transformer blocks\n")

    describe(find_best_chain(fleet), "dijkstra (PETALS)")
    describe(find_best_chain(fleet, mode="max_throughput"), "max-throughput")

    res = latency_throughput_tradeoff(fleet, pop_size=80, generations=50,
                                      seed=0)
    print(f"\nNSGA-II: {res.evaluations} evaluations, "
          f"{len(res.chains)} Pareto-optimal chains")
    shown = sorted(res.chains, key=lambda c: c.total_time)[:5]
    for i, c in enumerate(shown):
        describe(c, f"pareto[{i}]")
    describe(knee_chain(res), "knee (default)")


if __name__ == "__main__":
    main()
