"""Radix-tree prefix KV cache: cross-request sharing of physical pages.

DESIGN
======

Problem. The paging layer (``core.paging.allocator``) shares pages only
*within* a fork family (parallel sampling COW) and every admitted prompt is
prefilled from token 0. Production traffic is dominated by shared prefixes —
system prompts, few-shot templates, multi-turn chat history — so the same
prefix KV is recomputed and stored once per request.

Solution. A token-keyed radix tree over **physical KV pages**. Each node owns
exactly one full page: its key is the ``page_size``-token tuple stored in that
page, its value the physical block id. A root-to-node path therefore spells a
page-aligned token prefix, and the blocks along the path are precisely the KV
pages a new request with that prefix can reuse. The tree *holds one allocator
reference per adopted block* (``incref``), so pages survive the freeing of the
request that produced them; refcounts make sharing safe with the existing COW
machinery (a cached page always has refcount >= 1 from the tree, so any
appender that lands inside it copies first).

Lifecycle per request:

1. **match** — at admission the scheduler walks the tree over the prompt's
   full pages (capped at ``prompt_len - 1`` tokens so at least one suffix
   token remains to produce logits). Pure lookup, no side effects.
2. **lock** — once admission commits, the matched path is pinned
   (``pin_count``) and each block increfed on behalf of the request; the
   blocks seed the request's :class:`BlockTable`, so the uniform
   ``free_table`` path works unchanged at the end of life.
3. **insert** — as soon as the request's prefill iteration completes, its
   full *prompt* pages are inserted (their KV now exists, so waiting for
   request completion would let a same-prefix burst recompute the prefix N
   times): pages already present are skipped (the request's copy is simply
   freed at end of life), new pages are adopted by the tree with an extra
   reference.
4. **evict** — under ``OutOfBlocks`` pressure the scheduler evicts
   least-recently-used *unpinned leaves* before resorting to preemption.
   Only pages the tree exclusively owns (allocator refcount 1) are
   candidates: a page a running request still references is never freed, and
   forgetting it would lose cache without reclaiming memory.

Token-level matching (SGLang-style splitting, page-granular). SGLang's radix
tree is *token-level*: nodes hold variable-length token runs and are split on
partial matches, so a hit can end mid-page. Nodes here keep the 1:1
node/block mapping (one node == one full physical page), but the *frontier*
of a match is token-level: after the longest full-page walk,
:meth:`match_partial` scans the last node's children for the one sharing the
longest token run with the prompt's next page — up to ``page_size - 1``
further cached tokens. The "split" is realized at admission as a
**partial-page COW** instead of a tree mutation: the scheduler locks the
partially-matched node into the request's block table with only the shared
run counted as stored tokens, and the allocator's existing copy-on-write
duplicates the physical page on the first suffix write (the node stays
intact for requests continuing down its own branch). When the new request's
prefill completes, its divergent boundary page is inserted as a sibling —
the tree then holds both post-split branches, each backed by its own full
page, which is exactly SGLang's post-split structure expressed in whole
pages. Token-level matching is on by default (``token_level=False`` restores
page-aligned-only hits).
Cross-instance sharing. Every node carries a **hit counter** (bumped once
per *committed* admission that reuses the node — neither routing-policy
``probe`` lookups nor failed admission retries count). A serving router can ask for the *hot* root paths
(:meth:`take_hot_paths`) to publish their token keys + page payloads through
the distkv layer, and a peer instance adopts a published path into its own
tree with :meth:`adopt` — fresh local blocks, tree-owned, so the peer serves
the shared system prompt without ever computing it. The tree itself is
**payload-agnostic**: it tracks block *ids* and token keys only, never page
contents or their shape, so it works unchanged over any
:class:`~repro.core.paging.layout.KVPageLayout` (full GQA K/V pages and
MLA latent ckv/krope pages alike) — payload movement lives entirely in the
spill/publish/adopt hooks its owner wires, and the schema-compatibility
check between instances lives on the share board and lease grants.

Spill-to-host (tiered cache). With ``spill_budget > 0`` and a host tier on
the allocator, a cold leaf under eviction pressure *spills* to a host page
instead of being dropped: its device page is freed (what eviction wanted)
but the KV survives on host, the node stays in the tree with ``block = -1``
and ``host_block`` set, and a later :meth:`match` walking onto it *restores*
it onto a fresh device block (the ``spill_out_fn`` / ``spill_in_fn`` hooks
move the payloads; the sim leaves them None). The host budget is bounded and
LRU: when full, the coldest spilled page is dropped for good. Spilled nodes
are always leaves (insert un-spills in place before growing through one),
probe lookups still count them as hits, and hot-path publication skips them.

The LRU clock is a logical counter (no wall time), keeping the simulator
deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.paging.allocator import BlockAllocator, OutOfBlocks


@dataclasses.dataclass
class RadixNode:
    """One cached physical page. ``key`` is the page's token content."""
    key: Tuple[int, ...]
    block: int
    parent: Optional["RadixNode"]
    children: Dict[Tuple[int, ...], "RadixNode"] = \
        dataclasses.field(default_factory=dict)
    last_access: int = 0
    pin_count: int = 0  # running requests currently holding this node
    hit_count: int = 0  # committed admissions that reused this node
    published: bool = False  # already exported for cross-instance sharing
    pending_hot: bool = False  # queued in _recent_hits awaiting publication
    # spill-to-host: when spilled, ``block`` is -1 and this holds the host
    # page keeping the KV alive (-1 = device-resident)
    host_block: int = -1
    # spill-to-peer: the KV lives in a *neighbor instance's* device page
    # (lent rBlock) instead of host — ``block`` is -1, ``host_block`` is -1,
    # and these name the creditor instance and its physical page
    peer_home: int = -1
    peer_block: int = -1


class PrefixCache:
    def __init__(self, allocator: BlockAllocator,
                 page_size: Optional[int] = None, *,
                 token_level: bool = True,
                 spill_budget: int = 0):
        self.allocator = allocator
        self.page_size = page_size or allocator.block_size
        # token-level frontier matching (SGLang-style): recover up to
        # page_size - 1 tokens per hit past the last full-page match
        self.token_level = token_level
        self.root = RadixNode(key=(), block=-1, parent=None)
        self._clock = 0
        self.num_pages = 0
        # admission stats (recorded by the scheduler via record_admission)
        self.lookup_tokens = 0
        self.hit_tokens = 0
        self.admissions = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
        self.adopted_pages = 0  # pages imported from a peer's publication
        # hot-path publication plumbing, enabled by a cluster router
        # (track_hot=True). Off by default: a single-instance cache must not
        # accumulate node references nobody will ever drain.
        self.track_hot = False
        # nodes whose hit_count moved since the last take_hot_paths drain:
        # publication scans O(recently-hit) nodes, never the whole tree
        self._recent_hits: List[RadixNode] = []
        # spill-to-host: max host pages this cache may hold (0 = classic
        # hard eviction), the nodes currently spilled, and the payload
        # movers (same (dev, host)-pair-list signature as the scheduler's
        # swap hooks; the engine wires them, the sim has no payloads)
        self.spill_budget = spill_budget
        self._spilled: List[RadixNode] = []
        self.spill_out_fn = None
        self.spill_in_fn = None
        self.spilled_pages = 0   # cumulative spill-outs
        self.restored_pages = 0  # cumulative spill-ins (restores)
        # spill-to-peer tier (wired by a cluster router over the rBlock
        # lend/borrow machinery; None = host tier only). Tried *before*
        # host: a neighbor's free device memory restores over the NVLink
        # lane instead of PCIe.
        #   peer_spill_fn(dev_block) -> Optional[(home_instance, peer_block)]
        #     copies the payload out while dev_block is still allocated
        #   peer_restore_fn(home, peer_block, dev_block)
        #     copies back onto a fresh local block and repays the loan
        #   peer_drop_fn(home, peer_block)
        #     repays the loan without copying (page dies)
        self.peer_spill_fn = None
        self.peer_restore_fn = None
        self.peer_drop_fn = None
        self.peer_spilled_pages = 0
        self.peer_restored_pages = 0

    # -- lookup -----------------------------------------------------------------
    def match(self, tokens: Sequence[int], *,
              max_tokens: Optional[int] = None,
              probe: bool = False) -> List[RadixNode]:
        """Longest page-aligned cached prefix of ``tokens``.

        Returns the matched node path (root excluded; may be empty). Pure
        lookup apart from LRU touching — callers commit with :meth:`lock`,
        and hit counters (which drive cross-instance publication) are only
        bumped by :meth:`record_admission` on a *committed* admission, so a
        request retrying admission under memory pressure cannot inflate
        them. ``max_tokens`` caps the match (admission passes
        ``prompt_len - 1`` so a fully-cached prompt still prefills its last
        token for logits). ``probe=True`` is fully side-effect-free for
        routing policies probing every instance."""
        ps = self.page_size
        limit = len(tokens) if max_tokens is None else \
            min(max_tokens, len(tokens))
        node, path = self.root, []
        if not probe:
            self._clock += 1
        for i in range(limit // ps):
            child = node.children.get(tuple(tokens[i * ps:(i + 1) * ps]))
            if child is None:
                break
            if child.block == -1:
                # spilled page: a probe counts it as a hit without touching
                # anything; a committing match restores it onto a fresh
                # device block (or stops the path when the device is full —
                # the prefix restored so far is still valid)
                if not probe and not self._restore(child):
                    break
            if not probe:
                child.last_access = self._clock
            path.append(child)
            node = child
        return path

    def _restore(self, node: RadixNode) -> bool:
        """Spill-in: re-materialize a spilled node onto a device block
        (from the peer tier or the host tier, wherever it lives)."""
        try:
            dev = self.allocator.alloc_block()
        except OutOfBlocks:
            return False
        if node.peer_block != -1:
            self.peer_restore_fn(node.peer_home, node.peer_block, dev)
            node.peer_home = node.peer_block = -1
            self.peer_restored_pages += 1
        else:
            if self.spill_in_fn is not None:
                self.spill_in_fn([(node.host_block, dev)])
            self.allocator.free_host_block(node.host_block)
            node.host_block = -1
        self._spilled.remove(node)
        node.block = dev
        self.restored_pages += 1
        return True

    def match_partial(self, tokens: Sequence[int],
                      path: List[RadixNode], *,
                      max_tokens: Optional[int] = None,
                      probe: bool = False
                      ) -> Optional[Tuple[RadixNode, int]]:
        """Token-level frontier of a full-page :meth:`match`: the child of
        the last matched node sharing the longest run of further tokens.

        Returns ``(node, n_tokens)`` with ``1 <= n_tokens < page_size`` or
        ``None``. The caller reuses the node's page for its first
        ``n_tokens`` only — locking it into a block table with a partial
        token count makes the allocator COW the page on the first suffix
        write (the split-boundary copy), leaving the node's own branch
        intact. Disabled with ``token_level=False``."""
        if not self.token_level:
            return None
        ps = self.page_size
        limit = len(tokens) if max_tokens is None else \
            min(max_tokens, len(tokens))
        done = len(path) * ps
        rest = tokens[done:limit]
        if not rest:
            return None
        node = path[-1] if path else self.root
        best, best_run = None, 0
        for key, child in node.children.items():
            if child.block == -1:
                continue  # spilled: no device page to COW-lock
            run = 0
            stop = min(len(rest), len(key))
            while run < stop and key[run] == rest[run]:
                run += 1
            if run > best_run:
                best, best_run = child, run
        if best is None or best_run >= ps:
            # a full-page run would have been consumed by match() already;
            # >= ps here would mean an inconsistent tree
            return None
        if not probe:
            best.last_access = self._clock
        return best, best_run

    # -- request lifecycle --------------------------------------------------------
    def lock(self, path: List[RadixNode]) -> List[int]:
        """Pin ``path`` and take one block reference per node on behalf of an
        admitted request. Returns the block ids (in prefix order) for seeding
        the request's block table; ``free_table`` releases the references."""
        for node in path:
            node.pin_count += 1
            self.allocator.incref(node.block)
        return [node.block for node in path]

    def release(self, path: List[RadixNode]) -> None:
        """Unpin a path locked at admission (block refs are returned
        separately by the request's ``free_table``)."""
        for node in path:
            node.pin_count -= 1

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Insert the full pages of ``tokens`` (page ``i`` backed by
        ``blocks[i]``). Pages already cached are skipped; new pages are
        adopted with an extra allocator reference. Returns #pages adopted."""
        ps = self.page_size
        node, new = self.root, 0
        self._clock += 1
        for i in range(len(tokens) // ps):
            key = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                self.allocator.incref(blocks[i])
                child = RadixNode(key=key, block=blocks[i], parent=node)
                node.children[key] = child
                self.num_pages += 1
                new += 1
            elif child.block == -1:
                # un-spill in place for free: the inserter just computed
                # this very page, so adopt its fresh device block and let
                # the stale spilled copy go. Also keeps spilled nodes
                # leaves — we never grow a branch through an off-device
                # page.
                self.allocator.incref(blocks[i])
                if child.peer_block != -1:
                    if self.peer_drop_fn is not None:
                        self.peer_drop_fn(child.peer_home, child.peer_block)
                    child.peer_home = child.peer_block = -1
                else:
                    self.allocator.free_host_block(child.host_block)
                    child.host_block = -1
                self._spilled.remove(child)
                child.block = blocks[i]
            child.last_access = self._clock
            node = child
        self.inserted_pages += new
        return new

    # -- cross-instance sharing ---------------------------------------------------
    def take_hot_paths(self, threshold: int
                       ) -> List[Tuple[Tuple[int, ...], List[int]]]:
        """Root paths ending at *hot* nodes (``hit_count >= threshold``) not
        yet published. Each entry is ``(token_prefix, blocks)`` — the full
        token key of the path and the physical page per node — ready to be
        shipped (with page payloads) to the distkv publication board. Nodes
        are marked ``published`` so a path is exported once; the union of
        exported paths is the tree's hot subtree.

        Cost is O(recently-hit nodes), not O(tree): ``record_admission``
        queues the nodes it bumps and this drains the queue (nodes still
        under the threshold re-queue on their next hit)."""
        out = []
        for node in self._recent_hits:
            node.pending_hot = False
            if node.hit_count < threshold or node.published or \
                    node.parent is None:  # parent None = evicted meanwhile
                continue
            node.published = True
            toks: List[int] = []
            blocks: List[int] = []
            walk = node
            while walk.parent is not None:  # ancestors of a live node live
                if walk.block == -1:
                    break  # spilled since the hit: no payload to publish
                toks[:0] = walk.key
                blocks.insert(0, walk.block)
                walk = walk.parent
            if walk.parent is not None:
                node.published = False  # republishable once restored
                continue
            out.append((tuple(toks), blocks))
        self._recent_hits.clear()
        return out

    def adopt(self, tokens: Sequence[int]) -> List[Tuple[int, int]]:
        """Adopt a *published* page chain computed on another instance:
        allocate one fresh local block per page of ``tokens`` not already
        cached and graft the nodes into the tree (tree-owned, refcount 1).

        Returns ``(page_index, block)`` for every newly adopted page — the
        caller must materialize the page payloads (KV contents) into those
        blocks before any request reads them. Adoption is best-effort: it
        stops at the first page the allocator cannot supply (the leading
        pages alone are still a valid prefix). Imported nodes keep
        ``published=True`` so an adopter never re-publishes a prefix it did
        not compute."""
        ps = self.page_size
        node, adopted = self.root, []
        self._clock += 1
        for i in range(len(tokens) // ps):
            key = tuple(tokens[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is not None and child.block == -1:
                break  # adoption stops at a spilled frontier (a later
                # match restores it; growing through it would put children
                # under a host-resident page)
            if child is None:
                try:
                    block = self.allocator.alloc_block()
                except OutOfBlocks:  # keep the prefix adopted so far
                    break
                child = RadixNode(key=key, block=block, parent=node,
                                  published=True)
                node.children[key] = child
                self.num_pages += 1
                adopted.append((i, block))
            child.last_access = self._clock
            node = child
        self.adopted_pages += len(adopted)
        return adopted

    # -- eviction -----------------------------------------------------------------
    def evict(self, n_blocks: int, *, spill: bool = True) -> int:
        """Return >= ``n_blocks`` pages to the allocator's free list by
        dropping LRU unpinned leaves. Only pages the tree *exclusively* owns
        (refcount 1) are candidates: a page some request still references is
        never freed, and dropping the tree's reference to it would destroy
        cache without reclaiming any memory. With a spill budget, a
        candidate's KV moves to a host page instead of dying (the device
        page is freed either way). Returns blocks actually freed."""
        freed = 0
        progress = True
        # one tree walk per pass, not per freed block; extra passes only when
        # evicting a leaf exposes its parent as a new eviction candidate
        while freed < n_blocks and progress:
            progress = False
            for leaf in self._lru_leaves():
                if freed >= n_blocks:
                    break
                if spill and self.spill_budget and self._spill(leaf):
                    freed += 1  # device page freed, KV kept on host
                    progress = True
                    continue
                before = self.allocator.num_free
                self.allocator.decref(leaf.block)
                freed += self.allocator.num_free - before
                del leaf.parent.children[leaf.key]
                leaf.parent = None  # take_hot_paths skips evicted nodes
                self.num_pages -= 1
                self.evicted_pages += 1
                progress = True
        return freed

    def _spill(self, leaf: RadixNode) -> bool:
        """Move a cold leaf's page off-device: a neighbor instance's free
        device memory first (NVLink lane), the host tier second (PCIe).
        Falls back to False (hard eviction) when neither can take it."""
        if len(self._spilled) >= self.spill_budget:
            # budget full: the coldest spilled page dies so this (more
            # recently used) one can take its slot
            self._drop_spilled(min(self._spilled,
                                   key=lambda n: n.last_access))
        if self.peer_spill_fn is not None:
            # the fn copies the payload while leaf.block is still allocated
            dst = self.peer_spill_fn(leaf.block)
            if dst is not None:
                leaf.peer_home, leaf.peer_block = dst
                self.allocator.decref(leaf.block)  # refcount 1 -> freed
                leaf.block = -1
                self._spilled.append(leaf)
                self.spilled_pages += 1
                self.peer_spilled_pages += 1
                return True
        if self.allocator.host_num_free == 0:
            return False  # host pool exhausted (table swaps hold it)
        host = self.allocator.alloc_host_block()
        if self.spill_out_fn is not None:
            self.spill_out_fn([(leaf.block, host)])
        self.allocator.decref(leaf.block)  # refcount 1 -> page freed
        leaf.host_block = host
        leaf.block = -1
        self._spilled.append(leaf)
        self.spilled_pages += 1
        return True

    def _drop_spilled(self, node: RadixNode) -> None:
        """Permanently drop a spilled node (its peer loan repaid or host
        page freed, node unlinked). Spilled nodes are always leaves —
        nothing dangles."""
        if node.peer_block != -1:
            if self.peer_drop_fn is not None:
                self.peer_drop_fn(node.peer_home, node.peer_block)
            node.peer_home = node.peer_block = -1
        else:
            self.allocator.free_host_block(node.host_block)
        del node.parent.children[node.key]
        node.parent = None
        self._spilled.remove(node)
        self.num_pages -= 1
        self.evicted_pages += 1

    def _lru_leaves(self) -> List[RadixNode]:
        """Unpinned, exclusively-tree-owned leaves, oldest first."""
        leaves = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            for ch in node.children.values():
                if ch.children:
                    stack.append(ch)
                elif ch.pin_count == 0 and \
                        self.allocator.refcount_of(ch.block) == 1:
                    leaves.append(ch)
        leaves.sort(key=lambda ch: ch.last_access)
        return leaves

    def clear(self) -> int:
        """Drop every unpinned page (e.g. on engine reset), host tier
        included — no spilling on the way out."""
        for node in list(self._spilled):
            self._drop_spilled(node)
        return self.evict(self.num_pages, spill=False)

    # -- stats --------------------------------------------------------------------
    def record_admission(self, prompt_tokens: int, hit_tokens: int,
                         path: Sequence[RadixNode] = ()) -> None:
        """Called once per *committed* admission. ``path`` is the locked
        node chain the request reuses; its hit counters feed hot-path
        publication (one bump per serving request, never per retry)."""
        self.admissions += 1
        self.lookup_tokens += prompt_tokens
        self.hit_tokens += hit_tokens
        for node in path:
            node.hit_count += 1
            if self.track_hot and not node.published \
                    and not node.pending_hot:
                node.pending_hot = True
                self._recent_hits.append(node)

    @property
    def hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cached pages."""
        return self.hit_tokens / self.lookup_tokens if self.lookup_tokens \
            else 0.0

    @property
    def cached_tokens(self) -> int:
        return self.num_pages * self.page_size

    def stats(self) -> Dict[str, float]:
        return {
            "hit_rate": self.hit_rate,
            "hit_tokens": self.hit_tokens,
            "lookup_tokens": self.lookup_tokens,
            "admissions": self.admissions,
            "cached_pages": self.num_pages,
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "adopted_pages": self.adopted_pages,
            "spilled_pages": self.spilled_pages,
            "restored_pages": self.restored_pages,
            "spilled_now": len(self._spilled),
            "peer_spilled_pages": self.peer_spilled_pages,
            "peer_restored_pages": self.peer_restored_pages,
        }
