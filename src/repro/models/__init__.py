from repro.models.transformer import Model, Segment, stack_plan  # noqa: F401
from repro.models.layers import ShardingPolicy, NO_POLICY  # noqa: F401
