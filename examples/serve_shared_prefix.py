"""Radix prefix-cache serving through the LLMService front-end: N chat
sessions over one shared system prompt.

Runs the same traffic through two `PagedEngine` backends — cold (no cache)
and with the radix-tree prefix cache — and prints per-request prefill work,
the cache hit-rate, and KV page usage. With the cache, every request after
the first computes only its own suffix tokens; the shared system-prompt pages
are prefilled once and increfed into each request's block table.

  PYTHONPATH=src python examples/serve_shared_prefix.py
"""

import numpy as np

import jax

from repro.configs import smoke_config
from repro.models import Model
from repro.serving.api import LLMService, SamplingParams
from repro.serving.engine import EngineConfig, PagedEngine

N_SESSIONS = 8
PAGE_SIZE = 8
SYSTEM_PROMPT_PAGES = 2


def drive(eng, prompts, label):
    print(f"\n--- {label} ---")
    svc = LLMService(eng, default_params=SamplingParams(max_new_tokens=4))
    outputs = []
    for i, prompt in enumerate(prompts):
        # sequential sessions: each generate() call sees the pages the
        # previous session left in the radix tree
        out = svc.generate([prompt])[0]
        cached = out.metrics.num_cached_tokens
        print(f"session {i}: prompt {out.prompt_len:2d} tok, "
              f"prefilled {out.prompt_len - cached:2d}, "
              f"served from cache {cached:2d}")
        outputs.append(out.token_ids)
    used = eng.allocator.num_used
    print(f"kv pages in use after drain: {used}/{eng.allocator.num_blocks} "
          f"(cache-resident pages keep the shared prefix warm)")
    stats = eng.prefix_cache_stats()
    if stats:
        print(f"hit-rate {stats['hit_rate']:.1%} "
              f"({stats['hit_tokens']:.0f}/{stats['lookup_tokens']:.0f} "
              f"prompt tokens), {stats['cached_pages']:.0f} cached pages")
    return outputs


def main():
    cfg = smoke_config("h2o-danube-1.8b")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(42)
    system = rng.integers(2, cfg.vocab_size,
                          SYSTEM_PROMPT_PAGES * PAGE_SIZE).tolist()
    prompts = [system + rng.integers(2, cfg.vocab_size, 6).tolist()
               for _ in range(N_SESSIONS)]

    def engine(enable):
        return PagedEngine(cfg, params, EngineConfig(
            num_pages=64, page_size=PAGE_SIZE, max_slots=4,
            enable_prefix_cache=enable))

    cold = drive(engine(False), prompts, "cold start (no prefix cache)")
    warm = drive(engine(True), prompts, "radix prefix cache")
    match = cold == warm
    print(f"\noutputs identical across both engines: {match}")
    assert match, "prefix-cache path must be a pure optimization"


if __name__ == "__main__":
    main()
