"""Request model shared by the scheduler, engine, and simulator (ORCA §III.B)."""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, List, Optional


class Phase(enum.Enum):
    WAITING = "waiting"      # queued, not yet prefilled
    INITIATION = "initiation"  # prefill (ORCA's term)
    INCREMENT = "increment"    # autoregressive decode
    PREEMPTED = "preempted"    # pages reclaimed, must re-prefill
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    request_id: int
    arrival_time: float
    prompt: List[int]  # token ids (simulator may leave this empty)
    max_new_tokens: int
    prompt_len: Optional[int] = None  # simulator-only requests set this
    eos_token: Optional[int] = None
    n_samples: int = 1  # deprecated: use SamplingParams.n via the service
    # per-request decoding knobs (serving.api.SamplingParams; duck-typed here
    # to keep this module dependency-free). None = engine defaults (greedy).
    sampling: Optional[Any] = None
    # best-of-n sibling: COW-forked off the parent's prefill by the backend
    parent_id: Optional[int] = None

    phase: Phase = Phase.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    # tokens generated before a preemption (they re-enter as prompt on
    # recompute but still belong to the client-visible output)
    committed_output: List[int] = dataclasses.field(default_factory=list)
    # per-token log p(sampled token) aligned with ``full_output`` (the
    # engine appends one entry per emitted token; the simulator leaves it
    # empty — its outputs are placeholder ids)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    # chunked-prefill progress: prompt tokens whose KV exists (cached prefix
    # + chunks computed so far). The request decodes only once this reaches
    # ``prompt_len``; preemption resets it (recompute policy).
    prefilled_len: int = 0
    first_token_time: Optional[float] = None
    scheduled_time: Optional[float] = None  # first admission into a plan
    finish_time: Optional[float] = None
    # inter-token-gap tracking (stall observability): backend time of the
    # most recent emitted token, and the worst gap between consecutive
    # tokens — a decode stalled behind a long prefill shows up here
    last_token_time: Optional[float] = None
    max_tbt: float = 0.0
    # one of serving.api.FINISH_REASONS once finished
    finish_reason: Optional[str] = None
    preemptions: int = 0
    # swap-to-host preemptions (KV preserved on host, no recompute) — a
    # separate counter from ``preemptions`` because a swap loses no work and
    # must not eat into the max_preemptions drop budget
    swaps: int = 0
    # scheduler iteration index this request last received work in (decode
    # grant or prefill chunk) — the LRU victim policy's recency key
    last_planned_iter: int = -1
    # sum of log p(sampled token) under the model — best-of-n ranking
    cumulative_logprob: float = 0.0
    # prompt tokens served from the radix prefix cache at the current
    # admission (page-aligned; the engine prefills only the remainder)
    num_cached_tokens: int = 0
    # serving instance this request was placed on (set by RouterBackend;
    # None under a single-backend service)
    instance_id: Optional[int] = None

    def __post_init__(self):
        if self.prompt_len is None:
            self.prompt_len = len(self.prompt)

    @property
    def n_generated(self) -> int:
        return len(self.output)

    @property
    def full_output(self) -> List[int]:
        return self.committed_output + self.output

    @property
    def total_generated(self) -> int:
        return len(self.committed_output) + len(self.output)

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.n_generated

    def record_token_time(self, now: float) -> None:
        """Track the worst inter-token gap (backends call this once per
        emitted token). The first token's gap is TTFT, tracked separately."""
        if self.last_token_time is not None and now > self.last_token_time:
            self.max_tbt = max(self.max_tbt, now - self.last_token_time)
        self.last_token_time = now

    @property
    def stop_token_ids(self):
        return self.sampling.stop_token_ids if self.sampling is not None \
            else ()

    @property
    def finish_reason_if_done(self) -> Optional[str]:
        """Finish reason the request has earned so far, or None while it
        should keep decoding. Stop/eos on the *last sampled token* win over
        the length cap (vLLM semantics)."""
        last = self.output[-1] if self.output else None
        if last is not None:
            if last in self.stop_token_ids:
                return "stop"
            if self.eos_token is not None and last == self.eos_token:
                return "eos"
        if self.n_generated >= self.max_new_tokens:
            return "length"
        return None

    @property
    def done(self) -> bool:
        return self.finish_reason_if_done is not None

    def normalized_latency(self) -> Optional[float]:
        """Paper Fig. 9 metric: end-to-end latency / output length."""
        if self.finish_time is None:
            return None
        return (self.finish_time - self.arrival_time) / max(
            self.total_generated, 1)
