"""Distributed-path tests: a subprocess with 8 virtual host devices runs a
sharded train step + sharded decode and checks numerics against the
single-device result. (A subprocess is required because jax locks the
device count at first init; see launch/dryrun.py.)"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    stdout = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.launch import sharding as shd
        from repro.models import Model
        from repro.training import optimizer
        from repro.training.train_loop import make_train_step

        cfg = smoke_config("h2o-danube-1.8b")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        model = Model(cfg, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        opt = optimizer.init(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 64), 0, cfg.vocab_size)}
        batch["labels"] = batch["tokens"]

        # single-device reference
        step0 = jax.jit(make_train_step(model, optimizer.OptConfig()))
        _, _, m0 = step0(params, opt, batch)

        policy = shd.MeshPolicy(mesh, cfg)
        p_shape = jax.eval_shape(lambda: params)
        p_shard = shd.param_shardings(p_shape, mesh, cfg)
        o_shard = shd.param_shardings(jax.eval_shape(lambda: opt), mesh, cfg)
        b_shard = shd.batch_shardings(
            jax.eval_shape(lambda: batch), mesh, cfg)
        params_s = jax.device_put(params, p_shard)
        opt_s = jax.device_put(opt, o_shard)
        batch_s = jax.device_put(batch, b_shard)
        step1 = jax.jit(make_train_step(model, optimizer.OptConfig(),
                                        policy),
                        in_shardings=(p_shard, o_shard, b_shard))
        _, _, m1 = step1(params_s, opt_s, batch_s)
        print("loss0", float(m0["loss"]), "loss1", float(m1["loss"]))
        assert abs(float(m0["loss"]) - float(m1["loss"])) < 0.03, \\
            (float(m0["loss"]), float(m1["loss"]))
        print("SHARDED_OK")
        """)
    assert "SHARDED_OK" in stdout


@pytest.mark.slow
def test_sharded_moe_matches_single_device():
    # Tolerances, measured and justified (this test used to assert bf16
    # max-logit-err < 0.08 and failed at 0.0898 — a marginal, ill-posed
    # bound):
    #
    # * float32 run, max err < 5e-3 (measured 1.6e-3; the *dense* GQA model
    #   shows the same 1.1e-3 under identical sharding, so the residual is
    #   generic sharded-compilation reduction reordering, not the MoE
    #   mapping — an expert-routing or psum bug would be O(0.1+)). This is
    #   the correctness check for the expert-parallel shard_map path.
    # * bf16 run, MEAN err < 0.01 (measured 0.0025) and argmax agreement
    #   >= 0.97 (measured 0.992): bf16 hidden-state noise can flip a
    #   borderline router top-k choice for isolated tokens, and a flipped
    #   expert changes those logits by O(0.1) — so the bf16 MAX err is not
    #   boundable tightly; the bulk statistics are.
    stdout = _run("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.launch import sharding as shd
        from repro.models import Model

        def compare(dtype):
            cfg = smoke_config("deepseek-v2-236b")  # MLA + MoE(4 experts)
            cfg = dataclasses.replace(cfg, dtype=dtype)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            model = Model(cfg, remat=False)
            params = model.init(jax.random.PRNGKey(0))
            tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                        cfg.vocab_size)
            logits0, _ = model.forward(params, tokens)
            policy = shd.MeshPolicy(mesh, cfg)
            p_shard = shd.param_shardings(jax.eval_shape(lambda: params),
                                          mesh, cfg)
            params_s = jax.device_put(params, p_shard)
            fwd = jax.jit(lambda p, t: model.forward(p, t,
                                                     policy=policy)[0],
                          in_shardings=(p_shard, None))
            logits1 = fwd(params_s, tokens)
            d = jnp.abs(logits0.astype(jnp.float32)
                        - logits1.astype(jnp.float32))
            agree = jnp.mean((jnp.argmax(logits0, -1)
                              == jnp.argmax(logits1, -1)).astype(
                                  jnp.float32))
            return float(jnp.max(d)), float(jnp.mean(d)), float(agree)

        mx32, mean32, _ = compare("float32")
        print("f32 max err", mx32, "mean", mean32)
        assert mx32 < 5e-3, mx32
        mx16, mean16, agree16 = compare("bfloat16")
        print("bf16 max err", mx16, "mean", mean16, "agree", agree16)
        assert mean16 < 0.01, mean16
        assert agree16 >= 0.97, agree16
        print("MOE_SHARDED_OK")
        """)
    assert "MOE_SHARDED_OK" in stdout


@pytest.mark.slow
def test_dist_attention_on_mesh():
    stdout = _run("""
        import jax, jax.numpy as jnp
        from repro.core.distkv import dist_attention, dist_attention_ref
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (4, 8, 64))
        k = jax.random.normal(ks[1], (4, 256, 2, 64))
        v = jax.random.normal(ks[2], (4, 256, 2, 64))
        lens = jnp.array([3, 100, 256, 177], jnp.int32)
        out = dist_attention(mesh, q, k, v, lens)
        want = dist_attention_ref(q, k, v, lens)
        err = float(jnp.max(jnp.abs(out - want)))
        assert err < 1e-5, err
        print("DIST_ATTN_OK")
        """)
    assert "DIST_ATTN_OK" in stdout
