"""LLMService front-end: one API over the real engine and the simulator.

Covers the PR's acceptance criteria: a single workload exercised on both
backends through the ServingBackend protocol, and a batch mixing greedy and
temperature/top-p requests with different stop tokens producing
per-request-correct finish reasons and deterministic greedy outputs in one
fused decode.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.scheduling.request import Request
from repro.models import Model
from repro.serving.api import (FINISH_DROPPED, FINISH_REASONS, LLMService,
                               SamplingParams, ServingBackend)
from repro.serving.engine import EngineConfig, PagedEngine
from repro.serving.simulator import SimBackend, make_workload


@pytest.fixture(scope="module")
def model_setup():
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, n):
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def _engine(cfg, params, **kw):
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", 8)
    kw.setdefault("max_slots", 4)
    return PagedEngine(cfg, params, EngineConfig(**kw))


def test_generate_blocking_matches_oracle(model_setup):
    cfg, model, params = model_setup
    svc = LLMService(_engine(cfg, params))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, 10).tolist()
               for _ in range(3)]
    outs = svc.generate(prompts, SamplingParams(max_new_tokens=5))
    for p, o in zip(prompts, outs):
        assert o.token_ids == _oracle(model, params, p, 5)
        assert o.finish_reason == "length"
        assert o.metrics.ttft is not None and o.metrics.e2e is not None


def test_mixed_batch_finish_reasons_fused_decode(model_setup):
    """ACCEPTANCE: greedy + temperature/top-p requests with different stop
    tokens in ONE fused decode — per-request-correct finish reasons and
    deterministic greedy output."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    # reference: the greedy request run ALONE on a fresh engine — the mixed
    # batch must reproduce it exactly (sampled neighbors in the fused decode
    # must not perturb a greedy slot)
    greedy_out = LLMService(_engine(cfg, params)).generate(
        [prompt], SamplingParams(max_new_tokens=6))[0].token_ids

    def run():
        svc = LLMService(_engine(cfg, params))
        rids = [
            svc.submit(prompt, SamplingParams(max_new_tokens=6)),
            # greedy with a stop token at the oracle's 3rd token
            svc.submit(prompt, SamplingParams(
                max_new_tokens=6, stop_token_ids=(greedy_out[2],))),
            svc.submit(prompt, SamplingParams(
                max_new_tokens=6, temperature=0.9, top_p=0.9, seed=5,
                stop_token_ids=(123456,))),  # never hit: out-of-vocab id
            svc.submit(prompt, SamplingParams(
                max_new_tokens=6, temperature=1.3, top_k=50, seed=6,
                eos_token=None)),
        ]
        svc.drain()
        return [svc._results[r] for r in rids]

    outs = run()
    assert outs[0].token_ids == greedy_out
    assert outs[0].finish_reason == "length"
    # stops at the FIRST occurrence of the stop token in the greedy stream
    stop_at = greedy_out.index(greedy_out[2])
    assert outs[1].token_ids == greedy_out[:stop_at + 1]
    assert outs[1].finish_reason == "stop"
    assert outs[2].finish_reason == "length"
    assert outs[3].finish_reason == "length"
    assert all(len(o.token_ids) <= 6 for o in outs)
    # all four decoded in the same engine -> fused slots; rerun = identical
    outs2 = run()
    for a, b in zip(outs, outs2):
        assert a.token_ids == b.token_ids and \
            a.finish_reason == b.finish_reason


def test_eos_vs_length_finish(model_setup):
    cfg, model, params = model_setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 9).tolist()
    want = _oracle(model, params, prompt, 4)
    svc = LLMService(_engine(cfg, params))
    eos_out, len_out = svc.generate(
        [prompt, prompt],
        SamplingParams(max_new_tokens=8, eos_token=want[3]))
    assert eos_out.token_ids == want[:4]
    assert eos_out.finish_reason == "eos"
    assert len_out.finish_reason == "eos"  # same greedy stream
    svc2 = LLMService(_engine(cfg, params))
    out = svc2.generate([prompt], SamplingParams(max_new_tokens=2))[0]
    assert out.finish_reason == "length" and len(out.token_ids) == 2


def test_stream_chunks_concatenate_to_output(model_setup):
    cfg, model, params = model_setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist() for _ in range(2)]
    svc = LLMService(_engine(cfg, params))
    got = {0: [], 1: []}
    reasons = {}
    for ch in svc.stream(prompts, SamplingParams(max_new_tokens=4)):
        got[ch.request_id].extend(ch.token_ids)
        if ch.finished:
            reasons[ch.request_id] = ch.finish_reason
    for i, p in enumerate(prompts):
        assert got[i] == _oracle(model, params, p, 4)
        assert reasons[i] == "length"


def test_best_of_n_cow_forks(model_setup):
    cfg, model, params = model_setup
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 11).tolist()
    eng = _engine(cfg, params)
    svc = LLMService(eng)
    out = svc.generate([prompt], SamplingParams(
        max_new_tokens=4, temperature=1.0, n=3, seed=11))[0]
    assert len(out.samples) == 3
    # samples ranked best-first by cumulative logprob; best mirrored at top
    lps = [s.cumulative_logprob for s in out.samples]
    assert lps == sorted(lps, reverse=True)
    assert out.token_ids == out.samples[0].token_ids
    assert out.cumulative_logprob == lps[0]
    # distinct seeds -> (almost surely) distinct streams
    assert len({tuple(s.token_ids) for s in out.samples}) > 1
    # COW fork bookkeeping fully unwound: no leaked pages or refs
    assert eng.allocator.num_free == eng.allocator.num_blocks
    assert not eng.allocator.refcount


def test_same_workload_on_both_backends():
    """ACCEPTANCE: one workload, two ServingBackend implementations, one
    service drive loop."""
    cfg = smoke_config("h2o-danube-1.8b")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))

    def workload():
        return make_workload(12, rate=50.0, seed=9, max_len=48,
                             materialize_tokens=True,
                             vocab=cfg.vocab_size)

    for backend in (SimBackend(num_blocks=64, block_size=8, max_running=4),
                    PagedEngine(cfg, params, EngineConfig(
                        num_pages=64, page_size=8, max_slots=4,
                        max_context_len=96))):
        assert isinstance(backend, ServingBackend)
        svc = LLMService(backend)
        outs, stats = svc.replay(workload())
        assert stats.n_finished == 12
        for o in outs:
            assert o is not None
            assert o.finish_reason in FINISH_REASONS
            assert 1 <= o.n_generated
            assert o.metrics.ttft is not None


def test_preempted_dropped_finish_reason():
    """A request churning past the preemption budget is dropped and reported
    as preempted-dropped, not recomputed forever."""
    backend = SimBackend(num_blocks=12, block_size=8, max_running=8,
                         max_preemptions=0)
    svc = LLMService(backend)
    reqs = [Request(i, 0.0, [], max_new_tokens=60, prompt_len=20)
            for i in range(4)]
    outs, stats = svc.replay(reqs)
    reasons = {o.finish_reason for o in outs if o is not None}
    assert FINISH_DROPPED in reasons
    assert stats.n_dropped >= 1
    # dropped requests still carry metrics and free their pages
    assert backend.allocator.num_free == backend.allocator.num_blocks


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(n=0)
    sp = SamplingParams(temperature=0.7, n=3, seed=4,
                        stop_token_ids=[1, 2])
    assert sp.stop_token_ids == (1, 2)
    child = sp.for_sample(1)
    assert child.n == 1 and child.seed != sp.seed
