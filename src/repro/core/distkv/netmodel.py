"""Network/serialization cost model for cross-instance KV movement.

The cluster router can serve a published prefix to a peer instance two ways,
and both cost real network time that the virtual-clock simulator must charge
(a copy looked free before, which made every comparison flatter it):

* **copy** — ship the page payloads once and adopt them into the peer's own
  radix tree. Cost: per-page serialization/RPC overhead plus payload bytes
  over the interconnect, paid once per adopting instance; serving afterwards
  is local.
* **borrow (zero-copy)** — lease the home instance's physical pages
  (rBlocks) and serve them in place through the DistAttention partial
  ``(o, m, l)`` merge. Cost: a small lease RPC up front, then a per-iteration
  merge round plus remote context reads for as long as the borrower decodes.

``prefer_borrow`` is the myopic per-request decision ``share_mode="auto"``
uses: borrow when the estimated lifetime borrow overhead undercuts the
one-time payload transfer — hot *short* prefixes with modest decode lengths
borrow, long prefixes ahead of long decodes copy. The crossover is measured
by ``benchmarks/zero_copy_sweep.py``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class NetworkModel:
    """Per-page transfer latency + bandwidth, and partial-merge overhead.

    Defaults sketch a 100 Gb/s datacenter link serving OPT-13B-ish pages
    (2 [K+V] * 16 tokens * 40 layers * 5120 dim * 2 bytes ~= 13 MB/page).
    """

    gbps: float = 100.0          # interconnect bandwidth
    # serialized payload of one page under the *default* (GQA-ish) layout.
    # Every byte-charging method takes an optional per-call ``page_bytes``
    # override so compressed layouts (MLA latent pages are ~10x smaller)
    # are charged their actual wire bytes — see ``KVPageLayout.page_bytes``
    # and :meth:`for_layout`.
    page_bytes: int = 13_107_200  # serialized K+V payload of one page
    t_page_fixed: float = 40e-6  # per-page serialization + RPC overhead
    t_lease_fixed: float = 20e-6  # one-time lease/borrow RPC per request
    # one partial (o, m, l) merge round per borrowing request per iteration:
    # the partials are tiny (per-head stats), so this is latency, not bytes
    t_merge: float = 30e-6
    # remote context read per borrowed token per iteration (DistAttention
    # computes the micro-attention where the block lives and ships only the
    # partials, so this is coordination cost, not a page read — mirrors
    # CostModel.c_remote)
    c_remote_token: float = 6e-9
    # host swap lane: device<->host page movement rides PCIe, not the
    # interconnect. 256 Gb/s = 32 GB/s, a PCIe 5.0 x16 link's practical
    # throughput; t_swap_fixed covers the DMA setup per batched transfer
    pcie_gbps: float = 256.0
    t_swap_fixed: float = 20e-6
    # peer spill lane: device->device page movement between co-located
    # instances rides an NVLink-class link — much wider than the PCIe host
    # lane, which is what makes a neighbor's free device memory a better
    # spill target than host when one is available
    nvlink_gbps: float = 600.0

    @classmethod
    def for_layout(cls, layout, page_size: int, **overrides) -> "NetworkModel":
        """A model whose default ``page_bytes`` matches a ``KVPageLayout``."""
        overrides.setdefault("page_bytes", layout.page_bytes(page_size))
        return cls(**overrides)

    def _pb(self, page_bytes) -> int:
        return self.page_bytes if page_bytes is None else page_bytes

    def swap_time(self, n_pages: int, *, page_bytes: int = None) -> float:
        """One direction of a swap: ``n_pages`` over PCIe plus one DMA
        setup. A swap round trip (out now, in later) costs twice this."""
        if n_pages <= 0:
            return 0.0
        wire = self._pb(page_bytes) * 8.0 / (self.pcie_gbps * 1e9)
        return self.t_swap_fixed + n_pages * wire

    def peer_copy_time(self, n_pages: int, *, page_bytes: int = None) -> float:
        """One direction of a peer spill/restore: ``n_pages`` device pages
        moved to/from a neighbor instance over the NVLink-class lane, plus
        one transfer setup."""
        if n_pages <= 0:
            return 0.0
        wire = self._pb(page_bytes) * 8.0 / (self.nvlink_gbps * 1e9)
        return self.t_swap_fixed + n_pages * wire

    def page_copy_time(self, n_pages: int, *, page_bytes: int = None) -> float:
        """One-time payload transfer of ``n_pages`` (copy-mode adoption)."""
        wire = self._pb(page_bytes) * 8.0 / (self.gbps * 1e9)
        return n_pages * (self.t_page_fixed + wire)

    def lease_time(self, n_pages: int) -> float:
        """Borrow setup: one RPC, block ids only (no payload)."""
        return self.t_lease_fixed

    def borrow_iter_overhead(self, n_borrowing: int) -> float:
        """Per-iteration merge cost for ``n_borrowing`` requests whose
        attention gathered remote partials this iteration."""
        return n_borrowing * self.t_merge

    def borrow_lifetime_cost(self, n_pages: int, page_size: int,
                             est_decode_tokens: int) -> float:
        """Estimated total overhead of serving a prefix remotely for one
        request's lifetime (~one iteration per decoded token)."""
        per_iter = self.t_merge + self.c_remote_token * n_pages * page_size
        return self.lease_time(n_pages) + est_decode_tokens * per_iter

    def prefer_borrow(self, n_pages: int, page_size: int,
                      est_decode_tokens: int,
                      expected_reuse: float = 1.0, *,
                      page_bytes: int = None) -> bool:
        """The ``share_mode="auto"`` decision for one admission.

        ``expected_reuse`` amortizes the one-time copy across the requests
        expected to hit the same prefix on this instance (the share board's
        per-(instance, prefix) lease hit-count plus this one): a prefix that
        keeps getting leased tips toward copying, because the payload
        transfer is paid once while every borrower pays merge overhead for
        its whole decode. ``expected_reuse=1`` is the original myopic
        per-request decision."""
        copy_amortized = self.page_copy_time(
            n_pages, page_bytes=page_bytes) / max(expected_reuse, 1.0)
        return self.borrow_lifetime_cost(
            n_pages, page_size, est_decode_tokens) < copy_amortized
