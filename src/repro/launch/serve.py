"""Serving launcher: the LLMService front-end over either backend — the real
continuous-batching engine (wall-clock) or the cost-model simulator (virtual
clock) — with a synthetic open-loop request stream. ``--instances N`` puts a
cluster RouterBackend in front of N instances (placement via ``--policy``,
cross-instance prefix sharing via ``--prefix-share``).

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --reduced --requests 16 --rate 4
  PYTHONPATH=src python -m repro.launch.serve --backend sim --requests 200
  PYTHONPATH=src python -m repro.launch.serve --backend sim --requests 400 \
      --instances 4 --policy prefix_affinity --prefix-cache --prefix-share
  PYTHONPATH=src python -m repro.launch.serve --backend sim --requests 200 \
      --roles 2p2d --handoff-mode auto
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.serving.api import LLMService, SamplingParams


def build_netmodel(args):
    # no --net-gbps: network accounting stays off for copy AND zero_copy
    # alike (an asymmetric default would bias their comparison); share-mode
    # auto forces a model (its decision needs one), and so does explicit
    # swap-lane calibration (--pcie-gbps / --t-swap-fixed must reach the
    # backend's swap_net instead of silently using defaults)
    calibrated = args.pcie_gbps is not None or args.t_swap_fixed is not None
    if args.net_gbps is None and not calibrated \
            and args.share_mode != "auto":
        return None
    from repro.core.distkv.netmodel import NetworkModel
    kw = {}
    if args.net_gbps is not None:
        kw["gbps"] = args.net_gbps
    if args.pcie_gbps is not None:
        kw["pcie_gbps"] = args.pcie_gbps
    if args.t_swap_fixed is not None:
        kw["t_swap_fixed"] = args.t_swap_fixed
    return NetworkModel(**kw)


def build_instance(args):
    telemetry = bool(args.trace or args.metrics_csv)
    if args.backend == "sim":
        from repro.serving.simulator import SimBackend
        return SimBackend(num_blocks=args.pages, block_size=args.page_size,
                          max_running=args.slots,
                          prefix_cache=args.prefix_cache,
                          chunk_policy=args.chunk_policy,
                          host_blocks=args.host_pages,
                          swap_mode=args.swap_mode,
                          victim_policy=args.victim_policy,
                          swap_overlap=args.swap_overlap,
                          speculative_swap=args.speculative_swap,
                          cache_spill_pages=args.cache_spill_pages,
                          net=build_netmodel(args), trace=telemetry)
    import jax
    from repro.models import Model
    from repro.serving.engine import EngineConfig, PagedEngine
    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return PagedEngine(cfg, params, EngineConfig(
        num_pages=args.pages, page_size=args.page_size,
        max_slots=args.slots, use_kernel=args.use_kernel,
        enable_prefix_cache=args.prefix_cache,
        chunk_policy=args.chunk_policy, enable_telemetry=telemetry,
        host_pages=args.host_pages, swap_mode=args.swap_mode,
        victim_policy=args.victim_policy,
        speculative_swap=args.speculative_swap,
        cache_spill_pages=args.cache_spill_pages))


def parse_roles_arg(args):
    """Validate --roles early with a launcher-grade error (SystemExit, not
    a traceback), and reconcile it with --instances."""
    if args.roles is None:
        return None
    from repro.serving.disagg import parse_role_spec
    try:
        roles = parse_role_spec(args.roles)
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    if args.instances > 1 and args.instances != len(roles):
        raise SystemExit(
            f"error: --roles {args.roles!r} names {len(roles)} instances "
            f"but --instances is {args.instances} — drop --instances (the "
            f"spec sets the count) or make them agree")
    return roles


def build_backend(args):
    if args.prefix_share and not args.prefix_cache:
        raise SystemExit("--prefix-share requires --prefix-cache")
    if args.prefix_share and args.instances <= 1:
        raise SystemExit("--prefix-share requires --instances >= 2 "
                         "(there is no peer to share with)")
    if args.share_mode != "copy" and not args.prefix_share:
        raise SystemExit("--share-mode zero_copy/auto requires "
                         "--prefix-share")
    roles = parse_roles_arg(args)
    if roles is not None:
        args.instances = len(roles)
    if args.instances <= 1:
        return build_instance(args)
    from repro.serving.router import RouterBackend
    children = [build_instance(args) for _ in range(args.instances)]
    try:
        return RouterBackend(children, policy=args.policy,
                             prefix_share=args.prefix_share,
                             share_mode=args.share_mode,
                             board_pages=args.board_pages,
                             net=build_netmodel(args),
                             roles=roles,
                             handoff_mode=args.handoff_mode)
    except ValueError as e:  # e.g. a role spec with no decode instance
        raise SystemExit(f"error: {e}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("engine", "sim"), default="engine",
                    help="real PagedEngine (wall-clock) or cost-model "
                         "SimBackend (virtual clock) — same LLMService API")
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pages", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--best-of", type=int, default=1,
                    help="n parallel samples per prompt (COW-forked KV)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="Pallas paged-attention (interpret mode on CPU)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prefix KV cache (cross-request reuse)")
    from repro.core.scheduling import CHUNK_POLICIES
    ap.add_argument("--chunk-policy", default="decode_first",
                    choices=CHUNK_POLICIES,
                    help="chunked-prefill budget policy: decode_first "
                         "(Sarathi stall-free), prefill_first (TTFT-"
                         "optimal), monolithic (whole prompt in one "
                         "iteration next to the decodes), or solo (legacy: "
                         "over-budget prompts wait for an idle engine)")
    from repro.core.scheduling.iteration import SWAP_MODES, VICTIM_POLICIES
    ap.add_argument("--host-pages", type=int, default=0,
                    help="host (CPU) KV pages backing swap-to-host "
                         "preemption and prefix-cache spill (0 = no host "
                         "tier, preemption always recomputes)")
    ap.add_argument("--swap-mode", default="sacrifice", choices=SWAP_MODES,
                    help="what preemption does to a victim's computed KV: "
                         "sacrifice (free + re-prefill later), swap (move "
                         "to host pages over PCIe, resume without "
                         "re-prefill), or auto (per-victim cost decision)")
    ap.add_argument("--victim-policy", default="lifo",
                    choices=VICTIM_POLICIES,
                    help="which running request is preempted/swapped under "
                         "memory pressure: lifo (newest), fifo (oldest), "
                         "lru (least recently scheduled), or cost (cheapest "
                         "modeled eviction per freed page)")
    ap.add_argument("--swap-overlap", action="store_true",
                    help="sim backend: double-buffer PCIe swap DMAs against "
                         "each iteration's compute (only the surplus past "
                         "the compute time is charged)")
    ap.add_argument("--speculative-swap", action="store_true",
                    help="issue decode swap-outs one iteration early when "
                         "free pages trend under the watermark, cancelling "
                         "if pressure recedes (issue/complete halves over "
                         "the allocator's pending ledger)")
    ap.add_argument("--pcie-gbps", type=float, default=None,
                    help="swap-lane calibration: PCIe bandwidth for the "
                         "NetworkModel's device<->host swap time (default: "
                         "the model's 256 Gb/s)")
    ap.add_argument("--t-swap-fixed", type=float, default=None,
                    help="swap-lane calibration: per-batched-DMA setup time "
                         "in seconds (default: the model's 20us)")
    ap.add_argument("--cache-spill-pages", type=int, default=0,
                    help="host pages the prefix cache may use to spill "
                         "cold cached prefixes instead of evicting them "
                         "(bounded LRU; needs --host-pages and "
                         "--prefix-cache)")
    ap.add_argument("--instances", type=int, default=1,
                    help="serving instances behind the cluster router "
                         "(1 = no router)")
    ap.add_argument("--policy", default="round_robin",
                    choices=("round_robin", "least_loaded",
                             "prefix_affinity"),
                    help="router placement policy")
    ap.add_argument("--roles", default=None, metavar="SPEC",
                    help="disaggregated prefill/decode roles as "
                         "<count><p|d|m> groups, e.g. '2p2d' = 2 prefill + "
                         "2 decode instances; implies the instance count. "
                         "Prompts land on prefill instances, finished KV "
                         "is handed to decode instances")
    from repro.serving.disagg import HANDOFF_MODES
    ap.add_argument("--handoff-mode", default="auto", choices=HANDOFF_MODES,
                    help="how prefill->decode KV handoff moves the prompt "
                         "KV: migrate page payloads, zero_copy lease the "
                         "prefill host's pages in place, or auto "
                         "(per-request network-cost decision)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="publish hot radix paths through the distkv board "
                         "so instances adopt each other's cached prefixes "
                         "(needs --prefix-cache)")
    ap.add_argument("--board-pages", type=int, default=None,
                    help="size cap (pages) for the cross-instance "
                         "publication board; LRU pages are evicted past it "
                         "(default: unbounded)")
    from repro.serving.router import SHARE_MODES
    ap.add_argument("--share-mode", default="copy", choices=SHARE_MODES,
                    help="how a published prefix reaches a peer instance: "
                         "copy its page payloads once, zero_copy serve it "
                         "in place over borrowed rBlocks (DistAttention "
                         "partial merge), or auto (per-request network-"
                         "cost decision)")
    ap.add_argument("--net-gbps", type=float, default=None,
                    help="interconnect bandwidth for the network cost "
                         "model (sim backend charges payload copies and "
                         "lease RPCs; default: no network accounting, "
                         "except share-mode auto which needs the model)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable telemetry and export a Chrome/Perfetto "
                         "trace-event JSON (open in ui.perfetto.dev or "
                         "chrome://tracing) after the run")
    ap.add_argument("--metrics-csv", metavar="PATH", default=None,
                    help="enable telemetry and dump per-iteration metric "
                         "timelines (one row per instance-iteration) as "
                         "CSV after the run")
    args = ap.parse_args()

    backend = build_backend(args)
    svc = LLMService(backend)
    instance = backend.children[0] if hasattr(backend, "children") \
        else backend
    vocab = 32_000 if args.backend == "sim" else instance.cfg.vocab_size

    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        svc.submit(rng.integers(0, vocab, plen).tolist(),
                   SamplingParams(temperature=args.temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  n=args.best_of,
                                  max_new_tokens=int(rng.integers(
                                      2, args.max_new)),
                                  seed=int(i)),
                   arrival_time=float(arrivals[i]))

    t0 = time.monotonic()
    while svc.pending:
        now = time.monotonic() - t0 if args.backend == "engine" else None
        for ch in svc.poll(now):
            if ch.finished:
                t = ch.time if ch.time is not None else now
                print(f"[{t:7.2f}s] req {ch.request_id} done: "
                      f"{ch.n_generated} tokens ({ch.finish_reason})")
        if args.backend == "engine" and not backend.has_work and svc.pending:
            time.sleep(0.005)  # wait for the next wall-clock arrival

    stats = svc.stats()
    dt = time.monotonic() - t0 if args.backend == "engine" else stats.makespan
    print(f"served {stats.n_finished}/{stats.n_requests} requests, "
          f"{stats.total_tokens} tokens in {dt:.1f}s "
          f"({stats.total_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{backend.iterations} iterations); "
          f"mean ttft {stats.mean_ttft * 1e3:.1f}ms, "
          f"mean norm-lat {stats.mean_normalized_latency:.3f}s/tok")
    if stats.p99_tbt != float("inf"):
        print(f"p99 worst inter-token gap {stats.p99_tbt * 1e3:.1f}ms, "
              f"prefill stall {stats.prefill_stall_ms:.1f}ms "
              f"(chunk policy: {args.chunk_policy})")
    if stats.prefix_hit_rate is not None:
        print(f"prefix-cache hit-rate {stats.prefix_hit_rate:.1%}")
    kids = getattr(backend, "children", [backend])
    n_so = sum(getattr(c, "swapped_out", 0) for c in kids)
    n_si = sum(getattr(c, "swapped_in", 0) for c in kids)
    if n_so or n_si:
        print(f"host swap: {n_so} swap-outs, {n_si} swap-ins "
              f"(mode: {args.swap_mode}, victims: {args.victim_policy}, "
              f"{args.host_pages} host pages)")
    if getattr(backend, "pages_borrowed", 0):
        print(f"zero-copy: {backend.leases_granted} leases, "
              f"{backend.pages_borrowed} pages served remotely "
              f"(share mode: {args.share_mode})")
    ho = getattr(backend, "handoff", None)
    if ho is not None:
        print(f"disagg: {ho.handoffs_migrated} migrated + "
              f"{ho.handoffs_leased} leased KV handoffs "
              f"({ho.pages_copied} pages copied, {ho.pages_leased} leased, "
              f"{ho.deferrals} deferrals, {ho.fallbacks} fallbacks; "
              f"mode: {args.handoff_mode})")
    if stats.per_instance:
        for i, row in sorted(stats.per_instance.items()):
            extra = ""
            if "prefix_hit_rate" in row:
                extra = (f", hit {row['prefix_hit_rate']:.1%}, "
                         f"{row['adopted_pages']} adopted pages")
            print(f"  instance {i}: {row['requests']} reqs, "
                  f"{row['iterations']} iters{extra}")
    if args.trace:
        n = svc.export_trace(args.trace)
        print(f"wrote {n} trace events to {args.trace} "
              f"(open in https://ui.perfetto.dev)")
    if args.metrics_csv:
        n = svc.export_metrics_csv(args.metrics_csv)
        print(f"wrote {n} metric rows to {args.metrics_csv}")


if __name__ == "__main__":
    main()
