"""§II.B.5 — the comparison experiment the paper could not run.

The paper proposed NSGA-II chain selection for PETALS but lacked a private
swarm to evaluate it. Our swarm simulator provides one: random heterogeneous
fleets, comparing

* PETALS ``find_best_chain`` (Dijkstra, min-latency)       [baseline]
* PETALS max-throughput mode                                [baseline]
* the paper's NSGA-II "Latency-Throughput-Tradeoff" mode   [contribution]

Metrics: realized chain time (s/step), bottleneck throughput, Pareto
hypervolume, and wall-clock cost of the optimizer itself.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.chain import (find_best_chain, hypervolume_2d, knee_chain,
                              latency_throughput_tradeoff, make_fleet)


def run(n_fleets: int = 8, blocks: int = 24, servers: int = 24,
        generations: int = 40, verbose: bool = True):
    rows = []
    for seed in range(n_fleets):
        fleet = make_fleet(blocks, servers, seed=seed)
        t0 = time.monotonic()
        dij = find_best_chain(fleet)
        t_dij = time.monotonic() - t0
        thr = find_best_chain(fleet, mode="max_throughput")
        t0 = time.monotonic()
        res = latency_throughput_tradeoff(fleet, pop_size=60,
                                          generations=generations, seed=seed)
        t_ga = time.monotonic() - t0
        res_real = latency_throughput_tradeoff(
            fleet, pop_size=60, generations=generations, seed=seed,
            objectives="realized", memetic_seed=True)
        knee = knee_chain(res)
        best_time = min(c.total_time for c in res.chains)
        best_thr = max(c.bottleneck_throughput for c in res.chains)
        # hypervolume of the realized (time, -throughput) front vs baselines
        pts = np.array([[c.total_time, -c.bottleneck_throughput]
                        for c in res.chains])
        pts_real = np.array([[c.total_time, -c.bottleneck_throughput]
                             for c in res_real.chains])
        base_pts = np.array([[dij.total_time, -dij.bottleneck_throughput],
                             [thr.total_time, -thr.bottleneck_throughput]])
        ref = np.array([max(pts[:, 0].max(), base_pts[:, 0].max(),
                            pts_real[:, 0].max()) * 1.1, 0.0])
        hv_ga = hypervolume_2d(pts, ref)
        hv_real = hypervolume_2d(pts_real, ref)
        hv_base = hypervolume_2d(base_pts, ref)
        rows.append(dict(
            seed=seed, dij_time=dij.total_time,
            dij_thr=dij.bottleneck_throughput,
            maxthr_time=thr.total_time, maxthr_thr=thr.bottleneck_throughput,
            ga_best_time=best_time, ga_best_thr=best_thr,
            real_best_time=min(c.total_time for c in res_real.chains),
            knee_time=knee.total_time, knee_thr=knee.bottleneck_throughput,
            hv_ga=hv_ga, hv_real=hv_real, hv_base=hv_base,
            pareto=len(res.chains),
            t_dij_ms=t_dij * 1e3, t_ga_ms=t_ga * 1e3,
        ))
        if verbose:
            r = rows[-1]
            print(f"fleet {seed}: dijkstra {r['dij_time']:.2f}s/"
                  f"{r['dij_thr']:.1f}bps | NSGA-II(paper) best "
                  f"{r['ga_best_time']:.2f}s | NSGA-II(realized) best "
                  f"{r['real_best_time']:.2f}s | HV paper {r['hv_ga']:.1f} "
                  f"realized {r['hv_real']:.1f} baseline {r['hv_base']:.1f}"
                  f" | cost {r['t_ga_ms']:.0f}ms vs {r['t_dij_ms']:.1f}ms")
    agg = {k: float(np.mean([r[k] for r in rows])) for k in rows[0]
           if k != "seed"}
    if verbose:
        print(f"\nmean HV: paper-objectives "
              f"{agg['hv_ga']/max(agg['hv_base'],1e-9):.2f}x of baseline "
              f"(the paper's objective design is dominated); "
              f"realized-objectives {agg['hv_real']/max(agg['hv_base'],1e-9):.2f}x "
              f"(beyond-paper fix wins the tradeoff front); "
              f"min-latency gap realized/dijkstra "
              f"{agg['real_best_time']/agg['dij_time']:.2f}x")
    return rows, agg


if __name__ == "__main__":
    run()
