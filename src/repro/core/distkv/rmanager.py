"""InfiniteLLM rManager: per-instance rBlock virtualization (paper §III.D.3).

Each LLM service instance owns a local :class:`BlockAllocator` and virtualizes
it behind **rBlocks** — (instance_id, physical_block) pairs with metadata. On
local exhaustion the rManager turns debtor: asks the gManager for creditor
candidates and borrows physical blocks that live on a *remote* instance.
Attention over borrowed blocks is exactly the DistAttention micro-attention
path (``dist_attention.py``): partial (m, l, o) computed where the block
lives, merged by log-sum-exp.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core.distkv.gmanager import GManager, Heartbeat
from repro.core.paging.allocator import BlockAllocator, OutOfBlocks


@dataclasses.dataclass(frozen=True)
class RBlock:
    """The paper's rBlock metadata: ids + physical location."""
    rblock_id: int
    instance_id: int  # owning (home) instance of the *sequence*
    device_id: int    # instance where the physical block lives
    physical_id: int


@dataclasses.dataclass
class SeqKV:
    """A sequence's logical KV: ordered rBlocks (possibly multi-instance)."""
    rblocks: List[RBlock] = dataclasses.field(default_factory=list)
    num_tokens: int = 0


class RManager:
    def __init__(self, instance_id: int, allocator: BlockAllocator,
                 gmanager: GManager):
        self.instance_id = instance_id
        self.allocator = allocator
        self.g = gmanager
        self.peers: Dict[int, "RManager"] = {}
        self._next_rblock = 0
        self.seqs: Dict[int, SeqKV] = {}
        self.heartbeat()

    def register_peers(self, peers: Dict[int, "RManager"]) -> None:
        self.peers = peers

    def heartbeat(self) -> None:
        self.g.heartbeat(Heartbeat(self.instance_id,
                                   self.allocator.num_free,
                                   self.allocator.num_blocks))

    # -- lending side -----------------------------------------------------------
    def try_lend(self, debtor: int) -> Optional[int]:
        """Allocate one local physical block on behalf of ``debtor``."""
        if self.allocator.num_free <= self.g.safety_free:
            return None
        b = self.allocator.alloc_block()
        self.g.record_loan(self.instance_id, debtor, 1)
        self.heartbeat()
        return b

    def repay(self, creditor: int, physical_id: int) -> None:
        self.peers[creditor].allocator.decref(physical_id)
        self.g.record_repayment(creditor, self.instance_id, 1)
        self.peers[creditor].heartbeat()

    # -- borrowing side -----------------------------------------------------------
    def _alloc_one(self) -> RBlock:
        rid = self._next_rblock
        self._next_rblock += 1
        try:
            phys = self.allocator.alloc_block()
            self.heartbeat()
            return RBlock(rid, self.instance_id, self.instance_id, phys)
        except OutOfBlocks:
            pass
        # debtor path: ask the gManager for up to 3 creditors, try in order
        for cred in self.g.recommend_creditors(self.instance_id, 1):
            phys = self.peers[cred].try_lend(self.instance_id)
            if phys is not None:
                return RBlock(rid, self.instance_id, cred, phys)
        raise OutOfBlocks(f"instance {self.instance_id}: no local or remote "
                          f"blocks available")

    # -- sequence API ---------------------------------------------------------------
    def append_tokens(self, seq_id: int, new_tokens: int) -> List[RBlock]:
        """Grow a sequence; returns newly-allocated rBlocks. Atomic: if the
        cluster cannot supply all needed blocks, everything allocated so far
        is returned/repaid and OutOfBlocks propagates."""
        kv = self.seqs.setdefault(seq_id, SeqKV())
        bs = self.allocator.block_size
        total = kv.num_tokens + new_tokens
        need = -(-total // bs) - len(kv.rblocks)
        added: List[RBlock] = []
        try:
            for _ in range(need):
                rb = self._alloc_one()
                added.append(rb)
        except OutOfBlocks:
            for rb in added:  # roll back
                if rb.device_id == self.instance_id:
                    self.allocator.decref(rb.physical_id)
                else:
                    self.repay(rb.device_id, rb.physical_id)
            self.heartbeat()
            raise
        kv.rblocks.extend(added)
        kv.num_tokens = total
        return added

    def free_seq(self, seq_id: int) -> None:
        kv = self.seqs.pop(seq_id, None)
        if kv is None:
            return
        for rb in kv.rblocks:
            if rb.device_id == self.instance_id:
                self.allocator.decref(rb.physical_id)
            else:
                self.repay(rb.device_id, rb.physical_id)
        self.heartbeat()

    # -- cross-instance prefix sharing -------------------------------------------
    def publish_prefix(self, tokens, payloads) -> int:
        """Publish a hot page-aligned prefix (token keys + page payloads)
        computed on this instance to the cluster's board (on the gManager,
        like the debt ledger). Peers adopt via :meth:`lookup_prefix` +
        ``PrefixCache.adopt``."""
        return self.g.prefix_board.publish(self.instance_id, tokens, payloads,
                                           self.allocator.block_size)

    def lookup_prefix(self, tokens, max_tokens=None):
        """Longest published page chain for ``tokens`` (any home instance)."""
        return self.g.prefix_board.match(tokens, max_tokens=max_tokens)

    # -- stats ------------------------------------------------------------------
    def remote_fraction(self, seq_id: int) -> float:
        kv = self.seqs.get(seq_id)
        if not kv or not kv.rblocks:
            return 0.0
        remote = sum(1 for rb in kv.rblocks
                     if rb.device_id != self.instance_id)
        return remote / len(kv.rblocks)
