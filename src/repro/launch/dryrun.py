import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes; print memory/cost analysis; emit roofline JSON.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import, including jax, because jax locks the device count on first
init). Never import this module from tests/benches.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-large-123b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every combination
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ARCH_IDS, INPUT_SHAPES, get_config,  # noqa: E402
                           input_specs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.roofline import analysis  # noqa: E402
from repro.training import optimizer  # noqa: E402
from repro.training.train_loop import make_train_step  # noqa: E402


def _eval_shape_params(model: Model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full quadratic attention at 524k decode is out of scope for "
                "this arch (no sliding-window/SSM path) — see DESIGN.md §4")
    return ""


def _cap_plan(model: Model, cap: int) -> int:
    """Cap every stacked segment at ``cap`` layers (for cost extrapolation).
    Returns total layer count of the capped plan."""
    import dataclasses as dc
    model.plan = [dc.replace(s, n=min(s.n, cap)) for s in model.plan]
    model.enc_plan = [dc.replace(s, n=min(s.n, cap))
                      for s in model.enc_plan]
    return sum(s.n for s in model.plan + model.enc_plan)


def build(cfg, shape, mesh, *, unroll: bool = False, cap: int = 0):
    """Returns (jitted_fn, arg_specs: tuple, arg_shardings: tuple).

    ``unroll``: fully unroll layer scans (XLA costs a while body once
    regardless of trip count, so FLOPs/collective bytes need unrolled HLO).
    ``cap``: cap stacked segments at this many layers — the dry-run compiles
    capped-unrolled variants at 2 and 4 layers and extrapolates linearly
    (exact, since layers within a segment are structurally identical).
    Returns the capped total layer count as the 3rd element when cap>0."""
    from repro.models import attention as _attn
    _attn.CHUNK_UNROLL = unroll  # count every attention chunk (see module doc)
    model = Model(cfg, remat=(shape.kind == "train"), unroll_layers=unroll)
    n_layers = None
    if cap:
        n_layers = _cap_plan(model, cap)
    policy = shd.MeshPolicy(mesh, cfg, decode=shape.kind == "decode",
                            megatron=os.environ.get("REPRO_LAYOUT",
                                                    "megatron") == "megatron")
    p_shape = _eval_shape_params(model)
    p_shard = shd.param_shardings(p_shape, mesh, cfg)
    specs = input_specs(cfg, shape)

    if shape.kind == "train":
        o_shape = jax.eval_shape(optimizer.init, p_shape)
        o_shard = shd.param_shardings(o_shape, mesh, cfg)
        b_shard = shd.batch_shardings(specs, mesh, cfg)
        step = make_train_step(model, optimizer.OptConfig(), policy)
        fn = jax.jit(step,
                     in_shardings=(p_shard, o_shard, b_shard),
                     out_shardings=(p_shard, o_shard, None),
                     donate_argnums=(0, 1))
        return fn, (p_shape, o_shape, specs), n_layers

    if shape.kind == "prefill":
        b_shard = shd.batch_shardings(specs, mesh, cfg)

        def prefill_step(params, batch):
            return model.prefill(
                params, batch["tokens"],
                seq_capacity=shape.seq_len,
                media=batch.get("media"),
                encoder_tokens=batch.get("encoder_tokens"),
                policy=policy)

        fn = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
        return fn, (p_shape, specs), n_layers

    # decode: one new token against a seq_len cache
    enc_len = (shape.seq_len // 4) if cfg.is_encdec else 0
    cache_specs = model.init_cache(shape.global_batch, shape.seq_len,
                                   as_specs=True, enc_len=enc_len)
    c_shard = shd.cache_shardings(cache_specs, mesh, cfg,
                                  shape.global_batch)
    b_shard = shd.batch_shardings(specs, mesh, cfg)

    def serve_step(params, caches, batch):
        return model.decode_step(params, batch["tokens"],
                                 batch["positions"], caches,
                                 policy=policy)

    fn = jax.jit(serve_step, in_shardings=(p_shard, c_shard, b_shard),
                 donate_argnums=(1,))
    return fn, (p_shape, cache_specs, specs), n_layers


def run_one(arch: str, shape_name: str, multi_pod: bool, outdir: str,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    reason = skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        _write(rec, outdir)
        if verbose:
            print(f"SKIP {arch} {shape_name} {mesh_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.monotonic()
    with jax.sharding.set_mesh(mesh):
        # 1) deployable scan version: memory analysis + compile timing
        fn, arg_specs, _ = build(cfg, shape, mesh)
        lowered = fn.lower(*arg_specs)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower
        # 2) cost model: capped-unrolled variants at 2 and 4 layers per
        # segment; per-layer cost is exact within a segment, so the full
        # model's FLOPs/bytes/collectives extrapolate linearly.
        roof = None
        if not multi_pod:  # roofline table is single-pod (spec)
            fn2, specs2, l2 = build(cfg, shape, mesh, unroll=True, cap=2)
            c2 = fn2.lower(*specs2).compile()
            r2 = analysis.analyze(c2, chips)
            fn4, specs4, l4 = build(cfg, shape, mesh, unroll=True, cap=4)
            c4 = fn4.lower(*specs4).compile()
            r4 = analysis.analyze(c4, chips)
            l_full = sum(s.n for s in Model(cfg).plan) + \
                sum(s.n for s in Model(cfg).enc_plan)
            roof = analysis.extrapolate(r2, r4, l2, l4, l_full)

    mem = compiled.memory_analysis()
    mf = analysis.model_flops(cfg, shape)
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "model_flops_total": mf,
        "model_flops_per_device": mf / chips,
    })
    if roof is not None:
        rec["roofline"] = roof.as_dict()
        rec["useful_flop_frac"] = (mf / chips) / max(roof.flops, 1.0)
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        args_b = rec.get("argument_size_in_bytes", 0)
        temp_b = rec.get("temp_size_in_bytes", 0)
        rec["hbm_per_device_gib"] = round((args_b + temp_b) / 2**30, 3)
        rec["fits_16gib"] = (args_b + temp_b) < 16 * 2**30
    if verbose:
        msg = (f"OK {arch} {shape_name} {mesh_name}: "
               f"compile={rec['compile_s']}s "
               f"hbm/dev={rec.get('hbm_per_device_gib', '?')}GiB")
        if roof is not None:
            msg += (f" t_comp={roof.t_compute:.4f}s "
                    f"t_mem={roof.t_memory:.4f}s "
                    f"t_coll={roof.t_collective:.4f}s -> {roof.bottleneck}; "
                    f"useful={rec['useful_flop_frac']:.2f}")
        print(msg)
        print("  memory_analysis:", mem)
    _write(rec, outdir)
    return rec


def _write(rec, outdir):
    os.makedirs(outdir, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    with open(os.path.join(outdir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    if args.all:
        fails = []
        for arch in ARCH_IDS:
            for shape in INPUT_SHAPES:
                for mp in (False, True):
                    try:
                        run_one(arch, shape, mp, args.out)
                    except Exception as e:  # noqa: BLE001
                        print(f"FAIL {arch} {shape} mp={mp}: {e}")
                        fails.append((arch, shape, mp))
        if fails:
            sys.exit(1)
        return
    run_one(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
