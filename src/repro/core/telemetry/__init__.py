"""Unified telemetry: structured event tracing, per-iteration metric
timelines, and Chrome/Perfetto trace export for the serving stack."""

from .tracer import Event, Tracer, merge_events
from .metrics import Histogram, MetricsRegistry, percentile
from .export import (
    export_chrome_trace,
    export_metrics_csv,
    export_metrics_json,
    to_chrome_trace,
    validate_swap_balance,
    validate_trace_events,
)

__all__ = [
    "Event",
    "Tracer",
    "merge_events",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "export_chrome_trace",
    "export_metrics_csv",
    "export_metrics_json",
    "to_chrome_trace",
    "validate_swap_balance",
    "validate_trace_events",
]
