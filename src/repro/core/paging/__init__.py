from repro.core.paging.allocator import (  # noqa: F401
    BlockAllocator, BlockTable, ContiguousPreallocAllocator, OutOfBlocks,
    OutOfHostBlocks)
