"""Swap-to-host KV tier: preempted KV survives on host pages instead of
being recomputed.

Covers the PR's acceptance criteria and satellites: allocator host-tier
ledger round trips (and ``free_table`` draining host pages — satellite 3),
scheduler swap-out/swap-in semantics (a swapped request resumes decode with
NO re-prefill — the tentpole claim), victim policies, the abandon path for
snapshots that can never fit again, the preempted-victim prefix-credit fix
(suffix-only recompute — satellite 2), radix spill-to-host, the sim page-
conservation property (hypothesis), engine swap round-trip token identity
vs the fp32 oracle, and the KVHandoff deferral-starvation fallback
(satellite 1).

The overlapped-transfer tier adds: the allocator pending ledger
(issue/complete/cancel), speculative swap-out cancellation when pressure
recedes (the pages never leave), cost-vs-lru victim divergence where cost
wins on sim throughput, the conservation property extended across random
overlap/speculation settings, engine overlapped round-trip token identity,
the peer KV spill tier (lend/restore/repay over rBlocks, sim and engine),
and the ``validate_swap_balance`` pending-span invariants."""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import smoke_config
from repro.core.paging import (BlockAllocator, KVPageLayout, OutOfBlocks,
                               OutOfHostBlocks)
from repro.core.prefixcache import PrefixCache
from repro.core.scheduling import IterationScheduler, Phase, Request
from repro.core.scheduling.iteration import (SWAP_MODES, VICTIM_POLICIES,
                                             IterationPlan)
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine
from repro.serving.simulator import SimBackend, make_workload, simulate_paged

PS = 8  # page size used throughout


def _drive(s, *reqs, max_iters=500):
    for r in reqs:
        s.add_request(r)
    it = 0.0
    for _ in range(max_iters):
        plan = s.schedule()
        if plan.empty and not plan.swap_out and not plan.swap_in \
                and not s.waiting:
            return it
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    raise AssertionError("scheduler did not drain")


def _table_of(alloc, n_tokens):
    """A fully-populated device table, as the scheduler would build it."""
    from repro.core.paging.allocator import BlockTable
    t = BlockTable(blocks=[], num_tokens=0)
    alloc.append_tokens(t, n_tokens)
    return t


# -- allocator: host-tier ledger ----------------------------------------------

def test_allocator_swap_round_trip_ledger():
    a = BlockAllocator(8, PS, host_blocks=8)
    t = _table_of(a, 3 * PS)
    assert a.num_free == 5 and a.swapped_pages == 0

    pairs = a.swap_out(t)
    assert len(pairs) == 3 and t.on_host
    assert not t.blocks and len(t.host_blocks) == 3
    assert a.num_free == 8, "device pages must be freed by swap-out"
    assert a.swapped_pages == 3 and a.host_num_free == 5
    assert t.num_tokens == 3 * PS, "logical length survives the swap"

    pairs_in = a.swap_in(t)
    assert len(pairs_in) == 3 and not t.on_host
    assert len(t.blocks) == 3 and not t.host_blocks
    assert a.num_free == 5 and a.swapped_pages == 0 and a.host_num_free == 8
    a.free_table(t)
    assert a.num_free == 8


def test_allocator_swap_out_keeps_tree_shared_pages():
    """swap_out only drops THIS table's device refs: a page also held by
    the radix tree (refcount 2) must survive for the other holder."""
    a = BlockAllocator(8, PS, host_blocks=8)
    t = _table_of(a, 2 * PS)
    shared = t.blocks[0]
    a.incref(shared)  # the radix tree's hold
    a.swap_out(t)
    assert a.refcount_of(shared) == 1, "shared page must stay alive"
    a.decref(shared)
    assert a.num_free == 8


def test_free_table_on_host_releases_host_pages():
    """Satellite 3: freeing a swapped table (finish/abandon while on host)
    must return its HOST pages too — the ledger drains to empty."""
    a = BlockAllocator(8, PS, host_blocks=8)
    t = _table_of(a, 3 * PS)
    a.swap_out(t)
    assert a.swapped_pages == 3
    a.free_table(t)
    assert a.swapped_pages == 0 and a.host_num_free == 8
    assert a.num_free == 8 and a.num_used == 0


def test_allocator_host_exhaustion_and_double_free():
    a = BlockAllocator(8, PS, host_blocks=2)
    t = _table_of(a, 3 * PS)
    assert not a.can_swap_out(t), "3 pages cannot fit in 2 host blocks"
    with pytest.raises(OutOfHostBlocks):
        a.swap_out(t)
    b = a.alloc_host_block()
    a.free_host_block(b)
    with pytest.raises(ValueError):
        a.free_host_block(b)
    a.free_table(t)


def test_allocator_swap_in_raises_untouched_when_device_full():
    a = BlockAllocator(4, PS, host_blocks=8)
    t = _table_of(a, 3 * PS)
    a.swap_out(t)
    squatter = _table_of(a, 2 * PS)  # 2 of 4 device pages taken
    with pytest.raises(OutOfBlocks):
        a.swap_in(t)
    assert t.on_host and len(t.host_blocks) == 3, \
        "a failed swap-in must leave the host snapshot untouched"
    a.free_table(squatter)
    a.swap_in(t)
    a.free_table(t)
    assert a.num_free == 4 and a.host_num_free == 8


# -- allocator: overlapped swap-out (pending ledger) ---------------------------

def test_allocator_issue_complete_matches_synchronous_swap():
    """swap_out_issue keeps the DMA source pages ALLOCATED (num_free
    unchanged) while the table is host-resident immediately; complete
    lands the ledger in exactly the synchronous swap_out end state."""
    a = BlockAllocator(8, PS, host_blocks=8)
    t = _table_of(a, 3 * PS)
    ticket, pairs = a.swap_out_issue(t)
    assert len(pairs) == 3 and t.on_host
    assert not t.blocks and len(t.host_blocks) == 3
    assert a.num_free == 5, "DMA sources stay allocated until complete"
    assert a.pending_out_pages == 3
    assert a.host_num_free == 5, "host destinations are taken at issue"
    done = a.swap_out_complete(ticket)
    assert done == pairs
    assert a.num_free == 8 and a.pending_out_pages == 0
    assert a.swapped_pages == 3 and a.host_num_free == 5
    a.swap_in(t)  # the overlapped snapshot swaps back like any other
    a.free_table(t)
    assert a.num_free == 8 and a.host_num_free == 8


def test_allocator_issue_cancel_restores_table():
    """Cancel aborts the copy: device references move back onto the table
    (the pages never left — no payload was lost) and the host pages are
    released; the ledger shows no trace of the round trip."""
    a = BlockAllocator(8, PS, host_blocks=8)
    t = _table_of(a, 3 * PS)
    dev_before = list(t.blocks)
    ticket, pairs = a.swap_out_issue(t)
    back = a.swap_out_cancel(ticket, t)
    assert back == pairs
    assert t.blocks == dev_before and not t.on_host and not t.host_blocks
    assert a.pending_out_pages == 0 and a.host_num_free == 8
    assert a.num_free == 5, "the table still owns its device pages"
    a.free_table(t)
    assert a.num_free == 8


def test_allocator_issue_guards_and_shared_pages():
    a = BlockAllocator(8, PS, host_blocks=2)
    t = _table_of(a, 3 * PS)
    with pytest.raises(OutOfHostBlocks):
        a.swap_out_issue(t)  # 3 pages cannot fit in 2 host blocks
    assert a.pending_out_pages == 0 and not t.on_host
    a.free_table(t)

    a = BlockAllocator(8, PS, host_blocks=8)
    t = _table_of(a, 2 * PS)
    shared = t.blocks[0]
    a.incref(shared)  # the radix tree's hold
    ticket, _ = a.swap_out_issue(t)
    with pytest.raises(ValueError):
        a.swap_out_issue(t)  # already host-resident
    a.swap_out_complete(ticket)
    assert a.refcount_of(shared) == 1, "tree-shared page survives complete"
    a.decref(shared)
    a.free_table(t)
    assert a.num_free == 8 and a.swapped_pages == 0


# -- scheduler: swap as a preemption mode --------------------------------------

def _crunch_scheduler(**kw):
    """Two decoders on a device sized so growth forces one eviction."""
    kw.setdefault("swap_mode", "swap")
    a = BlockAllocator(8, PS, host_blocks=16)
    s = IterationScheduler(a, max_tokens_per_iter=64, **kw)
    return a, s


def test_swap_out_resumes_decode_without_reprefill():
    """THE tentpole acceptance: a swapped-out decoder re-enters WAITING
    holding host pages, swaps back in once pages free up, and resumes
    decode with ZERO further prefill chunks — prefilled_len, output, and
    the no-recompute budget all survive the round trip."""
    a, s = _crunch_scheduler()
    A = Request(0, 0.0, list(range(17)), max_new_tokens=24)
    B = Request(1, 0.0, list(range(100, 117)), max_new_tokens=24)
    chunks_after_swap_in = []
    swapped_back = set()
    for r in (A, B):
        s.add_request(r)
    it = 0.0
    for _ in range(200):
        plan = s.schedule()
        for req, _pairs in plan.swap_in:
            swapped_back.add(req.request_id)
        chunks_after_swap_in += [c for c in plan.chunks
                                 if c.req.request_id in swapped_back]
        if plan.empty and not plan.swap_out and not plan.swap_in \
                and not s.waiting:
            break
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    assert A.phase == Phase.FINISHED and B.phase == Phase.FINISHED
    victim = A if A.swaps else B
    assert victim.swaps >= 1, "the crunch must have forced a swap"
    assert victim.preemptions == 0, \
        "a swap must not count against the recompute/drop budget"
    assert victim.request_id in swapped_back
    assert not chunks_after_swap_in, \
        "no prefill chunk may follow a decode-phase swap-in"
    assert len(victim.output) == 24, "every granted token was kept"
    # ledger drains to empty after teardown (satellite 3's invariant)
    assert a.num_free == a.num_blocks and a.swapped_pages == 0


def test_swapped_victim_state_while_on_host():
    a, s = _crunch_scheduler()
    A = Request(0, 0.0, list(range(17)), max_new_tokens=24)
    B = Request(1, 0.0, list(range(100, 117)), max_new_tokens=24)
    for r in (A, B):
        s.add_request(r)
    victim = None
    for it in range(200):
        plan = s.schedule()
        if plan.swap_out:
            victim = plan.swap_out[0][0]
            break
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, float(it))
    assert victim is not None
    assert victim.phase == Phase.WAITING
    assert s.waiting and s.waiting[0] is victim, \
        "a swapped victim waits at the head of the line (FCFS)"
    assert victim.request_id in s.tables, \
        "the table must STAY registered — it holds the host pages"
    assert s.tables[victim.request_id].on_host
    assert victim.prefilled_len == victim.prompt_len, \
        "swap must not erase prefill progress"


@pytest.mark.parametrize("policy", VICTIM_POLICIES)
def test_victim_policy_picks_the_right_loser(policy):
    a = BlockAllocator(32, PS, host_blocks=32)
    s = IterationScheduler(a, max_tokens_per_iter=64, swap_mode="swap",
                           victim_policy=policy)
    reqs = [Request(i, 0.0, list(range(i * 50, i * 50 + 4)),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        s.add_request(r)
    plan = s.schedule()  # all three admitted
    for r in plan.prefill:
        r.output.append(0)
    s.complete_iteration(plan, 0.0)
    # recency: only request 1 got a decode grant in the next iteration
    reqs[0].last_planned_iter = 5
    reqs[1].last_planned_iter = 1
    reqs[2].last_planned_iter = 5
    victim = s._pick_victim(exclude=reqs[0])
    # candidates exclude the grower: lifo takes the newest, fifo the
    # oldest remaining, lru the least recently scheduled; cost sees three
    # identical one-page swap bills and the tie keeps the oldest remaining
    want = {"lifo": reqs[2], "fifo": reqs[1], "lru": reqs[1],
            "cost": reqs[1]}[policy]
    assert victim is want


def test_abandon_swap_degrades_to_sacrifice():
    """A snapshot whose context can never fit on device again (it filled
    the pool and still must grow) is dropped: the request re-enters the
    classic recompute path and its drop budget applies."""
    a = BlockAllocator(8, PS, host_blocks=16)
    s = IterationScheduler(a, max_tokens_per_iter=64, swap_mode="swap")
    r = Request(0, 0.0, list(range(7 * PS)), max_new_tokens=16)
    s.add_request(r)
    for it in range(40):  # prefill 7 pages, then decode into page 8
        plan = s.schedule()
        for q in plan.prefill + plan.decode:
            q.output.append(0)
        s.complete_iteration(plan, float(it))
        if r.n_generated >= PS:  # the table now spans all 8 device pages
            break
    g = r.n_generated
    plan = IterationPlan([], [], [])
    s._preempt_or_swap(r, plan, trigger=-1, kind="victim")
    assert r.swaps == 1 and plan.swap_out
    # swap-in needs 8 pages + 1 growth > num_blocks - watermark: abandon
    plan = s.schedule()
    assert plan.preempted == [r]
    assert r.phase == Phase.PREEMPTED and r.preemptions == 1
    assert r.request_id not in s.tables
    assert a.swapped_pages == 0, "the dead snapshot's host pages are freed"
    assert r.prompt_len == 7 * PS + g, "generated tokens merged into prompt"


def test_swap_auto_uses_decider():
    decisions = []

    def decider(req, n_pages):
        decisions.append((req.request_id, n_pages))
        return False  # always recompute

    a = BlockAllocator(8, PS, host_blocks=16)
    s = IterationScheduler(a, max_tokens_per_iter=64, swap_mode="auto",
                           swap_decider=decider)
    A = Request(0, 0.0, list(range(17)), max_new_tokens=24)
    B = Request(1, 0.0, list(range(100, 117)), max_new_tokens=24)
    _drive(s, A, B)
    assert decisions, "the crunch must have consulted the decider"
    assert A.swaps == B.swaps == 0
    assert A.preemptions + B.preemptions >= 1


def test_speculative_swap_cancel_pages_never_leave():
    """A speculative swap-out issued under decode pressure is CANCELLED
    when pressure recedes before the next iteration (here: the other
    decoder finishes): the victim resumes decode with its original device
    pages, the device->host copy hook never fires, and nothing remains in
    the pending or host ledgers."""
    a = BlockAllocator(8, PS, host_blocks=16)
    s = IterationScheduler(a, max_tokens_per_iter=64, swap_mode="swap",
                           speculative_swap=True)
    issued, completed, cancelled = [], [], []
    s.swap_issue_hook = issued.append
    s.swap_complete_hook = completed.append
    s.swap_cancel_hook = cancelled.append
    A = Request(0, 0.0, list(range(17)), max_new_tokens=40)
    B = Request(1, 0.0, list(range(100, 117)), max_new_tokens=40)
    s.add_request(A)
    s.add_request(B)
    plan, it = None, 0.0
    for _ in range(200):
        plan = s.schedule()
        if plan.swap_issue:
            break
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    assert plan.swap_issue, "the crunch must trigger a speculative issue"
    victim, pairs = plan.swap_issue[0]
    survivor = A if victim is B else B
    assert issued == [pairs]
    assert victim.phase == Phase.WAITING and victim in s.waiting
    assert a.pending_out_pages == len(pairs) > 0
    assert s.tables[victim.request_id].on_host
    # run the overlapped iteration; the survivor finishes, freeing its
    # pages — pressure recedes past the cancel hysteresis band
    for r in plan.prefill + plan.decode:
        r.output.append(0)
    survivor.max_new_tokens = survivor.n_generated
    s.complete_iteration(plan, it)
    plan2 = s.schedule()
    assert plan2.swap_cancel == [(victim, pairs)]
    assert cancelled == [pairs] and not completed, \
        "the device->host copy must never have happened"
    assert victim.phase == Phase.INCREMENT and victim in s.running
    table = s.tables[victim.request_id]
    assert not table.on_host and not table.host_blocks
    assert all(dev in table.blocks for dev, _ in pairs), \
        "the ledger's device references are back on the table"
    assert a.pending_out_pages == 0 and a.swapped_pages == 0
    # the victim then drains normally with no further swap traffic
    for r in plan2.prefill + plan2.decode:
        r.output.append(0)
    s.complete_iteration(plan2, it + 1.0)
    _drive(s)
    assert victim.phase == Phase.FINISHED and victim.swaps == 1
    assert not completed and len(issued) == 1
    assert a.num_free == a.num_blocks and a.swapped_pages == 0


# -- satellite 2: preempted victims keep their prefix-cache credit -------------

def test_sacrificed_victim_recomputes_only_uncached_suffix():
    """Regression: ``_preempt`` used to zero ``prefilled_len`` without
    banking the computed pages, so a victim re-prefilled from token 0.
    Now the full prompt pages are inserted into the radix tree before the
    table is freed, and re-admission chunks only the uncached suffix."""
    a = BlockAllocator(64, PS, host_blocks=0)
    cache = PrefixCache(a)
    s = IterationScheduler(a, max_tokens_per_iter=64, prefix_cache=cache)
    r = Request(0, 0.0, list(range(3 * PS)), max_new_tokens=8)
    s.add_request(r)
    for it in range(3):  # prefill + a couple of decode tokens
        plan = s.schedule()
        for q in plan.prefill + plan.decode:
            q.output.append(0)
        s.complete_iteration(plan, float(it))
    assert r.n_generated >= 1
    s._preempt(r)
    assert r.prefilled_len == 0 and r.phase == Phase.PREEMPTED
    plan = s.schedule()  # re-admission re-probes the radix tree
    assert r.num_cached_tokens >= 2 * PS, \
        "the victim's own prefilled pages must be served from cache"
    assert plan.chunks and plan.chunks[0].req is r
    assert plan.chunks[0].start == r.num_cached_tokens > 0, \
        "recompute must cover only the uncached suffix"


def test_mid_prefill_victim_banks_completed_chunks():
    """The same credit applies to a victim preempted BETWEEN chunks: its
    completed chunks' pages are real KV and must not be recomputed."""
    a = BlockAllocator(64, PS)
    cache = PrefixCache(a)
    s = IterationScheduler(a, max_tokens_per_iter=2 * PS,
                           prefix_cache=cache)
    r = Request(0, 0.0, list(range(6 * PS)), max_new_tokens=4)
    s.add_request(r)
    plan = s.schedule()  # first chunk: tokens [0, 16)
    s.complete_iteration(plan, 0.0)
    assert r.prefilled_len == 2 * PS
    s._preempt(r)
    plan = s.schedule()
    assert r.num_cached_tokens == 2 * PS
    assert plan.chunks[0].start == 2 * PS, \
        "chunking must resume at the banked pages, not token 0"


# -- sim: conservation property + crossover plumbing ---------------------------

# page-payload layouts the ledgers must be agnostic to: the classic GQA
# K/V schema and the compressed MLA latent schema (satellite: conservation
# parameterized over layouts — bytes change, accounting must not)
_LAYOUTS = (KVPageLayout.from_arch(smoke_config("h2o-danube-1.8b")),
            KVPageLayout.from_arch(smoke_config("deepseek-v2-236b")))


def _check_conservation(num_blocks, host_blocks, seed, swap_overlap,
                        speculative_swap, layout=None):
    backend = SimBackend(num_blocks=num_blocks, block_size=PS,
                         max_running=8, max_tokens_per_iter=128,
                         host_blocks=host_blocks, swap_mode="swap",
                         swap_overlap=swap_overlap,
                         speculative_swap=speculative_swap,
                         layout=layout)
    for r in make_workload(12, rate=200.0, dist="alpaca", seed=seed,
                           max_len=num_blocks * PS // 2):
        backend.add_request(r)
    a = backend.allocator
    for _ in range(5000):
        if not backend.has_work:
            break
        backend.step()
        assert a.num_used + a.num_free == a.num_blocks
        assert 0 <= a.pending_out_pages <= a.num_used, \
            "in-flight DMA sources are allocated device pages"
        assert a.swapped_pages + a.host_num_free == a.num_host_blocks
        assert a.swapped_pages == sum(
            len(t.host_blocks) for t in backend.scheduler.tables.values())
    else:
        raise AssertionError("sim did not drain")
    assert a.num_used == 0 and a.swapped_pages == 0, \
        "both ledgers drain to empty at teardown"
    assert a.pending_out_pages == 0, "no swap-out may stay in flight"


@settings(max_examples=10, deadline=None)
@given(num_blocks=st.integers(16, 48), host_blocks=st.integers(8, 64),
       seed=st.integers(0, 10_000), swap_overlap=st.booleans(),
       speculative_swap=st.booleans(), mla_layout=st.booleans())
def test_sim_page_conservation_every_iteration(num_blocks, host_blocks,
                                               seed, swap_overlap,
                                               speculative_swap, mla_layout):
    """Property: the device ledger (used + free == total, in-flight pages
    counted used) and the host ledger (swapped + free == total) hold after
    EVERY sim iteration, for any pressure pattern the workload generates,
    any overlap/speculation setting, and either page layout."""
    _check_conservation(num_blocks, host_blocks, seed, swap_overlap,
                        speculative_swap, layout=_LAYOUTS[mla_layout])


@pytest.mark.parametrize("layout", [None, *_LAYOUTS],
                         ids=["default", "gqa", "mla"])
@pytest.mark.parametrize("swap_overlap,speculative_swap",
                         [(False, False), (True, False), (True, True)])
def test_sim_page_conservation_examples(swap_overlap, speculative_swap,
                                        layout):
    """Example-based companion to the property above so the invariants
    (including the overlapped/speculative paths and both layouts) are
    exercised even where hypothesis is unavailable."""
    for seed in (7, 1234):
        _check_conservation(24, 16, seed, swap_overlap, speculative_swap,
                            layout)


def test_sim_swap_counters_and_result_fields():
    reqs = [Request(i, i * 0.05, [], prompt_len=6144, max_new_tokens=256)
            for i in range(8)]
    res = simulate_paged(reqs, num_blocks=1180, block_size=16,
                         max_tokens_per_iter=4096, host_blocks=1536,
                         swap_mode="swap")
    assert res.completed_frac == 1.0
    assert res.swapped_out == res.swapped_in > 0
    assert res.swap_time > 0.0, "PCIe time must be on the virtual clock"
    assert res.preemptions == 0


def test_cost_victims_beat_lru_on_heterogeneous_crunch():
    """Satellite 1 regression: under swap pressure with mixed 3072/512-
    token contexts, lru ranks by staleness and keeps evicting big tables
    (more PCIe round trips) while cost picks the cheapest eviction bill
    for the actual shortfall — DIFFERENT victims, fewer swapped pages,
    and strictly better sim throughput AND tail latency."""
    def run(policy):
        reqs = [Request(request_id=i, arrival_time=i * 0.02, prompt=[],
                        prompt_len=3072 if i % 4 == 0 else 512,
                        max_new_tokens=256) for i in range(16)]
        return simulate_paged(reqs, num_blocks=280, block_size=16,
                              max_tokens_per_iter=2048, host_blocks=2048,
                              swap_mode="swap", victim_policy=policy)
    lru, cost = run("lru"), run("cost")
    assert lru.completed_frac == cost.completed_frac == 1.0
    assert cost.swapped_out < lru.swapped_out, \
        "the policies must pick different victims in this crunch"
    assert cost.throughput_tokens_per_s > lru.throughput_tokens_per_s
    assert cost.p99_normalized_latency < lru.p99_normalized_latency


def test_sim_swap_rejects_bad_mode():
    with pytest.raises(ValueError, match="swap_mode"):
        SimBackend(num_blocks=16, block_size=PS, swap_mode="keep")
    with pytest.raises(ValueError, match="victim_policy"):
        SimBackend(num_blocks=16, block_size=PS, victim_policy="random")
    assert SWAP_MODES == ("sacrifice", "swap", "auto")


# -- radix spill tier ----------------------------------------------------------

def test_prefix_cache_spills_and_restores():
    a = BlockAllocator(8, PS, host_blocks=8)
    cache = PrefixCache(a, spill_budget=4)
    prompt = list(range(2 * PS))
    t = _table_of(a, 2 * PS)
    cache.insert(prompt, t.blocks)
    a.free_table(t)
    used_before = a.num_used
    # only leaves spill: the 2-page chain gives one spill candidate
    cache.evict(1)
    assert cache.spilled_pages == 1 and a.swapped_pages == 1
    assert a.num_used == used_before - 1
    path = cache.match(prompt)
    assert len(path) == 2, "a spilled prefix still serves hits (restored)"
    assert cache.restored_pages == 1 and a.swapped_pages == 0
    assert all(node.block >= 0 for node in path)
    cache.clear()
    assert a.num_used == 0 and a.swapped_pages == 0


def test_prefix_cache_spill_budget_is_lru():
    a = BlockAllocator(16, PS, host_blocks=16)
    cache = PrefixCache(a, spill_budget=1)
    for base in (0, 1000):  # two sibling one-page prefixes
        t = _table_of(a, PS)
        cache.insert(list(range(base, base + PS)), t.blocks)
        a.free_table(t)
    dropped_before = cache.evicted_pages
    cache.evict(2)  # budget 1: the first spill is dropped for the second
    assert cache.spilled_pages == 2, "both eviction candidates spilled"
    assert a.swapped_pages == 1, "but only one host slot may stay taken"
    assert cache.evicted_pages == dropped_before + 1
    cache.clear()
    assert a.swapped_pages == 0 and a.num_used == 0


def test_prefix_cache_probe_counts_spilled_as_hit():
    a = BlockAllocator(8, PS, host_blocks=8)
    cache = PrefixCache(a, spill_budget=4)
    prompt = list(range(PS))
    t = _table_of(a, PS)
    cache.insert(prompt, t.blocks)
    a.free_table(t)
    cache.evict(1)
    path = cache.match(prompt, probe=True)
    assert len(path) == 1, "a probe must count spilled pages as cached"
    assert a.swapped_pages == 1, "a probe must not restore"


# -- peer KV spill tier: cold pages parked in a neighbor's free memory ---------

def _peer_children(**kw):
    kw.setdefault("num_blocks", 16)
    kw.setdefault("block_size", PS)
    kw.setdefault("max_running", 4)
    kw.setdefault("max_tokens_per_iter", 128)
    kw.setdefault("host_blocks", 8)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("cache_spill_pages", 4)
    return [SimBackend(**kw) for _ in range(2)]


def test_peer_spill_lends_restores_and_repays():
    """The peer tier is tried BEFORE host: a cold leaf page moves into the
    neighbor's free device memory over an rBlock loan (debt in the
    gManager ledger), a later prefix hit restores it home and repays, and
    clear() drains both allocators and the ledger to empty."""
    from repro.serving.router import RouterBackend
    children = _peer_children()
    router = RouterBackend(children, prefix_share=True, peer_spill=True)
    pc = children[0].prefix_cache
    a0, a1 = children[0].allocator, children[1].allocator
    prompt = list(range(2 * PS))
    t = _table_of(a0, 2 * PS)
    pc.insert(prompt, t.blocks)
    a0.free_table(t)
    used1 = a1.num_used
    pc.evict(1)  # the leaf page is the spill candidate
    assert pc.spilled_pages == 1 and pc.peer_spilled_pages == 1
    assert a0.swapped_pages == 0, "peer tier must be preferred over host"
    assert router.g.lent_by(1) == 1, "instance 1 lent one rBlock"
    assert a1.num_used == used1 + 1, "the parked copy lives on the peer"
    path = pc.match(prompt)
    assert len(path) == 2, "a peer-spilled prefix still serves hits"
    assert pc.peer_restored_pages == 1
    assert router.g.lent_by(1) == 0, "the loan is repaid on restore"
    assert a1.num_used == used1
    pc.clear()
    assert a0.num_used == 0 and a1.num_used == 0
    assert a0.swapped_pages == 0 and router.g.lent_by(1) == 0


def test_peer_spill_drop_repays_without_restore():
    """A peer-parked page evicted outright (spill budget churn / clear)
    repays the loan without moving any payload — the ledger must not leak
    debt for copies that die unread."""
    from repro.serving.router import RouterBackend
    children = _peer_children()
    router = RouterBackend(children, prefix_share=True, peer_spill=True)
    pc = children[0].prefix_cache
    a1 = children[1].allocator
    t = _table_of(children[0].allocator, PS)
    pc.insert(list(range(PS)), t.blocks)
    children[0].allocator.free_table(t)
    pc.evict(1)
    assert pc.peer_spilled_pages == 1 and router.g.lent_by(1) == 1
    pc.clear()  # dies unread: no restore, loan still settled
    assert pc.peer_restored_pages == 0
    assert router.g.lent_by(1) == 0 and a1.num_used == 0


def test_peer_spill_requires_spill_capable_children():
    from repro.serving.router import RouterBackend
    with pytest.raises(ValueError, match="prefix cache"):
        RouterBackend([SimBackend(num_blocks=16, block_size=PS)
                       for _ in range(2)],
                      prefix_share=True, peer_spill=True)
    with pytest.raises(ValueError, match="cache_spill_pages"):
        RouterBackend(_peer_children(cache_spill_pages=0),
                      prefix_share=True, peer_spill=True)


# -- engine: swap round trip is token-identical --------------------------------

@pytest.fixture(scope="module")
def model_setup_f32():
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None, dtype="float32",
                              logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, cfg, prompt, n):
    import jax.numpy as jnp
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def test_engine_swap_round_trip_token_identity(model_setup_f32):
    """ACCEPTANCE: a request swapped to host mid-decode and back resumes
    mid-sequence — no re-prefill (preemptions stays 0), and its greedy
    tokens match the sequential fp32 oracle exactly."""
    cfg, model, params = model_setup_f32
    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=8, page_size=PS, max_slots=2, host_pages=16,
        swap_mode="swap"))
    # seed 2: both prompts individually match the sequential oracle in a
    # roomy no-swap run (some seeds hit unrelated fp32 near-ties), so any
    # mismatch here is attributable to the swap round trip
    rng = np.random.default_rng(2)
    reqs = [Request(i, 0.0,
                    rng.integers(0, cfg.vocab_size, 17).tolist(),
                    max_new_tokens=20) for i in range(2)]
    swapped_in, chunks_after = set(), []
    orig = eng.scheduler.schedule

    def spy():
        plan = orig()
        swapped_in.update(r.request_id for r, _ in plan.swap_in)
        chunks_after.extend(c for c in plan.chunks
                            if c.req.request_id in swapped_in)
        return plan

    eng.scheduler.schedule = spy
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion()
    assert eng.swapped_out == eng.swapped_in > 0, \
        "the crunch must force a swap round trip"
    assert not chunks_after, "no prefill chunk after a swap-in"
    for r in reqs:
        assert r.preemptions == 0
        want = _oracle(model, params, cfg, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"
    assert eng.allocator.num_free == eng.allocator.num_blocks
    assert eng.allocator.swapped_pages == 0


def test_engine_overlapped_swap_token_identity(model_setup_f32):
    """ACCEPTANCE (overlapped transfers): with speculative double-buffered
    swap-outs the crunch issues device->host copies EARLY, every issue
    resolves to exactly one complete or cancel, and the greedy tokens
    still match the sequential fp32 oracle — overlap changes when the
    copy happens, never what the KV contains."""
    cfg, model, params = model_setup_f32
    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=8, page_size=PS, max_slots=2, host_pages=16,
        swap_mode="swap", speculative_swap=True))
    rng = np.random.default_rng(2)  # same seed rationale as above
    reqs = [Request(i, 0.0,
                    rng.integers(0, cfg.vocab_size, 17).tolist(),
                    max_new_tokens=20) for i in range(2)]
    issues, completes, cancels = [], [], []
    orig = eng.scheduler.schedule

    def spy():
        plan = orig()
        issues.extend(plan.swap_issue)
        completes.extend(plan.swap_complete)
        cancels.extend(plan.swap_cancel)
        return plan

    eng.scheduler.schedule = spy
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion()
    assert issues, "the crunch must exercise the overlapped path"
    assert len(completes) + len(cancels) == len(issues), \
        "every issue resolves exactly once"
    for r in reqs:
        assert r.preemptions == 0
        want = _oracle(model, params, cfg, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"
    a = eng.allocator
    assert a.num_free == a.num_blocks and a.swapped_pages == 0
    assert a.pending_out_pages == 0, "the pending ledger drains to empty"


def test_engine_peer_spill_restore_token_identity(model_setup_f32):
    """ACCEPTANCE (peer tier, real engines): a cold prefix page parked in
    a NEIGHBOR engine's free device memory and restored on hit carries
    the real KV payload — the restored-prefix request decodes
    token-identically to the from-scratch oracle, and the rBlock loan is
    repaid with both allocators draining to empty."""
    from repro.serving.router import RouterBackend

    class _Pin:  # place every request on engine 0
        def choose(self, req, children):
            return 0

    cfg, model, params = model_setup_f32
    engines = [PagedEngine(cfg, params, EngineConfig(
        num_pages=16, page_size=PS, max_slots=2, host_pages=16,
        enable_prefix_cache=True, cache_spill_pages=4))
        for _ in range(2)]
    router = RouterBackend(engines, policy=_Pin(), prefix_share=True,
                           peer_spill=True)

    def drain(req):
        for _ in range(10_000):
            if req.phase == Phase.FINISHED:
                return
            router.step()
        raise AssertionError("router did not finish the request")

    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    r0 = Request(0, 0.0,
                 prefix + rng.integers(0, cfg.vocab_size, 4).tolist(),
                 max_new_tokens=3)
    router.add_request(r0)
    drain(r0)
    pc = engines[0].prefix_cache
    pc.evict(1)  # park the cold leaf page on the neighbor
    assert pc.peer_spilled_pages == 1 and router.g.lent_by(1) == 1
    r1 = Request(1, 0.0,
                 prefix + rng.integers(0, cfg.vocab_size, 4).tolist(),
                 max_new_tokens=3)
    router.add_request(r1)
    drain(r1)
    assert pc.peer_restored_pages == 1, "the hit restored the parked page"
    assert router.g.lent_by(1) == 0, "the loan is repaid on restore"
    assert r1.num_cached_tokens == 2 * PS
    for r in (r0, r1):
        want = _oracle(model, params, cfg, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"
    pc.clear()
    assert engines[1].allocator.num_used == 0
    assert engines[0].allocator.num_free == 16


# -- telemetry: the pending-span invariants in validate_swap_balance -----------

def _pending_ev(ph, rid, ts, **args):
    e = {"cat": "swap", "name": "pending", "ph": ph, "ts": ts,
         "pid": 0, "tid": 0, "id": rid}
    if args:
        e["args"] = args
    return e


def test_validate_swap_balance_pending_span_invariants():
    from repro.core.telemetry.export import validate_swap_balance
    ok = [_pending_ev("b", 1, 10.0),
          _pending_ev("e", 1, 20.0, outcome="complete"),
          _pending_ev("b", 1, 30.0),
          _pending_ev("e", 1, 40.0, outcome="cancel")]
    assert validate_swap_balance({"traceEvents": ok}) == []

    errs = validate_swap_balance({"traceEvents": [
        _pending_ev("b", 1, 1.0), _pending_ev("b", 1, 2.0),
        _pending_ev("e", 1, 3.0, outcome="cancel")]})
    assert any("already in flight" in e for e in errs)

    errs = validate_swap_balance({"traceEvents": [
        _pending_ev("e", 1, 3.0, outcome="complete")]})
    assert any("without an open issue" in e for e in errs)

    errs = validate_swap_balance({"traceEvents": [
        _pending_ev("b", 1, 1.0),
        _pending_ev("e", 1, 2.0, outcome="done")]})
    assert any("outcome" in e for e in errs)

    errs = validate_swap_balance({"traceEvents": [_pending_ev("b", 1, 1.0)]})
    assert any("never resolved" in e for e in errs)


def test_validate_swap_balance_no_work_while_pages_in_flight():
    from repro.core.telemetry.export import validate_swap_balance

    def act(name, ts, cat="sched"):
        return {"cat": cat, "name": name, "ph": "i", "ts": ts,
                "pid": 0, "tid": 0, "args": {"rid": 1}}

    span = [_pending_ev("b", 1, 1.0),
            _pending_ev("e", 1, 9.0, outcome="complete")]
    for bad in (act("admit", 5.0), act("swap_in", 5.0),
                act("chunk", 5.0, cat="req")):
        errs = validate_swap_balance({"traceEvents": span + [bad]})
        assert any("in flight" in e for e in errs), bad["name"]
    # the same work OUTSIDE the span (and for other rids) is fine
    outside = act("admit", 12.0)
    other = dict(act("admit", 5.0), args={"rid": 2})
    assert validate_swap_balance(
        {"traceEvents": span + [outside, other]}) == []


# -- satellite 1: KVHandoff deferral fallback ----------------------------------

def test_handoff_deferral_cap_falls_back_to_prefill_host():
    """Regression (engineered park): with every decode instance unable to
    accept, a prefill-complete request used to defer forever. After
    ``defer_cap`` tries it must decode on its prefill host (mixed-style),
    with the ``handoff.deferred`` -> ``handoff.fallback`` event pair."""
    from repro.serving.router import RouterBackend
    children = [SimBackend(num_blocks=64, block_size=PS, max_running=4,
                           max_tokens_per_iter=128, trace=True)
                for _ in range(2)]
    router = RouterBackend(children, roles=["prefill", "decode"],
                           handoff_mode="migrate", handoff_defer_cap=3)
    children[1].scheduler.max_running = 0  # park the only decode instance
    r = Request(0, 0.0, list(range(12)), max_new_tokens=6)
    router.add_request(r)
    for _ in range(200):
        if r.phase == Phase.FINISHED:
            break
        router.step()
    assert r.phase == Phase.FINISHED, \
        "the fallback must rescue the request from starvation"
    assert router.handoff.fallbacks == 1
    assert router.handoff.deferrals == 3
    assert router.handoff.handoffs == 0
    assert r.instance_id == 0, "it never left the prefill host"
    events = router.trace_events()
    deferred = [e for e in events
                if e.cat == "handoff" and e.name == "deferred"]
    fallback = [e for e in events
                if e.cat == "handoff" and e.name == "fallback"]
    assert len(deferred) == 3 and len(fallback) == 1
    assert fallback[0].rid == r.request_id
    assert not children[0].scheduler.decode_exempt, \
        "finish() must clean the exemption up"


def test_handoff_fallback_does_not_block_later_handoffs():
    """Once the parked decode instance frees up, subsequent requests hand
    off normally — the fallback is per-request, not a mode switch."""
    from repro.serving.router import RouterBackend
    children = [SimBackend(num_blocks=64, block_size=PS, max_running=4,
                           max_tokens_per_iter=128)
                for _ in range(2)]
    router = RouterBackend(children, roles=["prefill", "decode"],
                           handoff_mode="migrate", handoff_defer_cap=2)
    children[1].scheduler.max_running = 0
    r1 = Request(0, 0.0, list(range(12)), max_new_tokens=6)
    router.add_request(r1)
    for _ in range(50):
        if r1.phase == Phase.FINISHED:
            break
        router.step()
    assert router.handoff.fallbacks == 1
    children[1].scheduler.max_running = 4  # un-park
    r2 = Request(1, router.clock() + 0.001, list(range(50, 62)),
                 max_new_tokens=6)
    router.add_request(r2)
    for _ in range(200):
        if r2.phase == Phase.FINISHED:
            break
        router.step()
    assert r2.phase == Phase.FINISHED
    assert router.handoff.handoffs == 1, "the next request hands off"
    assert r2.instance_id == 1
