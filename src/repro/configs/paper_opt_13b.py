"""OPT-13B — the paper's own serving-comparison model family [arXiv:2205.01068].

The paper's Fig. 9 benchmarks ORCA/vLLM on OPT models; we include OPT-13B as
the paper-faithful config used by the serving benchmarks (not part of the
assigned 10, so it is not in ``ARCH_IDS``).
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="paper-opt-13b",
    family="dense",
    source="arXiv:2205.01068",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=20480,
    vocab_size=50272,
    attention="gqa",
    max_seq_len=2048,
    use_bias=True,
    gated_mlp=False,
    tie_embeddings=True,
)
