"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,hkv,dh,page_size,pages_per_seq",
    [
        (1, 4, 4, 32, 8, 4),     # MHA
        (3, 8, 2, 64, 16, 8),    # GQA
        (2, 8, 1, 64, 16, 4),    # MQA (granite-style)
        (2, 4, 4, 80, 8, 6),     # danube head_dim=80 (non-128 aligned)
    ])
def test_paged_attention_sweep(b, h, hkv, dh, page_size, pages_per_seq,
                               dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    npages = pages_per_seq * b + 2
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    kp = jax.random.normal(ks[1], (npages, page_size, hkv, dh), dtype)
    vp = jax.random.normal(ks[2], (npages, page_size, hkv, dh), dtype)
    bt = jax.random.randint(ks[3], (b, pages_per_seq), 0, npages)
    smax = pages_per_seq * page_size
    lens = jnp.asarray(
        np.random.default_rng(1).integers(1, smax + 1, b), jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lens, page_size=page_size)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens, page_size=page_size)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [None, 8, 24])
def test_paged_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    b, h, hkv, dh, ps, nper = 2, 4, 2, 32, 8, 6
    q = jax.random.normal(ks[0], (b, h, dh))
    kp = jax.random.normal(ks[1], (16, ps, hkv, dh))
    vp = jax.random.normal(ks[2], (16, ps, hkv, dh))
    bt = jax.random.randint(ks[3], (b, nper), 0, 16)
    lens = jnp.array([5, 44], jnp.int32)
    out = ops.paged_attention(q, kp, vp, bt, lens, page_size=ps,
                              window=window)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens, page_size=ps,
                                   window=window)
    np.testing.assert_allclose(out, want, rtol=2e-5, atol=2e-5)


def test_paged_attention_partials_merge():
    """(m, l) partials from two half-caches must merge to the full result —
    the DistAttention contract."""
    from repro.core.distkv.dist_attention import merge_partials_tree
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    b, h, hkv, dh, ps = 2, 4, 2, 32, 8
    kp = jax.random.normal(ks[1], (8, ps, hkv, dh))
    vp = jax.random.normal(ks[2], (8, ps, hkv, dh))
    q = jax.random.normal(ks[0], (b, h, dh))
    bt = jnp.array([[0, 1, 2, 3], [4, 5, 6, 7]], jnp.int32)
    lens = jnp.array([30, 32], jnp.int32)
    full = ops.paged_attention(q, kp, vp, bt, lens, page_size=ps)

    # split each sequence's pages into two shards of 2 pages
    o1, m1, l1 = ops.paged_attention(q, kp, vp, bt[:, :2],
                                     jnp.minimum(lens, 16), page_size=ps,
                                     return_partials=True)
    lens2 = jnp.maximum(lens - 16, 0)
    # second shard sees positions 16.. => emulate with its own table; mask
    # by (lens-16) and offset handled because pages are logical-in-order
    o2, m2, l2 = ops.paged_attention(q, kp, vp, bt[:, 2:], lens2,
                                     page_size=ps, return_partials=True)
    merged = merge_partials_tree([o1 * l1[..., None], o2 * l2[..., None]],
                                 [m1, m2], [l1, l2])
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,hkv,dh,qb,kb,causal,window",
    [
        (1, 128, 4, 4, 64, 64, 64, True, None),
        (2, 256, 8, 2, 64, 128, 128, True, None),
        (2, 256, 8, 2, 64, 128, 64, True, 64),
        (1, 128, 4, 1, 32, 32, 32, False, None),
        (2, 192, 6, 2, 80, 64, 64, True, None),  # non-pow2 heads/dh
    ])
def test_flash_prefill_sweep(b, s, h, hkv, dh, qb, kb, causal, window,
                             dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, dh), dtype)
    out = ops.flash_prefill(q, k, v, causal=causal, window=window,
                            q_block=qb, kv_block=kb)
    want = ref.flash_prefill_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ssd_chunked_vs_sequential():
    """Chunked SSD (production path) vs the sequential recurrence oracle."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    b, l, h, p, g, n = 2, 64, 4, 16, 2, 8
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    for chunk in (8, 16, 32, 64):
        y, st = ssd_chunked(x, dt, A, B, C, chunk)
        y_ref, st_ref = ref.ssd_scan_ref(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_scan_pallas_vs_sequential(chunk, g):
    """Pallas SSD kernel (VMEM state carry) vs the sequential oracle."""
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, l, h, p, n = 2, 128, 4, 16, 8
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    yr, sr = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), rtol=2e-4,
                               atol=2e-4)
