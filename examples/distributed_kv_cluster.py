"""InfiniteLLM-style distributed KV cluster in action: four serving
instances, one gets a burst of long-context requests, borrows rBlocks
through the gManager debt ledger, and repays on completion. Also runs the
DistAttention micro-attention merge on a multi-device host mesh.

  PYTHONPATH=src python examples/distributed_kv_cluster.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.distkv import (GManager, RManager, dist_attention,  # noqa: E402
                               dist_attention_ref)
from repro.core.paging import BlockAllocator  # noqa: E402
from repro.serving.simulator import make_workload, simulate_distkv  # noqa: E402


def debt_ledger_demo():
    print("== gManager debt ledger (paper Fig. 8) ==")
    g = GManager(4)
    rms = {i: RManager(i, BlockAllocator(16, 16), g) for i in range(4)}
    for r in rms.values():
        r.register_peers(rms)

    rms[0].append_tokens(seq_id=100, new_tokens=16 * 14)  # near-full
    rms[0].append_tokens(seq_id=101, new_tokens=16 * 6)   # must borrow
    rms[3].append_tokens(seq_id=300, new_tokens=16 * 15)
    rms[3].append_tokens(seq_id=301, new_tokens=16 * 3)

    snap = g.snapshot()
    print(f"{'inst':>4} {'free/total':>12}  debtors")
    for i, row in snap.items():
        debt = ", ".join(f"inst{d} owes {b} blk" for d, b in row["debtors"])
        print(f"{i:>4} {row['free']:>5}/{row['total']:<6} {debt or '-'}")
    print(f"instance 0 seq 101 remote fraction: "
          f"{rms[0].remote_fraction(101):.0%}")
    rms[0].free_seq(101)
    print(f"after repay, ledger entries: {len(g.ledger)}")


def dist_attention_demo():
    print("\n== DistAttention: sequence-sharded micro-attention ==")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, hkv, dh, s = 4, 8, 2, 64, 512
    q = jax.random.normal(ks[0], (b, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    lens = jnp.array([100, 512, 7, 300], jnp.int32)
    out = dist_attention(mesh, q, k, v, lens)
    want = dist_attention_ref(q, k, v, lens)
    err = float(jnp.max(jnp.abs(out - want)))
    print(f"KV sharded over {mesh.shape['model']} model shards; "
          f"merge error vs unsharded oracle: {err:.2e}")


def cluster_sim_demo():
    print("\n== cluster simulation: borrow vs no-borrow ==")
    wl = lambda: make_workload(160, rate=12.0, dist="sharegpt", seed=1,
                               long_frac=0.08, long_len=10_000, max_len=2048)
    rd = simulate_distkv(wl(), borrow=True, blocks_per_instance=800)
    rn = simulate_distkv(wl(), borrow=False, blocks_per_instance=800)
    print(f"DistKV (borrow): {rd.throughput_tokens_per_s:6.0f} tok/s, "
          f"completed {rd.completed_frac:.0%}, preemptions {rd.preemptions}")
    print(f"local-only     : {rn.throughput_tokens_per_s:6.0f} tok/s, "
          f"completed {rn.completed_frac:.0%}, preemptions {rn.preemptions}")


if __name__ == "__main__":
    debt_ledger_demo()
    dist_attention_demo()
    cluster_sim_demo()
