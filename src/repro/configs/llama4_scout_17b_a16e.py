"""Llama-4 Scout 17B-active/16E [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff(expert)=8192 vocab=202048; MoE 16 routed
experts top-1 + 1 shared expert; early-fusion multimodal (the vision frontend is
out of scope for the LM backbone — text path only here, per assignment).
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attention="gqa",
    rope_theta=500_000.0,
    num_experts=16,
    num_shared_experts=1,
    moe_top_k=1,
    moe_d_ff=8192,
)
