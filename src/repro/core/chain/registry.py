"""PETALS-style server fleet model (paper §II).

A swarm hosts an L-block model. Each server announces a contiguous span of
blocks, its measured compute throughput ("GPU speed", blocks/s) and the
client-measured network latency (s per hop). Clients build chains of servers
covering blocks [0, L).
"""

from __future__ import annotations

import dataclasses
import random
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class ServerInfo:
    server_id: int
    start_block: int  # inclusive
    end_block: int  # exclusive
    throughput: float  # blocks per second ("GPU speed")
    latency: float  # client<->server network latency, seconds

    @property
    def n_blocks(self) -> int:
        return self.end_block - self.start_block

    def hosts(self, block: int) -> bool:
        return self.start_block <= block < self.end_block

    def compute_time(self, n_blocks: int) -> float:
        return n_blocks / self.throughput


@dataclasses.dataclass
class Fleet:
    num_blocks: int
    servers: List[ServerInfo]

    def covering(self, block: int) -> List[ServerInfo]:
        return [s for s in self.servers if s.hosts(block)]

    def is_coverable(self) -> bool:
        return all(self.covering(b) for b in range(self.num_blocks))


def make_fleet(num_blocks: int, num_servers: int, *, seed: int = 0,
               min_span: int = 2, heterogeneity: float = 4.0) -> Fleet:
    """Random geo-distributed swarm: spans, speeds and latencies are drawn
    log-uniformly (heterogeneous consumer hardware, as in the PETALS paper).
    Guarantees full block coverage by seeding a few spanning servers."""
    rng = random.Random(seed)
    servers: List[ServerInfo] = []
    sid = 0

    def add(start, end):
        nonlocal sid
        thr = 10.0 * heterogeneity ** rng.uniform(-1, 1)  # blocks/s
        lat = 0.05 * heterogeneity ** rng.uniform(-1, 1)  # s
        servers.append(ServerInfo(sid, start, end, thr, lat))
        sid += 1

    # coverage backbone: consecutive spans tiling [0, num_blocks)
    b = 0
    while b < num_blocks:
        span = min(rng.randint(min_span, max(min_span, num_blocks // 3)),
                   num_blocks - b)
        add(b, b + span)
        b += span
    # the rest are random spans
    while sid < num_servers:
        start = rng.randrange(0, num_blocks - min_span + 1)
        span = rng.randint(min_span, num_blocks - start)
        add(start, start + span)
    fleet = Fleet(num_blocks, servers)
    assert fleet.is_coverable()
    return fleet
