"""InfiniteLLM rManager: per-instance rBlock virtualization (paper §III.D.3).

Each LLM service instance owns a local :class:`BlockAllocator` and virtualizes
it behind **rBlocks** — (instance_id, physical_block) pairs with metadata. On
local exhaustion the rManager turns debtor: asks the gManager for creditor
candidates and borrows physical blocks that live on a *remote* instance.
Attention over borrowed blocks is exactly the DistAttention micro-attention
path (``dist_attention.py``): partial (m, l, o) computed where the block
lives, merged by log-sum-exp.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.distkv.gmanager import GManager, Heartbeat
from repro.core.paging.allocator import BlockAllocator, OutOfBlocks


@dataclasses.dataclass(frozen=True)
class RBlock:
    """The paper's rBlock metadata: ids + physical location."""
    rblock_id: int
    instance_id: int  # owning (home) instance of the *sequence*
    device_id: int    # instance where the physical block lives
    physical_id: int


@dataclasses.dataclass
class RemoteLease:
    """A borrowed page-aligned KV prefix: rBlocks whose physical pages live
    on a *creditor* instance and are served in place (zero-copy) through the
    DistAttention partial merge, instead of having their payloads copied.

    The debtor's scheduler holds the lease for the lifetime of the borrowing
    request; :meth:`release` repays the creditor (one ``decref`` + ledger
    repayment per block). ``acquire`` refcounts the lease so a COW-forked
    best-of-n sibling can share its parent's borrowed prefix — the creditor
    is repaid exactly once, when the last holder releases.

    Two grant sites exist: admission-time prefix adoption (lease capped at
    ``prompt_len - 1`` so the final prompt token's logits are computed
    locally) and the disaggregated KV handoff (``serving/disagg.py``),
    where the lease covers *all* full prompt pages — the prefill host
    already sampled the first token, so the decode host needs no local
    prompt KV beyond the copied partial tail page."""

    home: int                 # creditor instance the pages live on
    debtor: int
    blocks: List[int]         # physical page ids on the creditor
    page_size: int
    # KVPageLayout schema of the pages on the creditor (None = unknown,
    # sim). The installing engine validates this against its own layout —
    # attending over foreign-layout pages would read garbage.
    schema: Optional[str] = None
    _release: Optional[Callable[["RemoteLease"], None]] = None
    _on_commit: Optional[Callable[["RemoteLease"], None]] = None
    _refs: int = 1
    committed: bool = False

    @property
    def num_pages(self) -> int:
        return len(self.blocks)

    @property
    def num_tokens(self) -> int:
        return len(self.blocks) * self.page_size

    def acquire(self) -> "RemoteLease":
        if self._refs <= 0:
            raise ValueError("acquire of a released lease")
        self._refs += 1
        return self

    def commit(self) -> None:
        """Called by the scheduler when an admission actually lands with
        this lease. An adopter may be asked (and borrow) every scheduling
        retry of a request that cannot fit yet — stats/charges hooked here
        instead of at grant time count served prefixes, not retries."""
        if not self.committed:
            self.committed = True
            if self._on_commit is not None:
                self._on_commit(self)

    def release(self) -> None:
        """Repay the creditor once the last holder lets go. Idempotent past
        zero so double-release in teardown paths cannot double-repay."""
        if self._refs <= 0:
            return
        self._refs -= 1
        if self._refs == 0 and self._release is not None:
            self._release(self)


@dataclasses.dataclass
class SeqKV:
    """A sequence's logical KV: ordered rBlocks (possibly multi-instance)."""
    rblocks: List[RBlock] = dataclasses.field(default_factory=list)
    num_tokens: int = 0


class RManager:
    def __init__(self, instance_id: int, allocator: BlockAllocator,
                 gmanager: GManager):
        self.instance_id = instance_id
        self.allocator = allocator
        self.g = gmanager
        self.peers: Dict[int, "RManager"] = {}
        self._next_rblock = 0
        self.seqs: Dict[int, SeqKV] = {}
        # KVPageLayout schema of this instance's pages (None when the
        # allocator carries no layout, e.g. pure-sim backends)
        layout = getattr(allocator, "layout", None)
        self.schema: Optional[str] = layout.schema if layout is not None \
            else None
        # telemetry: this instance's Tracer (wired by the cluster router),
        # or None — emission sites guard on it
        self.trace = None
        self.heartbeat()

    def register_peers(self, peers: Dict[int, "RManager"]) -> None:
        self.peers = peers

    def heartbeat(self) -> None:
        self.g.heartbeat(Heartbeat(self.instance_id,
                                   self.allocator.num_free,
                                   self.allocator.num_blocks))

    # -- lending side -----------------------------------------------------------
    def try_lend(self, debtor: int) -> Optional[int]:
        """Allocate one local physical block on behalf of ``debtor``."""
        if self.allocator.num_free <= self.g.safety_free:
            return None
        b = self.allocator.alloc_block()
        self.g.record_loan(self.instance_id, debtor, 1)
        self.heartbeat()
        if self.trace is not None:
            self.trace.instant("lease", "lend", debtor=debtor, blocks=1,
                               kind="fresh")
        return b

    def lend_blocks(self, debtor: int, blocks: List[int]) -> None:
        """Lend *specific existing* local pages (e.g. radix-cached prefix
        pages) to ``debtor``: one extra reference per block, so neither the
        local cache's eviction nor a local ``free_table`` can return a lent
        page to the free list while the debtor reads it. Raises ValueError
        (before touching the ledger) if any block is not live."""
        for b in blocks:
            if self.allocator.refcount_of(b) == 0:
                raise ValueError(
                    f"instance {self.instance_id}: cannot lend free block "
                    f"{b} — only live pages are lendable")
        for b in blocks:
            self.allocator.incref(b)
        self.g.record_loan(self.instance_id, debtor, len(blocks))
        self.heartbeat()
        if self.trace is not None:
            self.trace.instant("lease", "lend", debtor=debtor,
                               blocks=len(blocks), kind="live")

    def repay(self, creditor: int, physical_id: int) -> None:
        self.peers[creditor].allocator.decref(physical_id)
        self.g.record_repayment(creditor, self.instance_id, 1)
        self.peers[creditor].heartbeat()
        if self.trace is not None:
            self.trace.instant("lease", "repay", creditor=creditor, blocks=1)

    # -- zero-copy prefix leases ---------------------------------------------------
    def borrow_blocks(self, home: int, blocks: List[int]) -> RemoteLease:
        """Borrow specific pages living on ``home`` as a zero-copy prefix
        lease. The lease's :meth:`RemoteLease.release` repays through this
        (debtor) rManager."""
        if home == self.instance_id:
            raise ValueError("borrowing from oneself — serve locally instead")
        lender = self.peers[home]
        if self.schema is not None and lender.schema is not None \
                and self.schema != lender.schema:
            raise ValueError(
                f"KV layout schema mismatch on lease grant: debtor instance "
                f"{self.instance_id} has layout {self.schema!r} but creditor "
                f"{home} holds {lender.schema!r} pages — refusing the "
                "zero-copy borrow (attending over foreign-layout pages "
                "would read garbage)")
        lender.lend_blocks(self.instance_id, blocks)
        if self.trace is not None:
            self.trace.instant("lease", "borrow", home=home,
                               pages=len(blocks))

        def _repay(lease: RemoteLease) -> None:
            for b in lease.blocks:
                self.repay(lease.home, b)

        return RemoteLease(home=home, debtor=self.instance_id,
                           blocks=list(blocks),
                           page_size=self.allocator.block_size,
                           schema=lender.schema,
                           _release=_repay)

    # -- borrowing side -----------------------------------------------------------
    def _alloc_one(self) -> RBlock:
        rid = self._next_rblock
        self._next_rblock += 1
        try:
            phys = self.allocator.alloc_block()
            self.heartbeat()
            return RBlock(rid, self.instance_id, self.instance_id, phys)
        except OutOfBlocks:
            pass
        # debtor path: ask the gManager for up to 3 creditors, try in order
        for cred in self.g.recommend_creditors(self.instance_id, 1):
            phys = self.peers[cred].try_lend(self.instance_id)
            if phys is not None:
                return RBlock(rid, self.instance_id, cred, phys)
        raise OutOfBlocks(f"instance {self.instance_id}: no local or remote "
                          f"blocks available")

    # -- sequence API ---------------------------------------------------------------
    def append_tokens(self, seq_id: int, new_tokens: int) -> List[RBlock]:
        """Grow a sequence; returns newly-allocated rBlocks. Atomic: if the
        cluster cannot supply all needed blocks, everything allocated so far
        is returned/repaid and OutOfBlocks propagates."""
        kv = self.seqs.setdefault(seq_id, SeqKV())
        bs = self.allocator.block_size
        total = kv.num_tokens + new_tokens
        need = -(-total // bs) - len(kv.rblocks)
        added: List[RBlock] = []
        try:
            for _ in range(need):
                rb = self._alloc_one()
                added.append(rb)
        except OutOfBlocks:
            self._return_rblocks(added)  # roll back
            self.heartbeat()
            raise
        kv.rblocks.extend(added)
        kv.num_tokens = total
        return added

    def _return_rblocks(self, rblocks: List[RBlock]) -> None:
        """Give back a set of rBlocks, **creditors first**: remote blocks
        are repaid before any local page is freed, so a fault in the local
        teardown (e.g. a double-free surfacing as ValueError mid-loop) can
        never strand a creditor's lent block — the debt side is settled by
        the time local state is touched. This is the invariant the
        debtor-preemption path relies on."""
        for rb in rblocks:
            if rb.device_id != self.instance_id:
                self.repay(rb.device_id, rb.physical_id)
        for rb in rblocks:
            if rb.device_id == self.instance_id:
                self.allocator.decref(rb.physical_id)

    def free_seq(self, seq_id: int) -> None:
        """Free a sequence's rBlocks (request finish OR preemption of a
        debtor). Remote repayments run before local frees — see
        :meth:`_return_rblocks`."""
        kv = self.seqs.pop(seq_id, None)
        if kv is None:
            return
        self._return_rblocks(kv.rblocks)
        self.heartbeat()

    # -- cross-instance prefix sharing -------------------------------------------
    def publish_prefix(self, tokens, payloads) -> int:
        """Publish a hot page-aligned prefix (token keys + page payloads)
        computed on this instance to the cluster's board (on the gManager,
        like the debt ledger). Peers adopt via :meth:`lookup_prefix` +
        ``PrefixCache.adopt``."""
        return self.g.prefix_board.publish(self.instance_id, tokens, payloads,
                                           self.allocator.block_size,
                                           schema=self.schema)

    def lookup_prefix(self, tokens, max_tokens=None):
        """Longest published page chain for ``tokens`` (any home instance)."""
        return self.g.prefix_board.match(tokens, max_tokens=max_tokens)

    # -- stats ------------------------------------------------------------------
    def remote_fraction(self, seq_id: int) -> float:
        kv = self.seqs.get(seq_id)
        if not kv or not kv.rblocks:
            return 0.0
        remote = sum(1 for rb in kv.rblocks
                     if rb.device_id != self.instance_id)
        return remote / len(kv.rblocks)
