"""End-to-end serving engine: continuous batching on a real model must match
per-request sequential decoding exactly (greedy)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.scheduling.request import Request
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine


@pytest.fixture(scope="module")
def model_setup():
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def model_setup_f32():
    """Float32 everywhere (params, KV pages, logits): the engine and the
    ring-cache oracle agree bit-for-bit well past argmax resolution, so
    greedy comparisons are exact instead of flaky on bf16 near-ties."""
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None, dtype="float32",
                              logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, cfg, prompt, n):
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def _oracle_next_logits(model, params, tokens):
    """Next-token logits after feeding ``tokens`` (prefill last position)."""
    logits, _ = model.prefill(params, jnp.asarray(tokens, jnp.int32)[None],
                              seq_capacity=128)
    return np.asarray(logits[0])


def test_engine_matches_sequential_oracle(model_setup):
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(5):
        plen = int(rng.integers(3, 12))
        reqs.append(Request(i, 0.0,
                            rng.integers(0, cfg.vocab_size, plen).tolist(),
                            max_new_tokens=int(rng.integers(2, 7))))
        eng.add_request(reqs[-1])
    eng.run_to_completion()
    for r in reqs:
        want = _oracle(model, params, cfg, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"


def test_bf16_divergence_is_argmax_tie_artifact(model_setup, model_setup_f32):
    """ROADMAP follow-up: the rare engine-vs-oracle greedy divergence under
    bf16 is an argmax (near-)tie artifact, not a numerics bug.

    Short (3-token) prompts are replayed on the bf16 engine and the bf16
    oracle. Wherever the two streams first disagree, the oracle's own bf16
    logits at that step must rate the two winners within ONE bf16 ulp —
    i.e. the candidates are indistinguishable at bf16 resolution, and the
    two (both correct) implementations merely resolve the tie through
    different accumulation orders. With float32 compute the same prompts
    must match token-for-token (see model_setup_f32)."""
    cfg, model, params = model_setup
    cfg32, model32, params32 = model_setup_f32
    rng = np.random.default_rng(42)
    prompts = [rng.integers(0, cfg.vocab_size, 3).tolist() for _ in range(8)]
    n_new = 6

    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    reqs = [Request(i, 0.0, list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion()

    for r in reqs:
        got = r.full_output
        want = _oracle(model, params, cfg, r.prompt, n_new)
        if got == want:
            continue
        i = next(k for k, (a, b) in enumerate(zip(got, want)) if a != b)
        # both streams share the context up to the divergence point; the
        # bf16 logits there must rate the winners within one ulp (bf16 has
        # 8 mantissa bits -> ulp ~= magnitude * 2^-8; allow 2^-7 for the
        # boundary case spanning an exponent step)
        ctx = r.prompt + want[:i]
        lg = _oracle_next_logits(model, params, ctx)
        gap = abs(float(lg[got[i]]) - float(lg[want[i]]))
        ulp = float(np.abs(lg).max()) * 2.0 ** -7
        assert gap <= ulp, (
            f"req {r.request_id}: bf16 divergence at step {i} is NOT a "
            f"near-tie (logit gap {gap} > one bf16 ulp {ulp}) — real "
            f"numerics bug, not a tie artifact")

    # float32: tie-free at argmax resolution — same prompts, exact match
    eng32 = PagedEngine(cfg32, params32, EngineConfig(num_pages=64,
                                                      page_size=8,
                                                      max_slots=4))
    reqs32 = [Request(i, 0.0, list(p), max_new_tokens=n_new)
              for i, p in enumerate(prompts)]
    for r in reqs32:
        eng32.add_request(r)
    eng32.run_to_completion()
    for r in reqs32:
        want = _oracle(model32, params32, cfg32, r.prompt, n_new)
        assert r.full_output == want, f"req {r.request_id} (float32)"
    # (bf16 divergence is rare: zero diverging prompts in this sample is
    # fine — the float32 half still proves the comparison is exact)


def test_engine_pallas_kernel_path(model_setup):
    """Same engine with the Pallas paged-attention kernel (interpret)."""
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=32, page_size=8,
                                                max_slots=2, use_kernel=True))
    r = Request(0, 0.0, [5, 9, 2, 7], max_new_tokens=3)
    eng.add_request(r)
    eng.run_to_completion()
    want = _oracle(model, params, cfg, r.prompt, 3)
    assert r.full_output == want


def test_engine_swa_arch(model_setup):
    cfg = smoke_config("h2o-danube-1.8b")  # window=64 active
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=2))
    r = Request(0, 0.0, list(np.random.default_rng(2).integers(
        0, cfg.vocab_size, 10)), max_new_tokens=4)
    eng.add_request(r)
    eng.run_to_completion()
    want = _oracle(model, params, cfg, r.prompt, 4)
    assert r.full_output == want


def test_engine_continuous_batching_admits_late_request(model_setup_f32):
    # float32 compute: this test's 3-token prompt sits exactly on a bf16
    # argmax near-tie (top-2 logits one bf16 ulp apart), which the engine
    # and the ring-cache oracle legitimately break differently — the
    # pre-existing tier-1 flake recorded in ROADMAP, dissected by
    # test_bf16_divergence_is_argmax_tie_artifact. In float32 the
    # comparison is exact and the continuous-batching property under test
    # (late joiners don't perturb running requests) is checked tightly.
    cfg, model, params = model_setup_f32
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    r1 = Request(0, 0.0, [1, 2, 3], max_new_tokens=6)
    eng.add_request(r1)
    eng.step()  # r1 prefilled
    r2 = Request(1, 0.0, [4, 5], max_new_tokens=2)
    eng.add_request(r2)  # joins while r1 decodes
    eng.run_to_completion()
    assert r1.full_output == _oracle(model, params, cfg, r1.prompt, 6)
    assert r2.full_output == _oracle(model, params, cfg, r2.prompt, 2)


def test_engine_kv_utilization_reported(model_setup):
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=64, page_size=8,
                                                max_slots=4))
    eng.add_request(Request(0, 0.0, [1] * 9, max_new_tokens=3))
    eng.step()
    util = eng.kv_utilization()
    assert 0.5 <= util <= 1.0  # 9 tokens in 2 pages of 8 = 0.5625
