"""InternVL2-26B — InternViT-6B vision encoder + InternLM2-20B LM [arXiv:2404.16821].

LM backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The ViT + MLP projector frontend is a STUB per the assignment carve-out:
``input_specs`` provides precomputed patch embeddings (256 tokens per image
after pixel-shuffle) prepended to the text sequence.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attention="gqa",
    rope_theta=1_000_000.0,
    frontend="vision",
    num_media_tokens=256,
)
