"""Unified serving front-end: one API over the real engine and the simulator.

The paper's serving study (§III.E) compares ORCA/vLLM/InfiniteLLM as
*services*; this module is the service surface the rest of the repo talks to,
modeled on vLLM's ``LLM`` / ``SamplingParams`` split:

* :class:`SamplingParams` — per-request decoding knobs (temperature, top-k,
  top-p, stop tokens, best-of-n, seed). Sampling is no longer an engine-global
  ``EngineConfig.temperature``; every request carries its own params and the
  fused decode samples all slots with vectorized per-slot parameters.
* :class:`RequestOutput` / :class:`CompletionChunk` — results. ``generate``
  returns finished outputs with finish reasons and latency metrics;
  ``stream`` yields per-iteration chunks as the engine steps.
* :class:`LLMService` — the front-end. ``generate`` (blocking), ``stream``
  (iterator driven by backend ``step()``), and ``submit``/``poll`` for
  open-loop arrival traces (the Fig. 9/10 benchmarks).

Both backends implement the same :class:`ServingBackend` protocol: the real
``PagedEngine`` (wall-clock or caller-supplied time) and the cost-model
``SimBackend`` (virtual clock) from ``repro.serving.simulator``. Benchmarks
and examples pick a backend by flag, not by import. A whole cluster is also
just a backend: ``repro.serving.router.RouterBackend`` multiplexes N child
instances behind this protocol (placement policies + cross-instance prefix
sharing), reporting per-request placement via ``RequestMetrics.instance_id``
and per-instance aggregates via ``ServiceStats.per_instance``.
"""

from __future__ import annotations

import bisect
import dataclasses
import time
from typing import (Dict, Iterable, Iterator, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

from repro.core.scheduling.request import Request
from repro.core.telemetry import percentile

# finish reasons (Request.finish_reason / RequestOutput.finish_reason)
FINISH_STOP = "stop"                  # hit one of SamplingParams.stop_token_ids
FINISH_EOS = "eos"                    # hit the eos token
FINISH_LENGTH = "length"              # hit max_new_tokens
FINISH_DROPPED = "preempted-dropped"  # evicted past the preemption budget
FINISH_REASONS = (FINISH_STOP, FINISH_EOS, FINISH_LENGTH, FINISH_DROPPED)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters (vLLM-style).

    ``temperature <= 0`` means greedy. ``top_k = 0`` / ``top_p = 1.0``
    disable the respective filters. ``n > 1`` draws n parallel samples whose
    KV is shared through the paging layer's copy-on-write forks. ``seed``
    pins the request's sample stream (independent of batch composition and
    slot placement); ``None`` derives one from the request id.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop_token_ids: Tuple[int, ...] = ()
    eos_token: Optional[int] = None
    max_new_tokens: int = 16
    n: int = 1
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "stop_token_ids",
                           tuple(self.stop_token_ids or ()))
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0 (0 = greedy)")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 = disabled)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.n < 1:
            raise ValueError("n must be >= 1")

    def for_sample(self, k: int) -> "SamplingParams":
        """Params for best-of sibling ``k`` (k >= 1): same knobs, n=1, and a
        decorrelated seed so siblings draw distinct streams."""
        seed = None if self.seed is None else (self.seed + 7919 * k) & 0x7FFFFFFF
        return dataclasses.replace(self, n=1, seed=seed)


@dataclasses.dataclass
class CompletionChunk:
    """Tokens produced for one request during one service poll."""

    request_id: int
    token_ids: List[int]           # new tokens since the previous chunk
    n_generated: int               # cumulative tokens so far
    finished: bool = False
    finish_reason: Optional[str] = None
    time: Optional[float] = None   # backend clock (None = wall-clock backend)
    # per-token log p(token) aligned with token_ids, streamed incrementally;
    # None when the backend does not score tokens (the cost-model simulator)
    logprobs: Optional[List[float]] = None


@dataclasses.dataclass
class RequestMetrics:
    arrival_time: float
    queue_time: Optional[float]    # arrival -> first scheduled
    ttft: Optional[float]          # arrival -> first token (spans all
    #                                prefill chunks of a chunked prefill)
    tbt: Optional[float]           # mean time between output tokens
    e2e: Optional[float]           # arrival -> finish
    normalized_latency: Optional[float]  # e2e / output tokens (Fig. 9 metric)
    preemptions: int = 0
    num_cached_tokens: int = 0     # prompt tokens served from the radix cache
    # serving instance the request ran on (RouterBackend placement; None
    # under a single-backend service)
    instance_id: Optional[int] = None
    # worst gap between consecutive output tokens: the stall a decode
    # suffers when someone's prefill monopolizes an iteration
    max_tbt: Optional[float] = None
    # prefill-in-flight duration: first scheduled chunk -> first token
    prefill_time: Optional[float] = None


@dataclasses.dataclass
class CompletionSample:
    """One of a request's ``n`` parallel samples."""

    token_ids: List[int]
    cumulative_logprob: float
    finish_reason: str
    # per-token logprobs aligned with token_ids (None on the simulator)
    token_logprobs: Optional[List[float]] = None


@dataclasses.dataclass
class RequestOutput:
    """Final result for one submitted request. With ``n > 1`` all samples are
    kept (sorted best-first by cumulative logprob); ``token_ids`` /
    ``finish_reason`` mirror the best sample."""

    request_id: int
    prompt_len: int
    token_ids: List[int]
    finish_reason: str
    metrics: RequestMetrics
    cumulative_logprob: float = 0.0
    samples: List[CompletionSample] = dataclasses.field(default_factory=list)

    @property
    def n_generated(self) -> int:
        return len(self.token_ids)


@runtime_checkable
class ServingBackend(Protocol):
    """What LLMService needs from an execution backend. Implemented by
    ``PagedEngine`` (real model, wall-clock / caller time) and ``SimBackend``
    (cost model, virtual clock)."""

    def add_request(self, req: Request) -> None: ...

    def step(self, now: Optional[float] = None) -> List[Request]:
        """Run ONE iteration; returns requests finished this iteration."""
        ...

    @property
    def has_work(self) -> bool: ...

    def clock(self) -> Optional[float]:
        """Backend time. ``None`` = wall-clock backend (caller passes ``now``
        to :meth:`LLMService.poll`); a float = virtual clock the service may
        fast-forward across idle gaps."""
        ...


@dataclasses.dataclass
class ServiceStats:
    """Aggregates over a batch of finished outputs (Fig. 9-style metrics)."""

    n_requests: int = 0
    n_finished: int = 0
    n_dropped: int = 0
    total_tokens: int = 0
    makespan: float = 0.0
    mean_ttft: float = float("inf")
    mean_normalized_latency: float = float("inf")
    p99_normalized_latency: float = float("inf")
    throughput_tokens_per_s: float = 0.0
    preemptions: int = 0
    prefix_hit_rate: Optional[float] = None
    # P99 of per-request WORST inter-token gaps — the decode-stall tail
    # chunked prefill targets (a solo long prefill dominates it)
    p99_tbt: float = float("inf")
    # mean excess of a request's worst gap over its own average gap, in ms:
    # ~0 for an evenly-paced decode, large when decodes stall behind
    # someone's prefill
    prefill_stall_ms: float = 0.0
    # RouterBackend services: per-instance breakdown (requests placed,
    # iterations, load, cache stats), keyed by instance id
    per_instance: Optional[Dict[int, Dict]] = None
    # telemetry-enabled backends: per-iteration metric timelines, keyed by
    # instance id (one row per step; see repro.core.telemetry)
    timelines: Optional[Dict[int, List[Dict]]] = None
    # disaggregated routers: the same rows grouped per instance role
    # (prefill / decode / mixed) — the per-role split of the cluster
    role_timelines: Optional[Dict[str, List[Dict]]] = None

    @property
    def completed_frac(self) -> float:
        return self.n_finished / max(self.n_requests, 1)


@dataclasses.dataclass
class _Live:
    req: Request
    parent_id: int
    reported: int = 0
    finished: bool = False


class LLMService:
    """vLLM-style front-end over a :class:`ServingBackend`.

    Closed-loop use::

        svc = LLMService(PagedEngine(cfg, params, ecfg))
        outs = svc.generate(prompts, SamplingParams(temperature=0.8, top_p=0.9))

    Open-loop traces (``submit`` with arrival times, then ``poll``)::

        for r in requests:
            svc.submit(r.prompt, params, arrival_time=r.arrival_time)
        while svc.pending:
            for chunk in svc.poll():
                ...
    """

    def __init__(self, backend: ServingBackend, *,
                 default_params: Optional[SamplingParams] = None):
        self.backend = backend
        self.default_params = default_params or SamplingParams()
        self._next_id = 0
        self._queue: List[Request] = []   # future arrivals, sorted by time
        self._live: Dict[int, _Live] = {}
        self._families: Dict[int, List[int]] = {}  # parent -> member ids
        self._results: Dict[int, RequestOutput] = {}
        self._order: List[int] = []       # submission order of parent ids
        self._t0: Optional[float] = None  # wall-clock origin (engine backend)
        self._progressed = False          # last poll made progress

    # -- submission -------------------------------------------------------------

    def submit(self, prompt: Sequence[int],
               params: Optional[SamplingParams] = None,
               arrival_time: float = 0.0) -> int:
        """Queue one prompt; returns the request id. With ``params.n > 1``,
        sibling requests are created for the backend to COW-fork off the
        parent's prefill."""
        params = params or self.default_params
        rid = self._fresh_id()
        parent = Request(rid, arrival_time, list(prompt),
                         max_new_tokens=params.max_new_tokens,
                         eos_token=params.eos_token,
                         sampling=params if params.n == 1
                         else params.for_sample(0))
        members = [parent]
        for k in range(1, params.n):
            child = Request(self._fresh_id(), arrival_time, list(prompt),
                            max_new_tokens=params.max_new_tokens,
                            eos_token=params.eos_token,
                            sampling=params.for_sample(k), parent_id=rid)
            members.append(child)
        self._families[rid] = [m.request_id for m in members]
        self._order.append(rid)
        for m in members:
            self._enqueue(m)
        return rid

    def submit_request(self, req: Request,
                       params: Optional[SamplingParams] = None) -> int:
        """Queue a pre-built :class:`Request` (trace replay). The request's
        own ``max_new_tokens`` / ``eos_token`` / ``arrival_time`` are kept;
        ``params`` (optional) attaches sampling knobs."""
        if params is not None:
            req.sampling = params
        self._next_id = max(self._next_id, req.request_id + 1)
        self._families[req.request_id] = [req.request_id]
        self._order.append(req.request_id)
        self._enqueue(req)
        return req.request_id

    def _fresh_id(self) -> int:
        rid = self._next_id
        self._next_id += 1
        return rid

    def _enqueue(self, req: Request) -> None:
        self._live[req.request_id] = _Live(
            req, req.parent_id if req.parent_id is not None
            else req.request_id)
        bisect.insort(self._queue, req, key=lambda r: r.arrival_time)

    # -- the drive loop ---------------------------------------------------------

    @property
    def pending(self) -> bool:
        return bool(self._queue) or \
            any(not s.finished for s in self._live.values())

    def poll(self, now: Optional[float] = None, *,
             collect: bool = True) -> List[CompletionChunk]:
        """Inject due arrivals, run ONE backend iteration, and return the
        chunks it produced. ``now`` is the caller's clock for wall-clock
        backends; virtual-clock backends keep their own time and are
        fast-forwarded across idle gaps. ``collect=False`` skips building
        per-token chunks (drain/replay: nobody consumes them)."""
        t = now if now is not None else self.backend.clock()
        if t is None:
            # wall-clock backend, no caller time: measure from first poll so
            # arrival_time=0 submissions get meaningful latency metrics
            if self._t0 is None:
                self._t0 = time.monotonic()
            t = time.monotonic() - self._t0
        injected = False
        while self._queue and self._queue[0].arrival_time <= t:
            self.backend.add_request(self._queue.pop(0))
            injected = True
        iters_before = getattr(self.backend, "iterations", None)
        finished = self.backend.step(t)
        chunks: Dict[int, CompletionChunk] = {}
        tnow = self.backend.clock()
        if collect:
            for rid, st in self._live.items():
                if st.finished:
                    continue
                total = st.req.full_output
                if len(total) > st.reported:
                    # stream per-token logprobs with the tokens when the
                    # backend scores them (req.logprobs stays aligned with
                    # full_output across preemptions)
                    lps = list(st.req.logprobs[st.reported:len(total)]) \
                        if len(st.req.logprobs) == len(total) else None
                    chunks[rid] = CompletionChunk(
                        rid, list(total[st.reported:]), len(total), time=tnow,
                        logprobs=lps)
                    st.reported = len(total)
        for req in finished:
            st = self._live.get(req.request_id)
            if st is None:
                continue
            st.finished = True
            if collect:
                ch = chunks.setdefault(req.request_id, CompletionChunk(
                    req.request_id, [], len(req.full_output), time=tnow))
                ch.finished = True
                ch.finish_reason = req.finish_reason
            self._maybe_complete_family(st.parent_id)
        stepped = iters_before is not None and \
            getattr(self.backend, "iterations", None) != iters_before
        self._progressed = bool(chunks) or bool(finished) or injected \
            or stepped
        if not self._progressed and self._queue and not self.backend.has_work:
            if self.backend.clock() is not None:
                # virtual clock idle before the next arrival: jump ahead
                self.backend.advance_to(self._queue[0].arrival_time)
                return self.poll(now, collect=collect)
            if now is None:
                # wall clock, service-owned time: sleep out the gap
                time.sleep(max(0.0, self._queue[0].arrival_time - t))
                return self.poll(None, collect=collect)
        return list(chunks.values())

    def drain(self, max_iters: int = 1_000_000) -> None:
        """Poll until every submitted request finished or the backend can
        make no further progress (e.g. a request that can never fit)."""
        idle = 0
        # without an `iterations` counter on the backend, token chunks are
        # the only progress signal — keep collecting them
        collect = not hasattr(self.backend, "iterations")
        for _ in range(max_iters):
            if not self.pending:
                return
            self.poll(collect=collect)
            if self._progressed:
                idle = 0
            else:
                idle += 1
                if idle >= 3:
                    return  # stalled: nothing scheduled, nothing arriving

    # -- blocking / streaming front doors ---------------------------------------

    def generate(self, prompts: Iterable[Sequence[int]],
                 params: Optional[SamplingParams] = None
                 ) -> List[RequestOutput]:
        """Submit ``prompts`` and block until all finish. One
        :class:`RequestOutput` per prompt, in order."""
        ids = [self.submit(p, params) for p in prompts]
        self.drain()
        return [self._take_result(i) for i in ids]

    def stream(self, prompts: Iterable[Sequence[int]],
               params: Optional[SamplingParams] = None
               ) -> Iterator[CompletionChunk]:
        """Submit ``prompts`` and yield chunks as the backend steps."""
        for p in prompts:
            self.submit(p, params)
        idle = 0
        while self.pending:
            chunks = self.poll()
            idle = 0 if self._progressed else idle + 1
            if not chunks and idle >= 3:
                return
            yield from chunks

    def replay(self, requests: Sequence[Request],
               params: Optional[SamplingParams] = None
               ) -> Tuple[List[RequestOutput], ServiceStats]:
        """Run an open-loop arrival trace to completion (virtual-clock
        backends). Returns per-request outputs (trace order) + aggregates."""
        ids = [self.submit_request(r, params) for r in
               sorted(requests, key=lambda r: r.arrival_time)]
        self.drain()
        stats = self.stats()
        return [self._results.get(i) for i in ids], stats

    # -- results ----------------------------------------------------------------

    def _maybe_complete_family(self, parent_id: int) -> None:
        members = self._families[parent_id]
        if not all(self._live[m].finished for m in members):
            return
        samples = []
        for m in members:
            req = self._live[m].req
            samples.append(CompletionSample(
                list(req.full_output), req.cumulative_logprob,
                req.finish_reason or FINISH_LENGTH,
                token_logprobs=list(req.logprobs)
                if len(req.logprobs) == len(req.full_output) else None))
        samples.sort(key=lambda s: -s.cumulative_logprob)
        parent = self._live[parent_id].req
        best = samples[0]
        self._results[parent_id] = RequestOutput(
            request_id=parent_id,
            prompt_len=parent.prompt_len,
            token_ids=best.token_ids,
            finish_reason=best.finish_reason,
            metrics=_metrics_of(parent),
            cumulative_logprob=best.cumulative_logprob,
            samples=samples,
        )
        for m in members:
            del self._live[m]

    def _take_result(self, rid: int) -> RequestOutput:
        try:
            return self._results.pop(rid)
        except KeyError:
            raise RuntimeError(
                f"request {rid} did not finish (backend stalled — prompt "
                f"larger than the backend's memory, or drain() gave up)")

    def stats(self) -> ServiceStats:
        """Aggregate metrics over all completed outputs so far."""
        outs = list(self._results.values())
        s = ServiceStats(n_requests=len(self._order))
        s.n_finished = len(outs)
        s.n_dropped = sum(1 for o in outs
                          if o.finish_reason == FINISH_DROPPED)
        done = [o for o in outs if o.finish_reason != FINISH_DROPPED]
        s.total_tokens = sum(o.n_generated for o in done)
        ttfts = [o.metrics.ttft for o in outs if o.metrics.ttft is not None]
        if ttfts:
            s.mean_ttft = sum(ttfts) / len(ttfts)
        lats = [o.metrics.normalized_latency for o in done
                if o.metrics.normalized_latency is not None]
        if lats:
            s.mean_normalized_latency = sum(lats) / len(lats)
        s.p99_normalized_latency = percentile(lats, 99)
        worst = [o.metrics.max_tbt for o in done
                 if o.metrics.max_tbt is not None]
        s.p99_tbt = percentile(worst, 99)
        stalls = [max(0.0, o.metrics.max_tbt - o.metrics.tbt) for o in done
                  if o.metrics.max_tbt is not None
                  and o.metrics.tbt is not None]
        if stalls:
            s.prefill_stall_ms = 1e3 * sum(stalls) / len(stalls)
        clk = self.backend.clock()
        if clk is not None:
            s.makespan = clk
        elif done:
            s.makespan = max(o.metrics.e2e + o.metrics.arrival_time
                             for o in done if o.metrics.e2e is not None)
        if s.makespan > 0:
            s.throughput_tokens_per_s = s.total_tokens / s.makespan
        s.preemptions = getattr(self.backend, "preemptions", 0) or \
            sum(o.metrics.preemptions for o in outs)
        pc = getattr(self.backend, "prefix_cache", None)
        if pc is not None:
            s.prefix_hit_rate = pc.hit_rate
        inst = getattr(self.backend, "instance_stats", None)
        if inst is not None:
            s.per_instance = inst()
        tl = self.metrics_timelines()
        if tl:
            s.timelines = tl
        rt = getattr(self.backend, "role_timelines", None)
        if rt is not None:
            grouped = rt()
            if grouped:
                s.role_timelines = grouped
        return s

    # -- telemetry ----------------------------------------------------------------

    def metrics_timelines(self) -> Dict[int, List[Dict]]:
        """Per-instance metric timelines from a telemetry-enabled backend
        (empty when telemetry is off). Routers report one timeline per
        child instance; single backends report under instance 0."""
        fn = getattr(self.backend, "metrics_timelines", None)
        if fn is not None:
            return fn()
        m = getattr(self.backend, "metrics", None)
        return {0: m.rows()} if m is not None else {}

    def trace_events(self) -> list:
        """All tracer events from a telemetry-enabled backend (empty when
        telemetry is off), merged across instances for routers."""
        fn = getattr(self.backend, "trace_events", None)
        if fn is not None:
            return fn()
        tr = getattr(self.backend, "trace", None)
        return tr.events() if tr is not None else []

    def export_trace(self, path: str) -> int:
        """Write the backend's trace as Chrome/Perfetto trace-event JSON
        (open in https://ui.perfetto.dev). Returns the event count."""
        from repro.core.telemetry import export_chrome_trace
        events = self.trace_events()
        export_chrome_trace(events, path)
        return len(events)

    def export_metrics_csv(self, path: str) -> int:
        """Write per-iteration metric timelines as CSV (one row per
        instance-iteration). Returns the row count."""
        from repro.core.telemetry import export_metrics_csv
        return export_metrics_csv(self.metrics_timelines(), path)


def _metrics_of(req: Request) -> RequestMetrics:
    ttft = None if req.first_token_time is None else \
        req.first_token_time - req.arrival_time
    e2e = None if req.finish_time is None else \
        req.finish_time - req.arrival_time
    queue = None if req.scheduled_time is None else \
        req.scheduled_time - req.arrival_time
    tbt = None
    if req.finish_time is not None and req.first_token_time is not None \
            and req.total_generated > 1:
        tbt = (req.finish_time - req.first_token_time) / \
            (req.total_generated - 1)
    prefill_time = None
    if req.first_token_time is not None and req.scheduled_time is not None:
        prefill_time = req.first_token_time - req.scheduled_time
    return RequestMetrics(
        arrival_time=req.arrival_time, queue_time=queue, ttft=ttft, tbt=tbt,
        e2e=e2e, normalized_latency=req.normalized_latency(),
        preemptions=req.preemptions,
        num_cached_tokens=req.num_cached_tokens,
        instance_id=req.instance_id,
        max_tbt=req.max_tbt if req.total_generated > 1 else None,
        prefill_time=prefill_time)
