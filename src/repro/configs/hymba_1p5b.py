"""Hymba-1.5B — hybrid parallel attention + Mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba runs attention and SSM heads *in parallel within the same layer* and uses
sliding-window attention on most layers with a few global-attention layers —
which is what makes ``long_500k`` feasible for this arch.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention="gqa",
    sliding_window=1024,
    global_attn_every=16,  # layers 0, 16 use global attention (paper: first/middle/last)
    ssm_state=16,
    ssm_head_dim=50,  # d_inner=3200, 64 ssm heads of dim 50
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)
