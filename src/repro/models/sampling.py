"""Token sampling for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits, key, *, temperature: float = 1.0, top_k: int = 0):
    """logits: (B, V). temperature<=0 => greedy."""
    if temperature <= 0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
