"""ORCA iteration-level scheduler (paper §III.B Sol1) with selective batching
and Sarathi-style chunked prefill.

Each call to :meth:`schedule` plans exactly ONE engine iteration as a single
**token-budget batch composition**: ongoing decodes, prefill *chunks* of
running requests, and new admissions all draw from one
``max_tokens_per_iter`` budget. Early-finished requests leave the batch
immediately; late-joining requests enter at the next iteration — the exact
fix for ORCA's challenge C1.

Chunked prefill. A prompt larger than the iteration budget used to run
*solo* (stalling every running decode for the whole prefill). Now a request
is admitted once and then contributes budget-sized **chunks** across
successive iterations, tracked by ``Request.prefilled_len``: each iteration
the request prefills ``min(remaining prompt, leftover budget)`` tokens,
piggybacked with the ongoing decodes, and only the final chunk samples a
token. ``chunk_policy`` picks who gets the budget first:

* ``decode_first`` (default, Sarathi-style stall-free batching) — every
  running decode is granted its token before any prefill work, so TBT stays
  bounded by one budget-sized iteration;
* ``prefill_first`` — chunk continuations and admissions take the budget
  first and decodes run in the leftover (TTFT-optimal, decodes may stall
  under sustained prefill pressure — the classic prefill-priority trade);
* ``monolithic`` — no chunking: an over-budget prompt is admitted alongside
  the running decodes and prefills in ONE iteration, stalling every decode
  for the full prefill (the vLLM-default "solo prefill in the batch"
  baseline the chunked benchmark measures against);
* ``solo`` — the legacy stand-in policy: an over-budget prompt waits for an
  otherwise-idle instance and then runs alone. Decodes never stall (none
  are running), but the waiting prompt head-of-line-blocks every admission
  behind it while decodes drain — the TTFT/throughput pathology.

Selective batching (Sol2) shows up as the *token budget*: attention is
per-sequence (paged cache), while MLP/linear layers run over the flattened
token buffer, so the scheduler bounds ``sum(chunk lens) + #decodes`` per
iteration rather than the sequence count.

Memory is delegated to a :class:`BlockAllocator` (vLLM §III.C) or any object
with the same interface; a request's whole prompt worth of pages is reserved
at admission (chunk continuations never allocate). When pages run out a
victim chosen by ``victim_policy`` (LIFO / FIFO / LRU) loses its device
pages — by **sacrifice** (vLLM's recompute policy: pages freed, though the
victim's computed prompt pages are first adopted into the radix tree so the
recompute covers only the uncached suffix) or, with a host tier configured
and ``swap_mode`` allowing it, by **swap-to-host**: the KV moves to host
pages over PCIe, the request re-enters WAITING still holding its (now
host-resident) table, and swap-in resumes decode or mid-prefill chunking
exactly where it stopped — no recompute at all. ``swap_mode="auto"``
decides per victim via ``swap_decider`` (the sim wires a PCIe-vs-recompute
cost comparison) or a computed-token threshold.

With a :class:`~repro.core.prefixcache.PrefixCache` attached, admission first
matches the prompt against the radix tree: matched pages are locked into the
request's block table (refcounted, no recompute) and only the *uncached
suffix* is charged against the token budget — chunked exactly like a cold
prompt when it exceeds the budget. With ``token_level`` matching the hit may
end mid-page: the partially-matched node is locked with only the shared run
counted as stored, and the allocator's copy-on-write duplicates the boundary
page on the first suffix write (the SGLang split realized as a partial-page
COW). Prompt pages are inserted into the tree as soon as prefill completes
(and survive the request), and under page pressure LRU cache eviction runs
before any preemption.

``prefix_importer`` extends the match across instances: before committing
to a local match, admission offers the prompt to the importer (wired by a
cluster router to the distkv publication board), which may *adopt* pages a
peer instance published into the local tree — the admission then re-matches
and prefills only the suffix past the imported prefix.

``remote_adopter`` is the zero-copy alternative: instead of copying
payloads, it may return a :class:`~repro.core.distkv.rmanager.RemoteLease`
— borrowed rBlocks whose pages stay on the creditor instance. The request
is then admitted with only its *suffix* pages local (positions
``[0, lease.num_tokens)`` are served remotely through the DistAttention
partial merge), the lease is held for the request's lifetime, and release
(finish or preemption) repays the creditor **before** any local page is
freed. Leased prompts are never inserted into the local radix tree — their
leading pages do not exist here.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.paging.allocator import BlockAllocator, BlockTable
from repro.core.prefixcache.radix import PrefixCache
from repro.core.scheduling.request import Phase, Request

CHUNK_POLICIES = ("decode_first", "prefill_first", "monolithic", "solo")
# what happens to a preemption victim's KV:
#   sacrifice — free the pages, recompute on re-admission (the vLLM default)
#   swap      — move the pages to the host tier over PCIe, resume without
#               re-prefilling (falls back to sacrifice when host is full)
#   auto      — per-victim decision: swap when the modeled transfer undercuts
#               the recompute (``swap_decider``), else a computed-token
#               threshold stand-in
SWAP_MODES = ("sacrifice", "swap", "auto")
# who gets preempted when pages run out:
#   lifo — youngest running request (least sunk work, the vLLM default)
#   fifo — oldest running request
#   lru  — least recently *scheduled* (no decode/chunk granted longest)
#   cost — cheapest to evict per freed page: rank candidates by modeled
#          eviction seconds (PCIe round trip if the victim would swap,
#          quadratic recompute if it would sacrifice) divided by the pages
#          freed (``victim_cost_fn``, or a built-in mirror of the sim's
#          cost-model constants)
VICTIM_POLICIES = ("lifo", "fifo", "lru", "cost")


@dataclasses.dataclass
class PrefillChunk:
    """One iteration's slice of a request's prefill: compute prompt tokens
    ``[start, start + length)`` at their absolute positions. ``start`` of the
    first chunk is the cached-prefix length (which may be mid-page under
    token-level matching)."""
    req: Request
    start: int
    length: int

    @property
    def end(self) -> int:
        return self.start + self.length

    @property
    def is_last(self) -> bool:
        return self.end == self.req.prompt_len


@dataclasses.dataclass
class IterationPlan:
    # requests whose FINAL prefill chunk runs this iteration: they produce
    # first-token logits and enter decode next iteration. (Backends append
    # COW-forked best-of-n children here after scheduling.)
    prefill: List[Request]
    decode: List[Request]
    preempted: List[Request]
    # copy-on-write block replacements this iteration: the engine must copy
    # each old physical page into its new page before any decode write
    cow: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    # ALL prefill work this iteration (including the final chunks mirrored
    # in ``prefill``): the execution backends run these in order
    chunks: List[PrefillChunk] = dataclasses.field(default_factory=list)
    # host-tier transfers this iteration, as (request, page-pair list):
    # swap_out pairs are (device, host), swap_in pairs are (host, device).
    # The page payloads were already moved by the scheduler's swap hooks
    # (engine) — these lists exist for backends to charge transfer time
    # (sim PCIe lane) and manage per-request state (engine decode slots).
    swap_out: List[Tuple[Request, List[Tuple[int, int]]]] = \
        dataclasses.field(default_factory=list)
    swap_in: List[Tuple[Request, List[Tuple[int, int]]]] = \
        dataclasses.field(default_factory=list)
    # overlapped (speculative) swap-out lifecycle, same (request, pairs)
    # shape: ``swap_issue`` starts a device->host copy that double-buffers
    # against the NEXT iteration's compute, ``swap_complete`` lands it one
    # iteration later (device pages free only now), ``swap_cancel`` aborts
    # it because pressure receded (pages never left). Backends use these to
    # move the payloads (engine) / charge overlap-windowed PCIe time (sim)
    # and to manage per-request decode slots.
    swap_issue: List[Tuple[Request, List[Tuple[int, int]]]] = \
        dataclasses.field(default_factory=list)
    swap_complete: List[Tuple[Request, List[Tuple[int, int]]]] = \
        dataclasses.field(default_factory=list)
    swap_cancel: List[Tuple[Request, List[Tuple[int, int]]]] = \
        dataclasses.field(default_factory=list)

    @property
    def empty(self) -> bool:
        """No *compute* this iteration. Swap-only iterations are still
        "empty" — backends must process ``swap_out``/``swap_in`` (and
        ``swap_issue``/``swap_complete``/``swap_cancel``/``preempted``)
        before early-returning on this."""
        return not (self.chunks or self.prefill or self.decode)

    def token_count(self) -> int:
        """Tokens through the flattened MLP buffer this iteration (cached
        prefix pages are read, not recomputed — they cost no prefill FLOPs)."""
        return sum(c.length for c in self.chunks) + len(self.decode)


class IterationScheduler:
    def __init__(self, allocator: BlockAllocator, *,
                 max_running: int = 64,
                 max_tokens_per_iter: int = 8192,
                 watermark: float = 0.01,
                 prefix_cache: Optional[PrefixCache] = None,
                 max_preemptions: Optional[int] = None,
                 cache_generated: bool = True,
                 chunk_policy: str = "decode_first",
                 decode_reserve: bool = True,
                 prefill_chunk_min: Optional[int] = None,
                 prefix_importer: Optional[
                     Callable[[Sequence[int], int], int]] = None,
                 remote_adopter: Optional[
                     Callable[[Request, int], Optional[object]]] = None,
                 prefill_only: bool = False,
                 swap_mode: str = "sacrifice",
                 victim_policy: str = "lifo",
                 swap_decider: Optional[
                     Callable[[Request, int], bool]] = None,
                 swap_min_tokens: Optional[int] = None,
                 victim_cost_fn: Optional[
                     Callable[[Request, BlockTable], float]] = None,
                 speculative_swap: bool = False):
        if chunk_policy not in CHUNK_POLICIES:
            raise ValueError(f"chunk_policy must be one of {CHUNK_POLICIES}, "
                             f"got {chunk_policy!r}")
        if swap_mode not in SWAP_MODES:
            raise ValueError(f"swap_mode must be one of {SWAP_MODES}, "
                             f"got {swap_mode!r}")
        if victim_policy not in VICTIM_POLICIES:
            raise ValueError(f"victim_policy must be one of "
                             f"{VICTIM_POLICIES}, got {victim_policy!r}")
        self.allocator = allocator
        self.max_running = max_running
        self.max_tokens = max_tokens_per_iter
        self.watermark_blocks = max(1, int(allocator.num_blocks * watermark))
        self.prefix_cache = prefix_cache
        # a request preempted more than this many times is dropped with
        # finish_reason "preempted-dropped" instead of recomputed forever
        self.max_preemptions = max_preemptions
        # insert *generated* tokens into the radix tree at finish, so a
        # multi-turn follow-up resending the assistant reply hits the cache
        # beyond the prompt. Disable when outputs are placeholder ids (sim).
        self.cache_generated = cache_generated
        self.chunk_policy = chunk_policy
        # prefill_first only: set aside the pages this iteration's decode
        # grants will need BEFORE admissions run (admission-before-decode
        # could otherwise admit a request the same iteration's decode growth
        # then preempts). False restores the old racy behavior (tests).
        self.decode_reserve = decode_reserve
        # smallest first chunk worth ADMITTING a request on (degenerate
        # slivers pay an iteration's fixed cost for a handful of tokens,
        # and admitting on a sliver starts a prefill before a same-prefix
        # predecessor could warm the radix tree). Continuations are exempt:
        # an admitted request holds pages, so it always progresses. A final
        # chunk smaller than this still runs — prompts end somewhere.
        self.prefill_chunk_min = prefill_chunk_min \
            if prefill_chunk_min is not None else allocator.block_size
        # cross-instance sharing hook: (prompt, locally_cached_tokens) ->
        # #pages adopted from a peer's publication into the local tree.
        # Admission re-matches after a successful import.
        self.prefix_importer = prefix_importer
        # zero-copy sharing hook: (request, locally_cached_tokens) -> a
        # RemoteLease of borrowed rBlocks strictly longer than the local
        # match, or None. Tried BEFORE the copy importer; when a lease is
        # granted the copy path is skipped for this admission.
        self.remote_adopter = remote_adopter
        # disaggregated serving: a prefill-role instance never plans decode
        # tokens — a request whose prefill completed parks in ``running``
        # (Phase.INCREMENT) until a KVHandoff coordinator moves its KV to a
        # decode instance via release_request()/install_running()
        self.prefill_only = prefill_only
        # swap-to-host preemption (see SWAP_MODES / VICTIM_POLICIES above).
        # ``swap_decider(req, n_pages) -> bool`` resolves "auto" per victim
        # (the sim wires a PCIe-vs-recompute cost comparison); without one,
        # auto swaps once the victim's computed context reaches
        # ``swap_min_tokens`` (default: 8 pages' worth — below that the
        # recompute is cheaper than the round trip).
        self.swap_mode = swap_mode
        self.victim_policy = victim_policy
        self.swap_decider = swap_decider
        self.swap_min_tokens = swap_min_tokens if swap_min_tokens is not None \
            else 8 * allocator.block_size
        # cost victim policy: (request, table) -> the raw eviction bill in
        # seconds (PCIe round trip if the victim would swap, quadratic
        # recompute if it would sacrifice). _pick_from normalizes by the
        # pages the eviction actually frees toward the current shortfall,
        # lower = better victim. The sim wires its CostModel/NetworkModel;
        # without one a built-in mirror of those constants runs
        # (see _victim_cost).
        self.victim_cost_fn = victim_cost_fn
        # speculative overlapped swap-out: when free pages trend under the
        # watermark plus the running decodes' imminent page growth, issue a
        # victim's device->host copy at the END of schedule() so it
        # double-buffers against the next iteration's compute. The
        # allocator's pending ledger keeps the DMA-source pages allocated
        # until the copy completes at the top of the NEXT schedule() — or
        # the issue is cancelled there if pressure receded (pages never
        # left, the victim resumes with zero loss).
        self.speculative_swap = speculative_swap
        self._pending_swaps: List[Tuple[int, Request,
                                        List[Tuple[int, int]]]] = []
        # rids whose speculative swap-out completed: held out of swap-in
        # readmission until pressure genuinely recedes (avail covers their
        # need PLUS a watermark of slack). Without this the completed
        # swap's freed pages readmit the very victim they came from one
        # iteration later — a pure PCIe round trip that frees nothing —
        # because the complete lands before any decode has consumed the
        # pages (the demand path's eviction happens mid-decode-planning,
        # so its freed pages never look quite big enough to readmit into).
        self._swap_hold: set = set()
        # data-movement hooks wired by the engine (None in the sim): called
        # synchronously with the allocator's page pairs, swap_out_hook BEFORE
        # any later work this schedule() could reallocate-and-write the freed
        # device pages, swap_in_hook right after fresh device pages are
        # allocated (nothing reads them until the backend's next compute)
        self.swap_out_hook: Optional[
            Callable[[List[Tuple[int, int]]], None]] = None
        self.swap_in_hook: Optional[
            Callable[[List[Tuple[int, int]]], None]] = None
        # overlapped-swap lifecycle hooks (engine: issue records the pending
        # copy, complete performs it — the ledger guarantees the source
        # pages are still intact one iteration later — cancel drops it)
        self.swap_issue_hook: Optional[
            Callable[[List[Tuple[int, int]]], None]] = None
        self.swap_complete_hook: Optional[
            Callable[[List[Tuple[int, int]]], None]] = None
        self.swap_cancel_hook: Optional[
            Callable[[List[Tuple[int, int]]], None]] = None
        # KVHandoff fallback (disaggregated serving): request ids a
        # prefill-only instance IS allowed to decode — requests whose
        # handoff deferral cap expired decode here, mixed-style, instead of
        # starving behind busy decode instances
        self.decode_exempt: set = set()
        # monotonically increasing schedule() call index — stamps
        # Request.last_planned_iter, the "lru" victim policy's recency key
        self._iter_idx = 0
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.tables: Dict[int, BlockTable] = {}
        self._cache_paths: Dict[int, list] = {}  # request id -> locked nodes
        # outstanding zero-copy prefix leases by request id (shared by
        # COW-forked siblings via lease.acquire)
        self.leases: Dict[int, object] = {}
        # prefill_first decode-page reserve (see schedule())
        self._decode_reserve = 0
        # telemetry: a repro.core.telemetry.Tracer wired by the execution
        # backend, or None (the default — every emission site guards on
        # this so the disabled path allocates nothing)
        self.trace = None

    # -- client API -------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        req.phase = Phase.WAITING
        self.waiting.append(req)
        tr = self.trace
        if tr is not None:
            tr.begin("request", "req", req.request_id,
                     prompt_len=req.prompt_len,
                     max_new_tokens=req.max_new_tokens)

    def finish(self, req: Request, now: float,
               reason: Optional[str] = None) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = now
        req.finish_reason = reason or req.finish_reason_if_done \
            or req.finish_reason
        # repay the creditor of a zero-copy prefix lease FIRST: the debt
        # side must be settled before any local teardown can fault, so a
        # creditor never leaks a lent block
        lease = self.leases.pop(req.request_id, None)
        tr = self.trace
        if lease is not None:
            lease.release()
            if tr is not None:
                tr.instant("lease", "release", rid=req.request_id, ts=now,
                           tokens=lease.num_tokens, cause="finish")
        if tr is not None:
            tr.end("request", "req", req.request_id, ts=now,
                   reason=req.finish_reason, generated=req.n_generated)
        if req.request_id in self.tables:
            table = self.tables[req.request_id]
            # adopt the *generated* tokens' full pages too (the prompt pages
            # were inserted at prefill completion): a multi-turn follow-up
            # that resends this reply as history then hits past the prompt.
            # KV exists for the first num_tokens context tokens — the final
            # sampled token was never fed back, so its page may be partial.
            # A leased request's local pages cover only its suffix (the
            # leading positions live on the creditor), so there is no valid
            # root path to insert. A host-resident (swapped-out) table has
            # no device pages to adopt — finished-while-swapped just frees.
            if self.prefix_cache is not None and self.cache_generated \
                    and len(req.prompt) == req.prompt_len and lease is None \
                    and not table.on_host:
                toks = (req.prompt + req.output)[:table.num_tokens]
                self.prefix_cache.insert(toks, table.blocks)
            # the tree's increfs keep adopted pages alive past free_table
            self._release_cache_path(req)
            self.allocator.free_table(self.tables.pop(req.request_id))
        if req in self.running:
            self.running.remove(req)
        if req in self.waiting:  # finished-while-swapped / external cancel
            self.waiting.remove(req)
        self.decode_exempt.discard(req.request_id)

    def _release_cache_path(self, req: Request) -> None:
        path = self._cache_paths.pop(req.request_id, None)
        if path:
            self.prefix_cache.release(path)

    def prefill_backlog_tokens(self) -> int:
        """Prompt tokens not yet prefilled: queued prompts plus the unfilled
        remainder of running chunked prefills. A cluster router counts this
        as load — an instance chewing through a 100k-token prompt is busier
        than its request count suggests."""
        backlog = sum(r.prompt_len for r in self.waiting)
        backlog += sum(r.prompt_len - r.prefilled_len for r in self.running
                       if r.prefilled_len < r.prompt_len)
        return backlog

    def remote_tokens_of(self, request_id: int) -> int:
        """Leading context tokens served from a creditor instance's pages
        under a zero-copy lease (0 = fully local). Execution backends use
        this to split attention into local + remote partials."""
        lease = self.leases.get(request_id)
        return lease.num_tokens if lease is not None else 0

    # -- one iteration ------------------------------------------------------------
    def schedule(self) -> IterationPlan:
        plan = IterationPlan(prefill=[], decode=[], preempted=[], cow=[],
                             chunks=[])
        self._budget = self.max_tokens
        self._iter_idx += 1
        if self._pending_swaps:
            # every in-flight swap-out resolves exactly one iteration after
            # issue (double-buffering, not an unbounded queue): complete it
            # — the overlapped copy landed during last iteration's compute —
            # or cancel it if pressure receded meanwhile
            self._resolve_pending_swaps(plan)
        if self.chunk_policy == "prefill_first":
            # decode-page reserve: admissions run BEFORE the decode planner
            # here, so without a reserve an admission can take the very page
            # a running decode needs this same iteration — the decode then
            # preempts someone (possibly the fresh admission) it just made
            # room for. Set aside the pages this iteration's decode grants
            # will allocate before admitting anyone. (Conservative: a decode
            # later denied by the token budget still reserved its page.)
            self._decode_reserve = sum(
                self.allocator.blocks_needed(self.tables[r.request_id], 1)
                for r in self.running
                if r.request_id in self.tables
                and r.prefilled_len >= r.prompt_len) \
                if self.decode_reserve and not self.prefill_only else 0
            self._plan_continuations(plan)
            self._plan_admissions(plan)
            self._decode_reserve = 0
            self._plan_decodes(plan)
        else:  # decode_first (Sarathi stall-free) and legacy solo
            self._plan_decodes(plan)
            self._plan_continuations(plan)
            self._plan_admissions(plan)
        if self.speculative_swap:
            self._maybe_speculate(plan)
        return plan

    # -- overlapped (speculative) swap-out ------------------------------------
    def _growth_pages(self, horizon: int = 2) -> int:
        """Device pages the running decodes will allocate within the next
        ``horizon`` tokens — the demand side of the speculation trigger."""
        total = 0
        for r in self.running:
            t = self.tables.get(r.request_id)
            if t is None or t.on_host:
                continue
            if r.prefilled_len >= r.prompt_len:
                total += self.allocator.blocks_needed(t, horizon)
        return total

    def _maybe_speculate(self, plan: IterationPlan) -> None:
        """Issue one victim's swap-out BEFORE memory actually runs out, so
        the PCIe copy rides under the next iteration's compute instead of
        serializing with it when the demand eviction finally fires."""
        if self.swap_mode == "sacrifice" or self.prefill_only \
                or self.allocator.num_host_blocks == 0 \
                or self._pending_swaps:
            return
        # fire only when the pool cannot serve the running decodes' growth
        # over the lookahead horizon — the same exhaustion signal the
        # demand path's can_append failure gives, just 1-2 iterations
        # earlier. No watermark term: decode appends draw the pool below
        # the watermark freely (only admissions respect it), and firing at
        # the watermark would evict victims a completion was about to save.
        if self.allocator.num_free >= self._growth_pages():
            return  # free pages cover the imminent decode growth
        # decode-phase victims only: they hold a generated token the engine
        # can re-seed its slot from on cancel, and their planned work this
        # iteration is at most one decode token to rescind
        cands = [r for r in self.running
                 if r.request_id in self.tables
                 and r.prefilled_len >= r.prompt_len and r.n_generated > 0
                 and self._should_swap(r)]
        if not cands:
            return
        victim = self._pick_from(cands, needed=max(1, self._growth_pages()))
        self._rescind(plan, victim)
        self._release_cache_path(victim)
        table = self.tables[victim.request_id]
        ticket, pairs = self.allocator.swap_out_issue(table)
        if self.swap_issue_hook is not None:
            self.swap_issue_hook(pairs)
        victim.swaps += 1
        victim.phase = Phase.WAITING
        self.running.remove(victim)
        self.waiting.insert(0, victim)
        plan.swap_issue.append((victim, pairs))
        self._pending_swaps.append((ticket, victim, pairs))
        tr = self.trace
        if tr is not None:
            tr.begin("swap", "pending", victim.request_id,
                     pages=len(pairs), speculative=True)
            tr.instant("sched", "swap_issue", rid=victim.request_id,
                       pages=len(pairs), kind="speculative")

    def _resolve_pending_swaps(self, plan: IterationPlan) -> None:
        """Complete or cancel every in-flight swap-out (issued last
        iteration). Complete: the copy landed during the overlapped compute;
        the ledger's device references drop and the victim stays parked as a
        normal host-resident waiter. Cancel: a finish/eviction freed enough
        pages meanwhile — the device references move back onto the table
        and the victim resumes decode immediately, having lost nothing."""
        pending, self._pending_swaps = self._pending_swaps, []
        tr = self.trace
        for ticket, req, pairs in pending:
            if req.request_id not in self.tables:
                # finished-while-pending / external cancel: free_table
                # already released the host pages; just drop the ledger's
                # device references (no copy — there is nowhere to copy to)
                self.allocator.swap_out_complete(ticket)
                if tr is not None:
                    tr.end("swap", "pending", req.request_id,
                           outcome="orphaned")
                continue
            table = self.tables[req.request_id]
            # hysteresis: cancelling needs a watermark of slack past the
            # growth that triggered the issue (a completion-scale event,
            # not one stray freed page), or issue/cancel would flap at the
            # exhaustion boundary every iteration
            receded = self.allocator.num_free \
                >= self._growth_pages() + self.watermark_blocks
            if receded:
                self.allocator.swap_out_cancel(ticket, table)
                if self.swap_cancel_hook is not None:
                    self.swap_cancel_hook(pairs)
                self.waiting.remove(req)
                req.phase = Phase.INCREMENT if \
                    req.prefilled_len >= req.prompt_len else Phase.INITIATION
                req.last_planned_iter = self._iter_idx
                self.running.append(req)
                plan.swap_cancel.append((req, pairs))
                if tr is not None:
                    tr.end("swap", "pending", req.request_id,
                           outcome="cancel")
                    tr.instant("sched", "swap_cancel", rid=req.request_id,
                               pages=len(pairs))
            else:
                if self.swap_complete_hook is not None:
                    # engine copies device->host NOW — the pending ledger
                    # kept the source pages allocated across the overlap
                    # window, so they still hold the victim's KV
                    self.swap_complete_hook(pairs)
                self.allocator.swap_out_complete(ticket)
                # hold the victim out of readmission until pressure truly
                # recedes — the pages this complete just freed must become
                # decode headroom, not an immediate swap-in of the victim
                # they were taken from (see _swap_hold in __init__)
                self._swap_hold.add(req.request_id)
                plan.swap_complete.append((req, pairs))
                if tr is not None:
                    tr.end("swap", "pending", req.request_id,
                           outcome="complete")
                    # the pages have now actually left the device: emit the
                    # classic swap_out instant so the out/in balance
                    # invariant (validate_swap_balance) sees this request
                    # as host-resident from here on
                    tr.instant("sched", "swap_out", rid=req.request_id,
                               pages=len(pairs), trigger=req.request_id,
                               kind="speculative")

    def _rescind(self, plan: IterationPlan, victim: Request) -> None:
        """Remove work already planned this iteration for a preemption
        victim (its pages are gone): a granted decode token, a prefill
        chunk, or a pending COW copy must not reach the backend with a
        freed block table. Must run BEFORE :meth:`_preempt` frees the
        victim's table — the COW pairs are identified by their target
        blocks, which the victim still owns."""
        tr = self.trace
        if victim in plan.decode:
            plan.decode.remove(victim)
            self._budget += 1
            if tr is not None:
                tr.instant("req", "decode_rescind", rid=victim.request_id)
        for c in [c for c in plan.chunks if c.req is victim]:
            plan.chunks.remove(c)
            self._budget += c.length
            # roll back the progress the planner credited for this chunk:
            # its KV will never be computed, so leaving prefilled_len past
            # c.start would let a swap preserve — or the preemption path
            # cache-insert — pages holding garbage
            victim.prefilled_len = min(victim.prefilled_len, c.start)
            if tr is not None:
                tr.instant("req", "chunk_rescind", rid=victim.request_id,
                           start=c.start, length=c.length)
        if victim in plan.prefill:
            plan.prefill.remove(victim)
        # COW targets are freshly-allocated blocks exclusively owned by the
        # victim; once freed they can be REALLOCATED later this same
        # schedule() call (admission, prefix adoption), so a stale pending
        # copy would silently clobber the new owner's page contents
        table = self.tables.get(victim.request_id)
        if table is not None and plan.cow:
            owned = set(table.blocks)
            before = len(plan.cow)
            plan.cow[:] = [p for p in plan.cow if p[1] not in owned]
            if tr is not None and len(plan.cow) != before:
                tr.instant("sched", "cow_rescind", rid=victim.request_id,
                           pairs=before - len(plan.cow))

    def _plan_decodes(self, plan: IterationPlan) -> None:
        """Advance every running decode by one token (latency priority
        within its budget slice), preempting under page pressure."""
        if self.prefill_only and not self.decode_exempt:
            return  # disaggregated prefill role: decode happens elsewhere
        # under prefill_first this runs AFTER the chunk planners: a request
        # whose final chunk is planned this very iteration must not also be
        # granted a decode token (it samples its first token from the
        # prefill logits and enters decode NEXT iteration — otherwise a
        # max_new_tokens=1 request would emit two tokens at once)
        chunked_now = {c.req.request_id for c in plan.chunks}
        for req in list(self.running):
            if self._budget <= 0:
                break
            if self.prefill_only and \
                    req.request_id not in self.decode_exempt:
                continue  # only handoff-fallback requests decode here
            if req.request_id not in self.tables:
                continue  # became a preemption victim earlier this iteration
            if req not in self.running:
                continue  # swapped out earlier this very loop
            if req.prefilled_len < req.prompt_len or \
                    req.request_id in chunked_now:
                continue  # still prefilling / final chunk runs this iter
            table = self.tables[req.request_id]
            if not self.allocator.can_append(table, 1) and \
                    self.prefix_cache is not None:
                # reclaim unreferenced cached pages before preempting anyone
                self.prefix_cache.evict(self.allocator.blocks_needed(table, 1))
            if not self.allocator.can_append(table, 1):
                # _evict_one rescinds the victim's already-planned work for
                # this iteration, then swaps or sacrifices its pages
                victim = self._evict_one(exclude=req, plan=plan)
                if victim is not None and self.prefix_cache is not None \
                        and not self.allocator.can_append(table, 1):
                    # the victim's prompt pages may survive only as
                    # tree-held (refcount-1) cache pages — reclaim them
                    # before giving up on this request too
                    self.prefix_cache.evict(
                        self.allocator.blocks_needed(table, 1))
                if victim is None or not self.allocator.can_append(table, 1):
                    # evict this request itself (rescind any of its own
                    # planned work too — its device pages are going away)
                    self._rescind(plan, req)
                    self._preempt_or_swap(req, plan,
                                          trigger=req.request_id,
                                          kind="self")
                    continue
            plan.cow.extend(self.allocator.append_tokens(table, 1))
            plan.decode.append(req)
            req.last_planned_iter = self._iter_idx
            self._budget -= 1

    def _plan_continuations(self, plan: IterationPlan) -> None:
        """Budget-sized prefill chunks for running requests admitted in an
        earlier iteration whose prompt is not fully prefilled yet. No memory
        is needed — the whole prompt's pages were reserved at admission."""
        tr = self.trace
        for req in list(self.running):
            if self._budget <= 0:
                break
            if req.request_id not in self.tables:
                continue
            remaining = req.prompt_len - req.prefilled_len
            if remaining <= 0:
                continue
            # no sliver guard here: the request already holds its pages, so
            # stalling its continuation would waste memory to save an
            # iteration's overhead — admission is where slivers are refused
            n = min(remaining, self._budget)
            plan.chunks.append(PrefillChunk(req, req.prefilled_len, n))
            if tr is not None:
                tr.instant("req", "chunk", rid=req.request_id,
                           start=req.prefilled_len, length=n,
                           last=req.prefilled_len + n == req.prompt_len)
            req.prefilled_len += n
            req.last_planned_iter = self._iter_idx
            if req.prefilled_len == req.prompt_len:
                plan.prefill.append(req)
            self._budget -= n

    def _plan_admissions(self, plan: IterationPlan) -> None:
        """Admit waiting requests (FCFS) into leftover budget + memory. The
        whole prompt's pages are allocated up front; only the first chunk is
        charged against this iteration's budget."""
        while (self.waiting and self._budget > 0
               and len(self.running) < self.max_running):
            req = self.waiting[0]
            swapped = self.tables.get(req.request_id)
            if swapped is not None and swapped.on_host:
                # a swapped-out victim waits at the front of the queue
                # (FCFS, same as a sacrificed victim): it resumes — not
                # re-prefills — once the device can hold its pages again
                if not self._plan_swap_in(req, swapped, plan):
                    break  # head-of-line: device still too full
                continue
            path: list = []
            partial = None
            lease = None
            cached = 0
            bs = self.allocator.block_size
            if self.prefix_cache is not None and \
                    len(req.prompt) == req.prompt_len:
                # cap at prompt_len-1: the last prompt token must be computed
                # for the first-token logits even if fully cached
                path = self.prefix_cache.match(req.prompt,
                                               max_tokens=req.prompt_len - 1)
                cached = len(path) * bs
                if self.remote_adopter is not None:
                    lease = self.remote_adopter(req, cached)
                    if lease is not None and lease.num_tokens <= cached:
                        lease.release()  # not longer than the local match
                        lease = None
                    if lease is None:
                        # the adopter may have materialized the peer's pages
                        # locally (promote-to-copy after N leases) instead
                        # of granting a lease — re-match so this admission
                        # hits the fresh local pages
                        repath = self.prefix_cache.match(
                            req.prompt, max_tokens=req.prompt_len - 1)
                        if len(repath) > len(path):
                            path = repath
                            cached = len(repath) * bs
                if lease is not None:
                    # zero-copy admission: positions [0, lease.num_tokens)
                    # are served from the creditor's pages through the
                    # DistAttention merge — no local path is locked and only
                    # the suffix needs local pages
                    path = []
                    cached = lease.num_tokens
                else:
                    if self.prefix_importer is not None and \
                            self.prefix_importer(req.prompt, cached) > 0:
                        # adopt-imported-pages path: a peer published pages
                        # extending our local match and they were just
                        # grafted into the local tree — re-match over them
                        path = self.prefix_cache.match(
                            req.prompt, max_tokens=req.prompt_len - 1)
                    partial = self.prefix_cache.match_partial(
                        req.prompt, path, max_tokens=req.prompt_len - 1)
                    cached = len(path) * bs + (partial[1] if partial else 0)
            need_tokens = req.prompt_len - cached
            if self.chunk_policy == "solo":
                if need_tokens > self._budget:
                    # legacy stand-in: a prompt larger than the whole
                    # iteration budget may run alone when the instance is
                    # otherwise idle — else huge prompts
                    # head-of-line-block forever
                    solo_ok = plan.empty and not plan.preempted and \
                        self._budget == self.max_tokens
                    if not solo_ok:
                        if lease is not None:
                            lease.release()
                        if self.trace is not None:
                            self.trace.instant("sched", "refuse",
                                               rid=req.request_id,
                                               why="solo_wait")
                        break
                first_chunk = need_tokens
            elif self.chunk_policy == "monolithic":
                # no chunking: the whole prompt prefills this iteration,
                # right next to the running decodes (who all stall for it)
                first_chunk = need_tokens
            else:
                if self._budget < min(need_tokens, self.prefill_chunk_min):
                    if lease is not None:
                        lease.release()
                    if self.trace is not None:
                        self.trace.instant("sched", "refuse",
                                           rid=req.request_id,
                                           why="budget_sliver",
                                           budget=self._budget)
                    break  # not worth starting a prefill on a sliver
                first_chunk = min(need_tokens, self._budget)
            # lock before checking supply so eviction cannot claim the
            # matched pages out from under us. A token-level partial hit
            # locks the boundary node too: its page enters the table with
            # only the shared run counted as stored, so the allocator COWs
            # it on the first suffix write (the split-boundary copy).
            table = BlockTable()
            full_path = path + [partial[0]] if partial else path
            if full_path:
                table.blocks = self.prefix_cache.lock(full_path)
                table.num_tokens = cached
            # +1 block when the shared boundary page will be COW-copied;
            # the free-page bar excludes the prefill_first decode reserve
            needed = self.allocator.blocks_needed(table, need_tokens) + \
                (1 if partial else 0)
            avail = self.allocator.num_free - self.watermark_blocks - \
                self._decode_reserve
            if needed > avail and self.prefix_cache is not None:
                self.prefix_cache.evict(needed - avail)
                avail = self.allocator.num_free - self.watermark_blocks - \
                    self._decode_reserve
            if needed > avail:
                if full_path:  # roll back the lock
                    self.prefix_cache.release(full_path)
                    self.allocator.free_table(table)
                if lease is not None:
                    lease.release()
                if self.trace is not None:
                    self.trace.instant("sched", "refuse", rid=req.request_id,
                                       why="no_pages", needed=needed,
                                       avail=avail)
                break
            self.waiting.pop(0)
            plan.cow.extend(self.allocator.append_tokens(table, need_tokens))
            self.tables[req.request_id] = table
            if lease is not None:
                self.leases[req.request_id] = lease
                commit = getattr(lease, "commit", None)
                if commit is not None:  # stats/charges fire on commit only
                    commit()
            if full_path:
                self._cache_paths[req.request_id] = full_path
            req.num_cached_tokens = cached
            if self.prefix_cache is not None:
                self.prefix_cache.record_admission(req.prompt_len, cached,
                                                   full_path)
            req.phase = Phase.INITIATION
            self.running.append(req)
            plan.chunks.append(PrefillChunk(req, cached, first_chunk))
            tr = self.trace
            if tr is not None:
                tr.instant("sched", "admit", rid=req.request_id,
                           cached=cached,
                           leased=lease.num_tokens if lease is not None else 0,
                           chunk=first_chunk, policy=self.chunk_policy)
                if lease is not None:
                    tr.instant("lease", "acquire", rid=req.request_id,
                               tokens=lease.num_tokens)
                tr.instant("req", "chunk", rid=req.request_id, start=cached,
                           length=first_chunk,
                           last=cached + first_chunk == req.prompt_len)
            req.prefilled_len = cached + first_chunk
            req.last_planned_iter = self._iter_idx
            if req.prefilled_len == req.prompt_len:
                plan.prefill.append(req)
            self._budget -= first_chunk

    def _plan_swap_in(self, req: Request, table: BlockTable,
                      plan: IterationPlan) -> bool:
        """Try to re-materialize a swapped-out request's pages on device.
        Returns True when the queue head was consumed (swapped in, or its
        snapshot abandoned), False to head-of-line-block this iteration."""
        bs = self.allocator.block_size
        # the pages to restore, plus the growth block the next decode
        # append may need (checked against supply, not allocated)
        growth = max(0, -(-(table.num_tokens + 1) // bs)
                     - len(table.host_blocks))
        need = len(table.host_blocks) + growth
        avail = self.allocator.num_free - self.watermark_blocks - \
            self._decode_reserve
        if need > avail and self.prefix_cache is not None:
            self.prefix_cache.evict(need - avail)
            avail = self.allocator.num_free - self.watermark_blocks - \
                self._decode_reserve
        if need > avail:
            if need > self.allocator.num_blocks - self.watermark_blocks:
                # this context can NEVER fit on device again (it filled the
                # pool and still needs to grow): the snapshot is useless —
                # degrade to sacrifice so re-admission (and the
                # max_preemptions drop budget) takes over
                self._abandon_swap(req, table, plan)
                return True
            if self.trace is not None:
                self.trace.instant("sched", "refuse", rid=req.request_id,
                                   why="swap_wait", needed=need, avail=avail)
            return False
        if req.request_id in self._swap_hold:
            # speculatively swapped out: only readmit once the pool holds
            # its need PLUS a full watermark of slack, i.e. the pressure
            # that justified the early swap-out has genuinely receded
            # (typically a resident completed). Readmitting into a pool
            # that barely fits would undo the eviction one iteration later.
            if avail < need + self.watermark_blocks:
                if self.trace is not None:
                    self.trace.instant("sched", "refuse",
                                       rid=req.request_id, why="swap_hold",
                                       needed=need, avail=avail)
                return False
            self._swap_hold.discard(req.request_id)
        pairs = self.allocator.swap_in(table)
        if self.swap_in_hook is not None:
            # engine copies host->device; nothing reads the fresh blocks
            # before its next compute, but copying now keeps the hook
            # symmetric with swap_out and the pages immediately coherent
            self.swap_in_hook(pairs)
        self.waiting.pop(0)
        # resume EXACTLY where the swap interrupted: a fully-prefilled
        # request re-enters decode (no chunks — the acceptance criterion),
        # a mid-prefill victim continues chunking from its preserved
        # prefilled_len via _plan_continuations
        req.phase = Phase.INCREMENT if req.prefilled_len >= req.prompt_len \
            else Phase.INITIATION
        req.last_planned_iter = self._iter_idx
        self.running.append(req)
        plan.swap_in.append((req, pairs))
        if self.trace is not None:
            self.trace.instant("sched", "swap_in", rid=req.request_id,
                               pages=len(pairs),
                               prefilled=req.prefilled_len,
                               generated=req.n_generated)
        return True

    def _abandon_swap(self, req: Request, table: BlockTable,
                      plan: IterationPlan) -> None:
        """Drop a host snapshot that can never be swapped back in and reset
        the request to recompute-from-scratch semantics (same bookkeeping
        as :meth:`_preempt`, but the request is already in ``waiting``)."""
        self._swap_hold.discard(req.request_id)
        req.phase = Phase.PREEMPTED
        req.preemptions += 1
        req.prompt = (req.prompt + req.output) if req.prompt else req.prompt
        req.prompt_len = req.context_len
        req.max_new_tokens -= req.n_generated
        req.committed_output.extend(req.output)
        req.output = []
        req.num_cached_tokens = 0
        req.prefilled_len = 0
        self.allocator.free_table(self.tables.pop(req.request_id))
        plan.preempted.append(req)  # the drop budget applies
        if self.trace is not None:
            self.trace.instant("sched", "preempt", rid=req.request_id,
                               trigger=req.request_id, kind="swap_abandon")

    def complete_iteration(self, plan: IterationPlan, now: float) -> List[Request]:
        """Mark phases + retire finished requests. Returns finished list."""
        finished = []
        tr = self.trace
        for req in plan.prefill:
            req.phase = Phase.INCREMENT
            if req.first_token_time is None:
                req.first_token_time = now
                if tr is not None:
                    tr.instant("req", "first_token", rid=req.request_id,
                               ts=now)
            # adopt the prompt's full pages into the radix tree as soon as
            # their KV exists — waiting for request completion would make
            # every member of a same-prefix burst recompute the shared
            # prefix (thundering herd). A leased request's local pages hold
            # only its suffix (the leading positions live on the creditor
            # instance), so there is nothing page-0-aligned to insert.
            if self.prefix_cache is not None and \
                    len(req.prompt) == req.prompt_len and \
                    req.request_id in self.tables and \
                    req.request_id not in self.leases:
                self.prefix_cache.insert(
                    req.prompt, self.tables[req.request_id].blocks)
        for req in plan.prefill + plan.decode:
            if req.done:
                self.finish(req, now)
                finished.append(req)
        # preemption budget: a request churning through recomputes is dropped
        # (reported as "preempted-dropped") instead of thrashing forever
        if self.max_preemptions is not None:
            for req in plan.preempted:
                # still in waiting = not re-admitted this very iteration
                if req.preemptions > self.max_preemptions and \
                        req in self.waiting:
                    self.waiting.remove(req)
                    self.finish(req, now, reason="preempted-dropped")
                    finished.append(req)
        return finished

    # -- disaggregated handoff ------------------------------------------------
    def release_request(self, req: Request) -> None:
        """Detach a prefill-complete request from this scheduler WITHOUT
        finishing it (the prefill side of a KV handoff). The caller must
        have secured the KV first — exported page payloads for a migration,
        or lent the blocks (increfs) for a zero-copy lease — because the
        local block table is freed here. The request's telemetry span stays
        open: it ends on the instance that finishes the decode."""
        lease = self.leases.pop(req.request_id, None)
        if lease is not None:  # repay any creditor before local frees
            lease.release()
        self._release_cache_path(req)
        table = self.tables.pop(req.request_id, None)
        if table is not None:
            self.allocator.free_table(table)
        if req in self.running:
            self.running.remove(req)
        self.decode_exempt.discard(req.request_id)

    def install_running(self, req: Request, table: BlockTable,
                        lease: Optional[object] = None) -> None:
        """Adopt a request mid-flight (the decode side of a KV handoff):
        its prompt KV already exists — in ``table``'s local pages (migrate)
        and/or on the creditor instance under ``lease`` (zero-copy). The
        request enters decode directly; no admission, no prefill."""
        req.phase = Phase.INCREMENT
        self.tables[req.request_id] = table
        if lease is not None:
            self.leases[req.request_id] = lease
        self.running.append(req)

    # -- best-of-n forks ------------------------------------------------------
    def fork_from(self, parent: Request, child: Request) -> BlockTable:
        """COW-fork ``child`` off ``parent`` right after the parent's
        prefill: every prompt page is shared (refcounted; the first write
        into a shared partial page triggers copy-on-write in
        ``append_tokens``) and the child enters decode directly — no second
        prefill. The caller samples the child's first token from the
        parent's prefill logits."""
        table = self.allocator.fork(self.tables[parent.request_id])
        self.tables[child.request_id] = table
        tr = self.trace
        if tr is not None:
            tr.begin("request", "req", child.request_id,
                     fork_of=parent.request_id,
                     prompt_len=parent.prompt_len,
                     max_new_tokens=child.max_new_tokens)
        lease = self.leases.get(parent.request_id)
        if lease is not None:
            # the sibling reads the same borrowed prefix: share the lease
            # (refcounted — the creditor is repaid when the last holder
            # releases)
            self.leases[child.request_id] = lease.acquire()
            if tr is not None:
                tr.instant("lease", "acquire", rid=child.request_id,
                           tokens=lease.num_tokens, shared=True)
        child.prompt = list(parent.prompt)
        child.prompt_len = parent.prompt_len
        child.num_cached_tokens = parent.prompt_len  # nothing recomputed
        child.prefilled_len = parent.prompt_len
        child.phase = Phase.INCREMENT
        self.running.append(child)
        return table

    # -- preemption ----------------------------------------------------------------
    def _preempt(self, req: Request) -> None:
        req.phase = Phase.PREEMPTED
        req.preemptions += 1
        # keep the victim's prefix-cache credit: its prefilled prompt pages
        # hold REAL computed KV, so adopt the full ones into the radix tree
        # BEFORE the table is freed. Re-admission then re-probes the tree
        # and the recompute covers only the uncached suffix — previously a
        # mid-prefill victim restarted chunking from token 0 even though
        # its completed chunks' pages were still sitting in memory.
        # (Decode-phase victims' prompt pages were already inserted at
        # prefill completion; insert() dedups.) Leased requests are
        # excluded — their leading pages live on the creditor — and so are
        # sim requests with immaterial prompts.
        if self.prefix_cache is not None and req.prefilled_len > 0 \
                and req.request_id not in self.leases \
                and len(req.prompt) == req.prompt_len:
            table = self.tables.get(req.request_id)
            if table is not None and not table.on_host:
                n = min(req.prefilled_len, table.num_tokens)
                self.prefix_cache.insert(req.prompt[:n], table.blocks)
        # recompute policy: drop pages; generated tokens move into the prompt
        req.prompt = (req.prompt + req.output) if req.prompt else req.prompt
        req.prompt_len = req.context_len
        req.max_new_tokens -= req.n_generated
        req.committed_output.extend(req.output)
        req.output = []
        req.num_cached_tokens = 0  # re-matched at the next admission
        req.prefilled_len = 0  # recompute restarts chunked prefill
        # debtor preemption: repay the creditor of a borrowed prefix BEFORE
        # freeing any local page (re-admission may take a fresh lease)
        lease = self.leases.pop(req.request_id, None)
        if lease is not None:
            lease.release()
            if self.trace is not None:
                self.trace.instant("lease", "release", rid=req.request_id,
                                   tokens=lease.num_tokens, cause="preempt")
        self._release_cache_path(req)
        self.allocator.free_table(self.tables.pop(req.request_id))
        if req in self.running:
            self.running.remove(req)
        self.waiting.insert(0, req)

    def _victim_cost(self, req: Request) -> float:
        """Raw eviction bill of ``req`` in seconds. A victim that would
        *swap* costs its PCIe round trip; one that would *sacrifice* costs
        the quadratic recompute of its context. ``victim_cost_fn``
        (sim/engine-wired) overrides the built-in mirror of the sim's
        CostModel/NetworkModel defaults."""
        table = self.tables[req.request_id]
        if self.victim_cost_fn is not None:
            return self.victim_cost_fn(req, table)
        n = len(table.blocks)
        ctx = min(req.prefilled_len, table.num_tokens) + req.n_generated
        if self._should_swap(req):
            from repro.core.distkv.netmodel import NetworkModel
            return 2.0 * NetworkModel().swap_time(n)
        # CostModel defaults: c_token * ctx + c_ctx * attention reads
        return 12e-6 * ctx + 18e-9 * (ctx * (ctx - 1) // 2)

    def _pick_from(self, cands: List[Request], needed: int = 1) -> Request:
        """Rank non-empty ``cands`` per ``victim_policy``. ``needed`` is
        the current page shortfall: the ``cost`` policy ranks by eviction
        seconds per page freed *toward that shortfall* — a small decode
        hole favors the victim with the cheapest absolute bill (evicting a
        giant frees pages nobody asked for), while a bulk shortfall
        amortizes a big victim's bill over everything it frees."""
        if self.victim_policy == "cost":
            return min(cands, key=lambda r: self._victim_cost(r) / max(
                1, min(len(self.tables[r.request_id].blocks), needed)))
        if self.victim_policy == "fifo":
            return cands[0]
        if self.victim_policy == "lru":
            return min(cands, key=lambda r: r.last_planned_iter)
        return cands[-1]  # lifo: youngest, least sunk work (vLLM default)

    def _pick_victim(self, exclude: Request,
                     needed: int = 1) -> Optional[Request]:
        """Choose who loses their device pages, per ``victim_policy``.

        Under ``swap_mode="auto"`` swap-worthiness is evaluated PER
        CANDIDATE before ranking: a candidate whose KV is worth moving
        (cheap PCIe vs expensive recompute) beats one that would have to
        sacrifice, whatever the positional order says — previously the
        policy locked in a victim first and only then asked whether
        swapping it was worthwhile, so auto could pick a must-recompute
        victim while a cheap-to-swap one sat right next to it."""
        cands = [r for r in self.running
                 if r is not exclude and r.request_id in self.tables]
        if not cands:
            return None
        if self.swap_mode == "auto":
            worthy = [r for r in cands if self._should_swap(r)]
            if worthy:
                cands = worthy
        return self._pick_from(cands, needed)

    def _evict_one(self, exclude: Request,
                   plan: IterationPlan) -> Optional[Request]:
        """Pick a victim, rescind its planned work, and take its device
        pages — by swap when the mode/decider says the KV is worth the PCIe
        round trip, by sacrifice (recompute) otherwise."""
        table = self.tables.get(exclude.request_id)
        needed = self.allocator.blocks_needed(table, 1) if table is not None \
            else 1
        victim = self._pick_victim(exclude, needed=max(1, needed))
        if victim is None:
            return None
        self._rescind(plan, victim)
        self._preempt_or_swap(victim, plan, trigger=exclude.request_id,
                              kind="victim")
        return victim

    def _should_swap(self, req: Request) -> bool:
        if self.swap_mode == "sacrifice" or \
                self.allocator.num_host_blocks == 0:
            return False
        if req.request_id in self.leases:
            # a leased prefix lives on the creditor — the local pages are
            # only the suffix, and the lease must be repaid now, so a host
            # snapshot could not be resumed coherently. Sacrifice.
            return False
        table = self.tables.get(req.request_id)
        if table is None or not self.allocator.can_swap_out(table):
            return False  # host tier full: degrade to sacrifice
        if self.swap_mode == "swap":
            return True
        computed = min(req.prefilled_len, table.num_tokens) + req.n_generated
        if self.swap_decider is not None:
            return self.swap_decider(req, len(table.blocks))
        return computed >= self.swap_min_tokens

    def _preempt_or_swap(self, req: Request, plan: IterationPlan, *,
                         trigger: int, kind: str) -> None:
        """Evict ``req``'s device pages. Swap: the KV moves to host pages
        and the request re-enters WAITING still holding its table (and its
        prefill/decode progress) — swap-in resumes exactly where it
        stopped, no recompute. Sacrifice: classic preempt-by-recompute."""
        tr = self.trace
        if self._should_swap(req):
            table = self.tables[req.request_id]
            # the locked radix path's pages stay device-resident for the
            # tree (swap_out only drops THIS table's refs); release the
            # pins so they become evictable while we are away
            self._release_cache_path(req)
            pairs = self.allocator.swap_out(table)
            if self.swap_out_hook is not None:
                # engine copies device->host NOW, before anything later in
                # this schedule() can reallocate-and-write the freed pages
                self.swap_out_hook(pairs)
            req.swaps += 1
            req.phase = Phase.WAITING
            self.running.remove(req)
            self.waiting.insert(0, req)
            plan.swap_out.append((req, pairs))
            if tr is not None:
                tr.instant("sched", "swap_out", rid=req.request_id,
                           pages=len(pairs), trigger=trigger, kind=kind)
        else:
            self._preempt(req)
            plan.preempted.append(req)
            if tr is not None:
                tr.instant("sched", "preempt", rid=req.request_id,
                           trigger=trigger, kind=kind)
