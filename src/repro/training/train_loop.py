"""Training loop: jitted step (loss + AdamW) with optional sharding policy."""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import Model, NO_POLICY
from repro.training import checkpoint, optimizer
from repro.training.data import DataConfig, SyntheticCorpus


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    log_every: int = 10
    ckpt_every: int = 0  # 0 = never
    ckpt_dir: str = "/tmp/repro_ckpt"
    opt: optimizer.OptConfig = dataclasses.field(
        default_factory=optimizer.OptConfig)


def make_train_step(model: Model, opt_cfg: optimizer.OptConfig,
                    policy=NO_POLICY) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, state, metrics).

    This is exactly the function the multi-pod dry-run lowers with
    ``in_shardings`` — one definition serves CPU smoke tests and the
    512-chip mesh."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, policy=policy))(params)
        params, opt_state, m = optimizer.apply(opt_cfg, params, grads,
                                               opt_state)
        return params, opt_state, {"loss": loss, **m}

    return train_step


def train(cfg: ArchConfig, tcfg: TrainConfig, *, seed: int = 0,
          batch_override: Optional[Dict] = None,
          verbose: bool = True) -> Dict[str, Any]:
    """End-to-end single-host training on the synthetic corpus."""
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = optimizer.init(params)
    step_fn = jax.jit(make_train_step(model, tcfg.opt))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                      global_batch=8, seed=seed)
    if batch_override:
        dcfg = dataclasses.replace(dcfg, **batch_override)
    corpus = SyntheticCorpus(dcfg)
    losses = []
    t0 = time.monotonic()
    for step, batch in enumerate(corpus.batches()):
        if step >= tcfg.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            if verbose:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}")
        if tcfg.ckpt_every and step and step % tcfg.ckpt_every == 0:
            checkpoint.save(tcfg.ckpt_dir, step,
                            {"params": params, "opt": opt_state})
    return {"losses": losses, "params": params,
            "wall_s": time.monotonic() - t0}
