"""Low-overhead structured event tracer for the serving stack.

The stack makes dozens of consequential decisions per iteration — chunk
composition, preemption victim choice, placement, borrow-vs-copy, board
eviction — and post-hoc aggregates (``ServiceStats``) cannot explain a P99
stall or a preemption storm. The :class:`Tracer` records those decisions as
**typed events in a ring buffer**, cheap enough to leave on in the
virtual-clock simulator and exportable to Chrome/Perfetto trace-event JSON
(``repro.core.telemetry.export``).

Design constraints:

* **No cost when off.** Tracing is opt-in per backend; when disabled the
  backend holds ``trace = None`` and every emission site is guarded with a
  single attribute test — no event object, argument dict, or string is ever
  constructed. ``tools/validate_trace.py --check-disabled-overhead``
  asserts this structurally (zero allocations attributed to this module).
* **Bounded memory.** Events land in a fixed-capacity ring; once full the
  oldest events are overwritten (``dropped`` counts them). Exporters see
  events in emission order.
* **Clock-agnostic.** Every event is stamped through the owner's clock:
  a virtual-clock backend passes its ``clock`` callable (sim traces are
  perfectly reproducible — no wall time anywhere), a wall-clock engine
  updates the ``now`` attribute at each ``step``. A cluster router merges
  per-child tracers onto one timeline by sorting on these timestamps.

Event vocabulary (``cat``/``name``; ``args`` carry cause attribution):

====================  =====================================================
``request``           per-request async span: ``begin`` at submission /
                      fork, ``end`` at finish or drop (``reason=...``)
``req``               lifecycle instants inside the span: ``chunk`` (one
                      planned prefill chunk: start/length/last),
                      ``chunk_rescind`` / ``decode_rescind`` (planned work
                      withdrawn from a preemption victim), ``first_token``
``sched``             scheduler decisions with *why*: ``admit`` (cached /
                      leased tokens, first chunk), ``refuse`` (``why`` in
                      budget_sliver | no_pages | solo_wait | swap_wait |
                      swap_hold), ``preempt`` (victim + ``trigger``
                      request + ``kind`` victim|self), ``cow_rescind``,
                      ``swap_out`` / ``swap_in`` (host-tier page moves;
                      a speculative swap-out's instant fires when the
                      transfer COMPLETES, ``kind=speculative``),
                      ``swap_issue`` / ``swap_cancel`` (overlapped
                      swap-out issued early / rescinded)
``swap``              overlapped-transfer async span: ``pending`` begun
                      at issue and ended at resolution with ``outcome``
                      complete | cancel | orphaned — the device pages are
                      DMA-in-flight for the whole span and the request
                      does no work inside it (``validate_swap_balance``)
``lease``             zero-copy lease lifecycle: ``lend`` / ``borrow``
                      (rManager sides), ``acquire`` / ``release``
                      (scheduler holds), ``repay`` (creditor settled)
``board``             publication board: ``publish`` / ``lookup`` /
                      ``evict``
``net``               modeled network charges: ``charge`` (seconds),
                      ``copy`` / ``lease`` RPCs, ``promote`` (leased
                      prefix materialized locally) (router)
``router``            ``place``: placement decision + policy
``handoff``           disaggregated prefill->decode KV move: async ``kv``
                      span per request, begun at the prefill host's clock
                      (``src``/``dst``/``mode``/``pages``) and ended at
                      the decode host's once the transfer is charged
``engine``            per-iteration ``iteration`` complete events (one
                      track per instance), engine ``chunk`` executions
====================  =====================================================
"""

from __future__ import annotations

from typing import Callable, Iterator, List, Optional

# Chrome trace-event phases used here: X=complete, i=instant,
# b/e=async span begin/end, C=counter, M=metadata (added by the exporter)
PH_COMPLETE = "X"
PH_INSTANT = "i"
PH_BEGIN = "b"
PH_END = "e"
PH_COUNTER = "C"


class Event:
    """One typed trace event. ``ts``/``dur`` are seconds on the emitting
    backend's clock; ``instance`` is the serving-instance track; ``rid``
    keys per-request async spans; ``it`` is the engine iteration the event
    belongs to (correlates scheduler decisions with their iteration)."""

    __slots__ = ("ts", "cat", "name", "ph", "instance", "rid", "it", "dur",
                 "args")

    def __init__(self, ts: float, cat: str, name: str, ph: str,
                 instance: int, rid: Optional[int], it: int,
                 dur: Optional[float], args: Optional[dict]):
        self.ts = ts
        self.cat = cat
        self.name = name
        self.ph = ph
        self.instance = instance
        self.rid = rid
        self.it = it
        self.dur = dur
        self.args = args

    def __repr__(self) -> str:  # debugging/test aid
        return (f"Event({self.ts:.6f}, {self.cat}.{self.name}, ph={self.ph},"
                f" inst={self.instance}, rid={self.rid}, it={self.it},"
                f" args={self.args})")


class Tracer:
    """Ring buffer of :class:`Event`. One per serving instance; a router
    assigns ``instance`` ids and merges buffers at export.

    ``clock``: callable returning the owner's current time in seconds
    (virtual clocks pass their own — sim traces never touch wall time).
    ``None`` means the owner updates :attr:`now` explicitly (wall-clock
    engines set it to the caller-supplied ``now`` each ``step``).
    ``iteration`` is likewise owner-updated per step so every event carries
    the iteration it belongs to.
    """

    def __init__(self, capacity: int = 131_072, *,
                 clock: Optional[Callable[[], float]] = None,
                 instance: int = 0):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.clock = clock
        self.instance = instance
        self.now = 0.0
        self.iteration = 0
        self._buf: List[Event] = []
        self._head = 0  # next overwrite slot once the ring is full
        self.dropped = 0
        self.emitted = 0

    # -- emission ---------------------------------------------------------------

    def _ts(self, ts: Optional[float]) -> float:
        if ts is not None:
            return ts
        return self.clock() if self.clock is not None else self.now

    def _push(self, ev: Event) -> None:
        self.emitted += 1
        if len(self._buf) < self.capacity:
            self._buf.append(ev)
        else:
            self._buf[self._head] = ev
            self._head = (self._head + 1) % self.capacity
            self.dropped += 1

    def instant(self, cat: str, name: str, *, rid: Optional[int] = None,
                ts: Optional[float] = None, **args) -> None:
        """A point-in-time event (scheduler decision, lease transition)."""
        self._push(Event(self._ts(ts), cat, name, PH_INSTANT, self.instance,
                         rid, self.iteration, None, args or None))

    def complete(self, cat: str, name: str, *, dur: float,
                 rid: Optional[int] = None, ts: Optional[float] = None,
                 **args) -> None:
        """A duration slice on the instance track (``ts`` is the start)."""
        self._push(Event(self._ts(ts), cat, name, PH_COMPLETE, self.instance,
                         rid, self.iteration, dur, args or None))

    def begin(self, cat: str, name: str, rid: int, *,
              ts: Optional[float] = None, **args) -> None:
        """Open a per-request async span (closed by :meth:`end`)."""
        self._push(Event(self._ts(ts), cat, name, PH_BEGIN, self.instance,
                         rid, self.iteration, None, args or None))

    def end(self, cat: str, name: str, rid: int, *,
            ts: Optional[float] = None, **args) -> None:
        self._push(Event(self._ts(ts), cat, name, PH_END, self.instance,
                         rid, self.iteration, None, args or None))

    def counter(self, name: str, *, ts: Optional[float] = None,
                **values) -> None:
        """A counter-track sample (rendered as stacked area in Perfetto)."""
        self._push(Event(self._ts(ts), "metrics", name, PH_COUNTER,
                         self.instance, None, self.iteration, None, values))

    # -- access -----------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._buf)

    def events(self) -> List[Event]:
        """Events in emission order (oldest first, ring unwound)."""
        return self._buf[self._head:] + self._buf[:self._head]

    def __iter__(self) -> Iterator[Event]:
        return iter(self.events())


def merge_events(tracers) -> List[Event]:
    """Merge several tracers' buffers onto one timeline, ordered by
    timestamp (ties keep per-tracer emission order — Python's sort is
    stable). The router uses this to splice child instances' traces."""
    evs: List[Event] = []
    for t in tracers:
        if t is not None:
            evs.extend(t.events())
    evs.sort(key=lambda e: e.ts)
    return evs
