"""The paper's novel contribution (§II.B): "Latency-Throughput-Tradeoff"
chain selection via NSGA-II.

Chromosome (exactly as §II.B.2): a binary matrix, rows = servers, columns =
model blocks; entry (s, b) = 1 means server s is used for block b. Objectives
(§II.B.4): minimize the sum of latencies and maximize the sum of throughputs
across all blocks; constraint: every block assigned to >=1 hosting server.

``decode_chain`` turns a feasible matrix into an executable chain (per block,
the assigned hosting server with the highest throughput; consecutive equal
servers merge into spans), which gives the *realized* latency/throughput used
by the comparison benchmark — the experiment the paper itself could not run
(§II.B.5) for lack of a private swarm.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.chain.nsga2 import nsga2 as _run_nsga2
from repro.core.chain.baseline import Chain
from repro.core.chain.registry import Fleet, ServerInfo


@dataclasses.dataclass
class ChainSequenceProblem:
    """pymoo-style Problem (the paper used pymoo's ``Problem``; we implement
    the same interface against our own NSGA-II).

    ``objectives``:

    * ``"paper"``    — exactly §II.B.4: minimize the *sum of latencies* and
      maximize the *sum of throughputs* over all engaged (server, block)
      assignments. Our benchmark shows these reward engaging many servers
      and produce chains dominated by the Dijkstra baseline on realized
      metrics — a finding about the paper's objective design.
    * ``"realized"`` — beyond-paper fix: minimize the *decoded chain's*
      end-to-end time and maximize its *bottleneck throughput* (what a
      client actually experiences). Same chromosome, same operators.
    """

    fleet: Fleet
    objectives: str = "paper"

    def __post_init__(self):
        self.n_servers = len(self.fleet.servers)
        self.n_blocks = self.fleet.num_blocks
        self.n_var = self.n_servers * self.n_blocks
        # hosting mask: H[s, b] = server s hosts block b
        self.hosts = np.zeros((self.n_servers, self.n_blocks), bool)
        for i, s in enumerate(self.fleet.servers):
            self.hosts[i, s.start_block:s.end_block] = True
        self.lat = np.array([s.latency for s in self.fleet.servers])
        self.thr = np.array([s.throughput for s in self.fleet.servers])

    def evaluate(self, x: np.ndarray) -> Tuple[np.ndarray, float]:
        m = x.reshape(self.n_servers, self.n_blocks) & self.hosts
        # constraint: every block covered by at least one valid server
        uncovered = int(self.n_blocks - m.any(axis=0).sum())
        # discourage dead bits (assignments to non-hosted blocks)
        dead = int((x.reshape(self.n_servers, self.n_blocks) & ~self.hosts).sum())
        cv = float(uncovered) + 0.001 * dead
        if self.objectives == "realized":
            chain = decode_chain(self, x) if uncovered == 0 else None
            if chain is None:
                return np.array([1e9, 1e9]), max(cv, 1.0)
            return np.array([chain.total_time,
                             -chain.bottleneck_throughput]), cv
        # paper objectives (§II.B.4)
        f0 = float((m * self.lat[:, None]).sum())
        f1 = -float((m * self.thr[:, None]).sum())
        return np.array([f0, f1]), cv

    def chain_to_x(self, chain: Chain) -> np.ndarray:
        """Encode an executable chain as a chromosome (for memetic seeding)."""
        m = np.zeros((self.n_servers, self.n_blocks), np.int8)
        for s, a, b in chain:
            m[s.server_id, a:b] = 1
        return m.reshape(-1)

    def seeded_init(self, rng: np.random.Generator) -> np.ndarray:
        """Random column-wise covering assignment (keeps the initial
        population feasible, as pymoo users typically seed)."""
        m = np.zeros((self.n_servers, self.n_blocks), np.int8)
        for b in range(self.n_blocks):
            cands = np.flatnonzero(self.hosts[:, b])
            m[rng.choice(cands), b] = 1
        # sprinkle extra redundancy
        extra = (rng.random(m.shape) < 0.05) & self.hosts
        return (m | extra).reshape(-1).astype(np.int8)


def decode_chain(problem: ChainSequenceProblem, x: np.ndarray) -> Optional[Chain]:
    """Feasible matrix -> executable chain (per-block fastest assigned server,
    merged into consecutive spans)."""
    m = x.reshape(problem.n_servers, problem.n_blocks) & problem.hosts
    if not m.any(axis=0).all():
        return None
    servers = problem.fleet.servers
    pick: List[ServerInfo] = []
    for b in range(problem.n_blocks):
        cands = np.flatnonzero(m[:, b])
        pick.append(servers[cands[np.argmax(problem.thr[cands])]])
    chain = Chain()
    start = 0
    for b in range(1, problem.n_blocks + 1):
        if b == problem.n_blocks or pick[b].server_id != pick[start].server_id:
            chain.append((pick[start], start, b))
            start = b
    return chain


@dataclasses.dataclass
class TradeoffResult:
    pareto_front: np.ndarray  # (n, 2) [latency, -throughput]
    chains: List[Chain]
    evaluations: int


def latency_throughput_tradeoff(
    fleet: Fleet, *, pop_size: int = 100, generations: int = 60,
    seed: int = 0, objectives: str = "paper",
    memetic_seed: bool = False) -> TradeoffResult:
    """The paper's new PETALS mode. Returns the Pareto set of chains.

    ``memetic_seed`` (beyond-paper): inject the Dijkstra min-latency and
    max-throughput chains into the initial population — NSGA-II elitism then
    guarantees the final front dominates both single-objective baselines and
    the GA explores the middle of the tradeoff curve."""
    from repro.core.chain.baseline import find_best_chain
    prob = ChainSequenceProblem(fleet, objectives=objectives)
    seeds_x = []
    if memetic_seed:
        for mode in ("min_latency", "max_throughput"):
            c = find_best_chain(fleet, mode=mode)
            if c is not None:
                seeds_x.append(prob.chain_to_x(c))
    counter = {"i": 0}

    def init(rng):
        i = counter["i"]
        counter["i"] += 1
        if i < len(seeds_x):
            return seeds_x[i].copy()
        return prob.seeded_init(rng)

    res = _run_nsga2(prob.evaluate, prob.n_var, pop_size=pop_size,
                     generations=generations, seed=seed, init=init)
    chains, front = [], []
    for ind in res.pareto:
        c = decode_chain(prob, ind.x)
        if c is not None:
            chains.append(c)
            front.append(ind.f)
    return TradeoffResult(
        pareto_front=np.array(front).reshape(-1, 2),
        chains=chains, evaluations=res.evaluations)


def knee_chain(result: TradeoffResult) -> Optional[Chain]:
    """Pick the knee of the Pareto front (max distance to the extremes'
    chord) — a sensible single default for clients."""
    if not result.chains:
        return None
    f = result.pareto_front.astype(float)
    f = (f - f.min(0)) / np.maximum(f.max(0) - f.min(0), 1e-12)
    a, b = f[np.argmin(f[:, 0])], f[np.argmin(f[:, 1])]
    ab = b - a
    denom = np.linalg.norm(ab) + 1e-12
    fa = f - a
    d = np.abs(ab[0] * fa[:, 1] - ab[1] * fa[:, 0]) / denom  # 2-D cross
    return result.chains[int(np.argmax(d))]
