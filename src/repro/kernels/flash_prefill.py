"""Causal flash-attention prefill kernel (+ sliding window), TPU Pallas.

Grid ``(B, Hkv, nq, nk)`` with the KV axis sequential ("arbitrary") so the
online-softmax scratch accumulator carries across KV blocks of one query
block. Blocks are MXU-aligned where the head_dim allows (q/k blocks default
128x128 tiles). GQA is handled by blocking G query heads of the same KV group
together — one KV DMA serves all G query heads.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  q_block: int, kv_block: int, nk: int,
                  window: Optional[int], causal: bool, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, q_block, Dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (kv_block, Dh)
    v = v_ref[0, 0].astype(jnp.float32)

    qpos = iq * q_block + jax.lax.iota(jnp.int32, q_block)
    kpos = ik * kv_block + jax.lax.iota(jnp.int32, kv_block)
    mask = jnp.ones((q_block, kv_block), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window

    s = jnp.einsum("gqd,kd->gqk", q, k) * scale
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask[None], p, 0.0)
    l_new = l_prev * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "gqk,kd->gqd", p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-9)[..., None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"))
def flash_prefill(
    q,  # (B, S, H, Dh)
    k,  # (B, Skv, Hkv, Dh)
    v,  # (B, Skv, Hkv, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = True,
):
    b, s, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q_block = min(q_block, s)
    kv_block = min(kv_block, skv)
    assert s % q_block == 0 and skv % kv_block == 0
    nq, nk = s // q_block, skv // kv_block
    scale = 1.0 / (dh ** 0.5)

    # (B, Hkv, G, S, Dh) so one KV block serves all G grouped query heads
    qg = q.reshape(b, s, hkv, g, dh).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, Skv, Dh)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, q_block=q_block, kv_block=kv_block, nk=nk,
        window=window, causal=causal, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, q_block, dh),
                         lambda bb, hh, iq, ik: (bb, hh, 0, iq, 0)),
            pl.BlockSpec((1, 1, kv_block, dh),
                         lambda bb, hh, iq, ik: (bb, hh, ik, 0)),
            pl.BlockSpec((1, 1, kv_block, dh),
                         lambda bb, hh, iq, ik: (bb, hh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, q_block, dh),
                               lambda bb, hh, iq, ik: (bb, hh, 0, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, q_block), jnp.float32),
            pltpu.VMEM((g, q_block), jnp.float32),
            pltpu.VMEM((g, q_block, dh), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, s, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)
