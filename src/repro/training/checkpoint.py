"""Msgpack pytree checkpointing (orbax is unavailable offline).

Layout: ``<dir>/step_<n>/ckpt.msgpack`` with a tiny manifest. Arrays are
stored as (dtype, shape, raw bytes); bfloat16 round-trips via uint16 views.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return {"dt": "bfloat16", "shape": list(x.shape),
                "data": x.view(np.uint16).tobytes()}
    return {"dt": x.dtype.str, "shape": list(x.shape), "data": x.tobytes()}


def _unpack_leaf(d):
    if d["dt"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["data"], np.dtype(d["dt"]))
                       .reshape(d["shape"]))


def save(path: str, step: int, tree: Any) -> str:
    d = os.path.join(path, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    payload = msgpack.packb({"leaves": [_pack_leaf(l) for l in leaves]},
                            use_bin_type=True)
    with open(os.path.join(d, "ckpt.msgpack"), "wb") as f:
        f.write(payload)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves)}, f)
    return d


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(path)
             if n.startswith("step_")]
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any) -> Any:
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "ckpt.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = [_unpack_leaf(l) for l in payload["leaves"]]
    _, treedef = jax.tree.flatten(like)
    return treedef.unflatten(leaves)
