"""ORCA iteration-level scheduling + vLLM paging on a real model: requests
arrive over time, join mid-flight, finish early, and (with tight memory)
get preempted and recomputed — watch the engine iterate.

  PYTHONPATH=src python examples/serve_continuous_batching.py
"""

import numpy as np

import jax

from repro.configs import smoke_config
from repro.core.scheduling.request import Request
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine


def main():
    cfg = smoke_config("paper-opt-13b") if False else smoke_config(
        "h2o-danube-1.8b")
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=48, page_size=8, max_slots=3,  # tight: shows preemption
        max_tokens_per_iter=256))

    rng = np.random.default_rng(7)
    reqs = []
    for i in range(8):
        plen = int(rng.integers(6, 20))
        reqs.append(Request(i, arrival_time=i * 0.5,
                            prompt=rng.integers(2, cfg.vocab_size,
                                                plen).tolist(),
                            max_new_tokens=int(rng.integers(4, 16))))

    it, injected = 0, 0
    while injected < len(reqs) or eng.scheduler.waiting or \
            eng.scheduler.running:
        # inject arrivals: 2 iterations ~ 1 "second"
        while injected < len(reqs) and reqs[injected].arrival_time <= it / 2:
            eng.add_request(reqs[injected])
            print(f"[iter {it:3d}] + request {injected} arrives "
                  f"(prompt {reqs[injected].prompt_len} tok, "
                  f"wants {reqs[injected].max_new_tokens})")
            injected += 1
        finished = eng.step(now=float(it))
        for r in finished:
            print(f"[iter {it:3d}] - request {r.request_id} done: "
                  f"{r.total_generated} tokens, "
                  f"{r.preemptions} preemptions")
        it += 1
        if it > 500:
            break
    print(f"\n{it} iterations, kv pages free "
          f"{eng.allocator.num_free}/{eng.allocator.num_blocks}")


if __name__ == "__main__":
    main()
