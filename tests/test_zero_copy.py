"""Zero-copy remote prefix serving over borrowed rBlocks.

Covers the PR's acceptance criteria and satellites: RManager lending of
existing pages + repay-before-free ordering (a creditor never leaks a lent
block, including on debtor preemption), board block ids with pin/unpin,
scheduler admission with a RemoteLease (suffix-only local pages, lease
lifecycle across finish/preempt/fork), the prefill_first decode-page
reserve, pow2 chunk-shape bucketing (compile-counter), and the token
identity of instance B's decode when its prefix KV is served from instance
A's pages through the DistAttention partial merge — vs the fp32 oracle and
vs copy-mode adoption."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distkv import (GManager, NetworkModel, RManager, RemoteLease)
from repro.core.distkv.prefixshare import PrefixShareBoard
from repro.core.paging import BlockAllocator
from repro.core.prefixcache import PrefixCache
from repro.core.scheduling import IterationScheduler, Phase, Request
from repro.serving.simulator import (SimBackend, make_shared_prefix_workload,
                                     simulate_router)

PS = 8  # page size for the engine tests


def _cluster(n=2, blocks=8, bs=16, **g_kw):
    g = GManager(n, **g_kw)
    rms = {i: RManager(i, BlockAllocator(blocks, bs), g) for i in range(n)}
    for r in rms.values():
        r.register_peers(rms)
    return g, rms


# -- NetworkModel ---------------------------------------------------------------

def test_netmodel_copy_vs_borrow_decision():
    net = NetworkModel()
    # short decodes over a hot prefix: the one-time payload copy never pays
    # itself off -> borrow; very long decodes amortize it -> copy
    assert net.prefer_borrow(32, 16, est_decode_tokens=16)
    assert not net.prefer_borrow(32, 16, est_decode_tokens=50_000)
    # monotone in decode length
    costs = [net.borrow_lifetime_cost(8, 16, t) for t in (1, 64, 4096)]
    assert costs == sorted(costs)
    assert net.page_copy_time(4) == pytest.approx(2 * net.page_copy_time(2))


# -- RManager: lending existing pages -------------------------------------------

def test_lend_and_release_existing_pages():
    g, rms = _cluster()
    b = rms[1].allocator.alloc_block()  # stands in for a cached page
    lease = rms[0].borrow_blocks(1, [b])
    assert rms[1].allocator.refcount_of(b) == 2  # owner + lease
    assert g.lent_by(1) == 1 and g.borrowed_by(0) == 1
    assert lease.num_tokens == rms[0].allocator.block_size
    lease.release()
    assert rms[1].allocator.refcount_of(b) == 1
    assert g.lent_by(1) == 0
    lease.release()  # idempotent past zero: no double repay
    assert rms[1].allocator.refcount_of(b) == 1


def test_lease_refcount_shares_across_holders():
    g, rms = _cluster()
    b = rms[1].allocator.alloc_block()
    lease = rms[0].borrow_blocks(1, [b])
    lease.acquire()  # a COW-forked sibling
    lease.release()
    assert g.lent_by(1) == 1, "creditor repaid only by the LAST holder"
    lease.release()
    assert g.lent_by(1) == 0
    with pytest.raises(ValueError):
        lease.acquire()  # released leases cannot be revived


def test_lend_free_block_raises_before_ledger():
    g, rms = _cluster()
    with pytest.raises(ValueError, match="lend"):
        rms[1].lend_blocks(0, [3])  # never allocated
    assert not g.ledger, "a failed lend must not touch the debt ledger"
    with pytest.raises(ValueError):
        rms[0].borrow_blocks(0, [0])  # borrowing from oneself


def test_free_seq_repays_creditors_before_local_frees():
    """AUDIT (satellite): a fault in the debtor's local teardown (e.g. a
    double-free surfacing mid-loop) must not strand the creditor's lent
    block — remote repayments run first."""
    g, rms = _cluster(blocks=4)
    rms[0].append_tokens(7, 16 * 5)  # 4 local + 1 borrowed
    assert g.borrowed_by(0) == 1
    kv = rms[0].seqs[7]
    local = next(rb for rb in kv.rblocks if rb.device_id == 0)
    rms[0].allocator.decref(local.physical_id)  # corrupt: premature free
    with pytest.raises(ValueError):
        rms[0].free_seq(7)
    # the local teardown faulted, but the creditor was already repaid
    assert g.borrowed_by(0) == 0
    assert all(rm.allocator.refcount_of(rb.physical_id) == 0
               for rm in rms.values() for rb in kv.rblocks
               if rb.device_id == 1)


# -- publication board: lendable blocks + pins -----------------------------------

def test_board_blocks_pin_and_evict_unpin():
    events = []
    board = PrefixShareBoard(max_pages=2)
    board.on_pin = lambda h, b: events.append(("pin", h, b))
    board.on_unpin = lambda h, b: events.append(("unpin", h, b))
    a = list(range(16))
    board.publish(0, a, [None, None], 8, blocks=[5, 6])
    assert events == [("pin", 0, 5), ("pin", 0, 6)]
    hit = board.match(a)
    assert [p.block for p in hit] == [5, 6] and all(p.home == 0 for p in hit)
    events.clear()
    board.publish(1, list(range(100, 116)), [None, None], 8, blocks=[7, 8])
    # over the cap: path a ages out tail-first, returning its pins
    assert ("unpin", 0, 6) in events and ("unpin", 0, 5) in events
    assert board.num_pages == 2


def test_board_payload_upgrade_moves_the_pin():
    """A sim's bookkeeping publication later upgraded by an engine with real
    payloads: the lendable block must follow the payload home — the old
    lender's pin is returned, the new home's page is pinned."""
    events = []
    board = PrefixShareBoard()
    board.on_pin = lambda h, b: events.append(("pin", h, b))
    board.on_unpin = lambda h, b: events.append(("unpin", h, b))
    toks = list(range(8))
    board.publish(0, toks, [None], 8, blocks=[3])
    board.publish(1, toks, ["real-kv"], 8, blocks=[9])
    assert events == [("pin", 0, 3), ("unpin", 0, 3), ("pin", 1, 9)]
    page = board.match(toks)[0]
    assert page.home == 1 and page.block == 9 and page.payload == "real-kv"


# -- scheduler: lease admission lifecycle ----------------------------------------

def _lease(tokens, ps=PS, home=1, released=None):
    blocks = list(range(100, 100 + tokens // ps))
    rel = released if released is not None else []
    return RemoteLease(home=home, debtor=0, blocks=blocks, page_size=ps,
                       _release=lambda l: rel.append(l)), rel


def test_scheduler_admits_with_lease_suffix_only():
    a = BlockAllocator(16, PS)
    pc = PrefixCache(a)
    lease, released = _lease(16)
    offered = []

    def adopter(req, local_tokens):
        offered.append((req.request_id, local_tokens))
        return lease

    s = IterationScheduler(a, prefix_cache=pc, max_tokens_per_iter=999,
                           remote_adopter=adopter)
    r = Request(0, 0.0, list(range(24)), max_new_tokens=2)
    s.add_request(r)
    plan = s.schedule()
    assert offered == [(0, 0)]
    # the borrowed 16 tokens are NOT recomputed and hold NO local pages:
    # only the 8-token suffix is local, prefilled at an absolute start of 16
    assert [(c.start, c.length) for c in plan.chunks] == [(16, 8)]
    assert r.num_cached_tokens == 16
    assert s.remote_tokens_of(0) == 16
    table = s.tables[0]
    assert len(table.blocks) == 1 and table.num_tokens == 8
    r.output.append(0)
    s.complete_iteration(plan, 0.0)
    # the leased prompt must NOT enter the local radix tree (its leading
    # pages live on the creditor — there is no page-0-aligned path here)
    assert pc.match(r.prompt) == []
    while r.phase != Phase.FINISHED:
        plan = s.schedule()
        for x in plan.prefill + plan.decode:
            x.output.append(0)
        s.complete_iteration(plan, 1.0)
    assert released == [lease], "finish must repay the creditor"
    assert 0 not in s.leases
    pc.clear()
    assert a.num_free == 16 and not a.refcount


def test_scheduler_preemption_releases_lease_then_releases():
    """Debtor preemption: the lease is repaid BEFORE local pages are freed,
    and recompute starts over (a fresh lease may be granted on
    re-admission)."""
    a = BlockAllocator(16, PS)
    pc = PrefixCache(a)
    grants = []

    def adopter(req, local_tokens):
        lease, rel = _lease(16)
        grants.append((lease, rel))
        return lease

    s = IterationScheduler(a, prefix_cache=pc, max_tokens_per_iter=999,
                           remote_adopter=adopter)
    r = Request(0, 0.0, list(range(24)), max_new_tokens=8)
    s.add_request(r)
    s.complete_iteration(s.schedule(), 0.0)
    assert len(grants) == 1
    s._preempt(r)
    assert grants[0][1] == [grants[0][0]], "preemption must repay"
    assert 0 not in s.leases and 0 not in s.tables
    assert a.num_free == 16
    plan = s.schedule()  # re-admission takes a fresh lease
    assert len(grants) == 2 and s.remote_tokens_of(0) == 16
    assert [(c.start, c.length) for c in plan.chunks] == [(16, 8)]


def test_scheduler_releases_shorter_lease_and_uses_local_match():
    """A lease no longer than the local radix match is useless: it must be
    released immediately and the local path used instead."""
    a = BlockAllocator(16, PS)
    pc = PrefixCache(a)
    s = IterationScheduler(a, prefix_cache=pc, max_tokens_per_iter=999)
    warm = Request(0, 0.0, list(range(24)), max_new_tokens=1)
    s.add_request(warm)
    s.complete_iteration(s.schedule(), 0.0)
    while warm.phase != Phase.FINISHED:
        plan = s.schedule()
        for x in plan.prefill + plan.decode:
            x.output.append(0)
        s.complete_iteration(plan, 1.0)
    lease, released = _lease(16)  # local tree already matches 16 tokens
    s.remote_adopter = lambda req, local: lease
    r = Request(1, 0.0, list(range(24)), max_new_tokens=1)
    s.add_request(r)
    s.schedule()
    assert released == [lease]
    assert 1 not in s.leases
    # served by the LOCAL pages (2 full pages + a token-level partial hit)
    assert r.num_cached_tokens >= 16


# -- prefill_first decode-page reserve (satellite) -------------------------------

def _crunch_scheduler(decode_reserve):
    """The PR-4 crunch: two decoders about to cross a page boundary while a
    token-level-hit admission wants the last free pages."""
    a = BlockAllocator(10, PS)
    c = PrefixCache(a)
    s = IterationScheduler(a, prefix_cache=c, max_tokens_per_iter=8192,
                           chunk_policy="prefill_first",
                           decode_reserve=decode_reserve)
    r0 = Request(0, 0.0, list(range(24)), max_new_tokens=2)
    s.add_request(r0)
    it = 0.0
    while r0.phase != Phase.FINISHED:
        plan = s.schedule()
        for x in plan.prefill + plan.decode:
            x.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    r1 = Request(1, 0.0, list(range(1000, 1006)), max_new_tokens=20)
    r3 = Request(3, 0.0, list(range(2000, 2006)), max_new_tokens=20)
    s.add_request(r1)
    s.add_request(r3)
    while True:
        plan = s.schedule()
        for x in plan.prefill + plan.decode:
            x.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
        if s.tables[1].num_tokens >= 16:
            break
    r2 = Request(2, 0.0, list(range(20)) + [777] * 8, max_new_tokens=2)
    s.add_request(r2)
    return s, (r1, r2, r3), it


def test_prefill_first_decode_reserve_prevents_admit_then_preempt():
    """REGRESSION (satellite): under prefill_first, admission-before-decode
    used to admit a request that the same iteration's decode growth then
    preempted. The decode-page reserve defers the admission instead: no
    preemption, the decodes get their pages, and the request is admitted on
    a later iteration once pages free up."""
    s, (r1, r2, r3), it = _crunch_scheduler(decode_reserve=True)
    plan = s.schedule()
    assert r2 in s.waiting and r2 not in plan.preempted
    assert not plan.preempted, "the reserve must prevent the preemption"
    assert r1 in plan.decode and r3 in plan.decode
    for x in plan.prefill + plan.decode:
        x.output.append(0)
    s.complete_iteration(plan, it)
    # everything still completes (r2 admitted once pages free up)
    for k in range(200):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            break
        for x in plan.prefill + plan.decode:
            x.output.append(0)
        s.complete_iteration(plan, it + 1 + k)
    assert all(r.phase == Phase.FINISHED for r in (r1, r2, r3))
    assert r2.preemptions == 0


def test_crunch_without_reserve_still_preempts():
    """Control: decode_reserve=False reproduces the PR-4 behavior the
    reserve fixes (same engineered crunch, admission then preemption)."""
    s, (r1, r2, r3), it = _crunch_scheduler(decode_reserve=False)
    plan = s.schedule()
    assert r2 in plan.preempted and r2 in s.waiting


# -- sim cluster end-to-end ------------------------------------------------------

def _wl(n=40, out_len=16, seed=3):
    return make_shared_prefix_workload(n, rate=100.0, n_groups=2,
                                       prefix_len=64, suffix_len=16,
                                       out_len=out_len, seed=seed,
                                       group_draw="random")


def test_sim_cluster_zero_copy_end_to_end():
    res = simulate_router(_wl(), n_instances=3, policy="round_robin",
                          prefix_share=True, share_mode="zero_copy",
                          blocks_per_instance=128, block_size=16,
                          net=NetworkModel())
    assert res.completed_frac == 1.0
    assert res.borrowed_pages > 0, "zero_copy must actually borrow"
    assert res.adopted_pages == 0, "zero_copy must never copy payloads"
    assert res.prefix_hit_rate is not None and res.prefix_hit_rate > 0
    # every lease repaid: no outstanding debt anywhere after the drain
    for row in res.per_instance.values():
        assert row["lent_pages"] == 0 and row["borrowed_pages"] == 0


def test_sim_cluster_copy_vs_zero_copy_same_tokens():
    """share_mode must not change WHAT is generated, only how the prefix
    KV travels (the sim emits one token per granted iteration either way)."""
    a = simulate_router(_wl(), n_instances=2, prefix_share=True,
                        share_mode="copy", blocks_per_instance=128,
                        block_size=16)
    b = simulate_router(_wl(), n_instances=2, prefix_share=True,
                        share_mode="zero_copy", blocks_per_instance=128,
                        block_size=16)
    for ra, rb in zip(a.requests, b.requests):
        assert ra.total_generated == rb.total_generated


def test_sim_auto_mode_follows_network_model():
    """auto: short decodes borrow (the copy never pays itself off within
    the request), very long decodes copy."""
    short = simulate_router(_wl(out_len=8), n_instances=2, prefix_share=True,
                            share_mode="auto", blocks_per_instance=512,
                            block_size=16, net=NetworkModel())
    assert short.borrowed_pages > 0 and short.adopted_pages == 0
    long_ = simulate_router(_wl(n=16, out_len=2500), n_instances=2,
                            prefix_share=True, share_mode="auto",
                            blocks_per_instance=512, block_size=16,
                            max_tokens_per_iter=16384, net=NetworkModel())
    assert long_.adopted_pages > 0 and long_.borrowed_pages == 0


def test_router_share_mode_validation():
    from repro.serving.router import RouterBackend
    children = [SimBackend(num_blocks=32, block_size=8, prefix_cache=True)
                for _ in range(2)]
    with pytest.raises(ValueError, match="share_mode"):
        RouterBackend(children, prefix_share=True, share_mode="rdma")
    with pytest.raises(ValueError, match="prefix_share"):
        RouterBackend(children, share_mode="zero_copy")


# -- engine: chunk-shape bucketing (satellite) -----------------------------------

def _fresh_engine(cfg, params, **kw):
    from repro.serving.engine import EngineConfig, PagedEngine
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PS)
    kw.setdefault("max_slots", 4)
    return PagedEngine(cfg, params, EngineConfig(**kw))


@pytest.fixture(scope="module")
def model_setup():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None, logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_chunk_compile_count_is_logarithmic(model_setup):
    """REGRESSION (satellite): _prefill_chunk_fn retraced per
    (chunk_len, n_pages) shape pair; with pow2 bucketing a mixed-length
    workload compiles O(log) variants, not one per distinct length."""
    from repro.serving.engine import PagedEngine
    cfg, model, params = model_setup
    eng = _fresh_engine(cfg, params)
    fn = PagedEngine._prefill_chunk_fn
    before = fn._cache_size()
    rng = np.random.default_rng(5)
    lengths = [9, 11, 13, 21, 27, 37, 45, 53, 61, 63]
    for i, n in enumerate(lengths):
        r = Request(i, 0.0, rng.integers(0, cfg.vocab_size, n).tolist(),
                    max_new_tokens=1)
        eng.add_request(r)
        eng.run_to_completion()
    traced = fn._cache_size() - before
    # 10 distinct lengths bucket to s_pad in {16, 32, 64} (pages follow):
    # far fewer compiles than the 10 the unbucketed shapes would cost
    assert traced <= 4, f"{traced} chunk variants compiled for " \
        f"{len(set(lengths))} distinct chunk lengths"


def test_bucketed_chunk_token_identity(model_setup):
    """Padding + masking must be a pure compile-time optimization: odd,
    unaligned prompt lengths decode identically to the fp32 oracle path
    (covers the pad-scatter/trash-page and last-real-position logits)."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(6)
    eng = _fresh_engine(cfg, params)
    for i, n in enumerate((7, 19, 33)):
        prompt = rng.integers(0, cfg.vocab_size, n).tolist()
        r = Request(i, 0.0, list(prompt), max_new_tokens=3)
        eng.add_request(r)
        eng.run_to_completion()
        assert r.full_output == _oracle(model, params, prompt, 3), \
            f"prompt len {n}"


# -- engine: zero-copy token identity (ACCEPTANCE) -------------------------------

class ScriptedPolicy:
    def __init__(self, script):
        self.script = list(script)
        self._i = 0

    def choose(self, req, children):
        i = self.script[self._i]
        self._i += 1
        return i


def _oracle(model, params, prompt, n):
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def _run_cluster(cfg, params, mode, prompts, n_new=3):
    from repro.serving.router import RouterBackend
    engines = [_fresh_engine(cfg, params, enable_prefix_cache=True)
               for _ in range(2)]
    router = RouterBackend(engines, policy=ScriptedPolicy([0] * (len(prompts)
                                                                 - 1) + [1]),
                           prefix_share=True, share_mode=mode,
                           hot_threshold=1)
    reqs = [Request(i, 0.0, list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        router.add_request(r)
        while router.has_work:
            router.step()
    return router, engines, reqs


def test_engine_zero_copy_token_identity(model_setup):
    """ACCEPTANCE: instance B admits with borrowed rBlocks — its prefix KV
    stays in instance A's physical pages and is served through the
    DistAttention (o, m, l) merge in both the suffix prefill and every
    decode step — and B's output is token-identical to the fp32 oracle AND
    to copy-mode adoption. No payload is ever copied."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(12)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab_size, 4).tolist()
               for _ in range(3)]

    router_z, engines_z, reqs_z = _run_cluster(cfg, params, "zero_copy",
                                               prompts)
    assert reqs_z[2].instance_id == 1
    assert router_z.leases_granted >= 1 and router_z.pages_borrowed >= 2
    assert engines_z[1].prefix_cache.adopted_pages == 0, \
        "zero_copy must not copy payloads"
    assert reqs_z[2].num_cached_tokens == 2 * PS
    assert not router_z.g.ledger, "every lease repaid at request finish"
    # instance A's pages still pinned by the board (lendable), tree intact
    assert engines_z[0].prefix_cache.num_pages >= 2

    router_c, engines_c, reqs_c = _run_cluster(cfg, params, "copy", prompts)
    assert engines_c[1].prefix_cache.adopted_pages == 2

    for rz, rc, prompt in zip(reqs_z, reqs_c, prompts):
        want = _oracle(model, params, prompt, 3)
        assert rz.full_output == want, f"zero-copy req {rz.request_id}"
        assert rz.full_output == rc.full_output


def test_engine_zero_copy_long_suffix_chunks(model_setup):
    """The borrowed prefix also feeds _prefill_chunk_fn across multiple
    suffix chunks (remote partial merged into every chunk's attention)."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(14)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    prompts = [prefix + rng.integers(0, cfg.vocab_size, 3).tolist(),
               prefix + rng.integers(0, cfg.vocab_size, 3).tolist(),
               prefix + rng.integers(0, cfg.vocab_size, 20).tolist()]
    from repro.serving.router import RouterBackend
    engines = [_fresh_engine(cfg, params, enable_prefix_cache=True,
                             max_tokens_per_iter=8) for _ in range(2)]
    router = RouterBackend(engines, policy=ScriptedPolicy([0, 0, 1]),
                           prefix_share=True, share_mode="zero_copy",
                           hot_threshold=1)
    reqs = [Request(i, 0.0, list(p), max_new_tokens=2)
            for i, p in enumerate(prompts)]
    for r in reqs:
        router.add_request(r)
        while router.has_work:
            router.step()
    assert reqs[2].num_cached_tokens == 2 * PS
    assert router.pages_borrowed >= 2
    # 20 suffix tokens at budget 8 => 3 chunks, each merging the remote part
    assert reqs[2].full_output == _oracle(model, params, prompts[2], 2)


def test_engine_cannot_borrow_from_sim_home(model_setup):
    """A sim home has no KV pools an engine could read: the engine child
    must decline the lease, recompute, and still match the oracle."""
    cfg, model, params = model_setup
    from repro.serving.router import RouterBackend
    sim = SimBackend(num_blocks=64, block_size=PS, prefix_cache=True)
    eng = _fresh_engine(cfg, params, enable_prefix_cache=True)
    router = RouterBackend([sim, eng], policy=ScriptedPolicy([0, 0, 1]),
                           prefix_share=True, share_mode="zero_copy",
                           hot_threshold=1)
    rng = np.random.default_rng(21)
    prefix = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    reqs = [Request(i, 0.0, prefix +
                    rng.integers(0, cfg.vocab_size, 3).tolist(),
                    max_new_tokens=2) for i in range(3)]
    for r in reqs:
        router.add_request(r)
        while router.has_work:
            router.step()
    assert reqs[2].instance_id == 1
    assert reqs[2].num_cached_tokens == 0, "no lease from a sim home"
    assert eng.prefix_cache.adopted_pages == 0
    assert reqs[2].full_output == _oracle(model, params, reqs[2].prompt, 2)
