"""Production mesh builders (TPU v5e pods; 256 chips/pod).

Defined as FUNCTIONS so importing this module never touches jax device
state — ``dryrun.py`` must set XLA_FLAGS before the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices actually exist (tests/examples)."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The axes batch is sharded over (pod+data when multi-pod)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
