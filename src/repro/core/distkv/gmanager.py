"""InfiniteLLM gManager: global coordinator + debt ledger (paper §III.D.3).

Maintains per-instance memory availability from periodic heartbeats, builds
the **global debt ledger** (who lent how many rBlocks to whom) and answers
creditor recommendations for a debtor instance. Selection follows the paper:
locality (ring distance between instances, a stand-in for datacenter
topology), availability, and communication cost — the top-3 candidates are
proposed and the debtor tries them in order.

The gManager also hosts the cluster's **prefix publication board**
(``prefixshare.PrefixShareBoard``): instances publish hot radix paths (token
keys + page payloads) through it, and peers adopt them into their own radix
trees — the cross-instance half of prefix caching.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.distkv.prefixshare import PrefixShareBoard


@dataclasses.dataclass
class Heartbeat:
    instance_id: int
    free_blocks: int
    total_blocks: int


@dataclasses.dataclass
class DebtEntry:
    creditor: int
    debtor: int
    blocks: int


class GManager:
    def __init__(self, num_instances: int, *, safety_free: int = 2,
                 prefix_board_pages: Optional[int] = None):
        self.num_instances = num_instances
        self.free: Dict[int, int] = {i: 0 for i in range(num_instances)}
        self.total: Dict[int, int] = {i: 0 for i in range(num_instances)}
        self.ledger: List[DebtEntry] = []
        self.safety_free = safety_free  # blocks a creditor must keep local
        # cross-instance prefix sharing: published hot radix paths,
        # size-capped (LRU) — publications past the cap evict cold pages
        self.prefix_board = PrefixShareBoard(max_pages=prefix_board_pages)

    # -- heartbeats -----------------------------------------------------------
    def heartbeat(self, hb: Heartbeat) -> None:
        self.free[hb.instance_id] = hb.free_blocks
        self.total[hb.instance_id] = hb.total_blocks

    # -- debt ledger ------------------------------------------------------------
    def lent_by(self, inst: int) -> int:
        return sum(e.blocks for e in self.ledger if e.creditor == inst)

    def borrowed_by(self, inst: int) -> int:
        return sum(e.blocks for e in self.ledger if e.debtor == inst)

    def record_loan(self, creditor: int, debtor: int, blocks: int) -> None:
        for e in self.ledger:
            if e.creditor == creditor and e.debtor == debtor:
                e.blocks += blocks
                return
        self.ledger.append(DebtEntry(creditor, debtor, blocks))

    def record_repayment(self, creditor: int, debtor: int, blocks: int) -> None:
        for e in list(self.ledger):
            if e.creditor == creditor and e.debtor == debtor:
                e.blocks -= blocks
                if e.blocks <= 0:
                    self.ledger.remove(e)
                return
        raise KeyError((creditor, debtor))

    # -- creditor recommendation ---------------------------------------------
    def _distance(self, a: int, b: int) -> int:
        d = abs(a - b)
        return min(d, self.num_instances - d)  # ring topology

    def recommend_creditors(self, debtor: int, blocks: int,
                            k: int = 3) -> List[int]:
        """Top-k candidate creditors: must have spare capacity beyond the
        safety margin; ranked by (locality, then most-available)."""
        cands: List[Tuple[int, int, int]] = []
        for inst in range(self.num_instances):
            if inst == debtor:
                continue
            spare = self.free.get(inst, 0) - self.safety_free
            if spare <= 0:
                continue
            cands.append((self._distance(debtor, inst), -spare, inst))
        cands.sort()
        return [inst for _, _, inst in cands[:k]]

    def snapshot(self) -> Dict[int, Dict]:
        """The paper's Fig. 8 table: per-instance unused/total + debtors."""
        table = {}
        for inst in range(self.num_instances):
            debts = [(e.debtor, e.blocks) for e in self.ledger
                     if e.creditor == inst]
            table[inst] = {"free": self.free.get(inst, 0),
                           "total": self.total.get(inst, 0),
                           "debtors": debts}
        return table
