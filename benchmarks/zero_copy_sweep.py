"""Copy-vs-borrow sweep for cross-instance prefix serving.

A published hot prefix can reach a peer instance two ways: **copy** its page
payloads once into the peer's radix tree, or **borrow** the home instance's
physical pages (zero-copy rBlocks) and serve them in place through the
DistAttention partial merge. With the network cost model attached, both are
charged — the copy pays per-page serialization + wire time once per adopting
instance, the borrow pays a lease RPC plus a per-iteration merge round for
the borrower's whole decode.

The crossover is the decode length: the copy's one-time cost amortizes over
every future local hit, while the borrow's overhead grows with each decoded
token. Short decodes over a hot prefix favor borrowing (the copy never pays
itself off before the request is gone); long decodes favor copying. The
sweep replays the same shared-prefix workload at several output lengths
through `simulate_router` in `share_mode = copy | zero_copy | auto` and
reports the measured network-attributable overhead per mode — the headline
checks an actual crossover, not a modeling assumption.

    PYTHONPATH=src python benchmarks/zero_copy_sweep.py [--smoke]
"""

from __future__ import annotations

import argparse

from repro.core.distkv.netmodel import NetworkModel
from repro.serving.router import SHARE_MODES
from repro.serving.simulator import (make_shared_prefix_workload,
                                     simulate_router)

N_INSTANCES = 4
N_GROUPS = 4
PREFIX_LEN = 512           # 32 pages of 16: a real system prompt
BLOCK_SIZE = 16
BLOCKS_PER_INSTANCE = 1200


def run(n_requests: int = 240, out_lens=(16, 48, 96, 192),
        n_instances: int = N_INSTANCES, verbose: bool = True):
    rows = []
    net = NetworkModel()
    for out_len in out_lens:
        for mode in SHARE_MODES:
            wl = make_shared_prefix_workload(
                n_requests, rate=60.0, n_groups=N_GROUPS,
                prefix_len=PREFIX_LEN, suffix_len=32, out_len=out_len,
                seed=17, group_draw="random")
            res = simulate_router(
                wl, n_instances=n_instances, policy="round_robin",
                prefix_share=True, share_mode=mode,
                blocks_per_instance=BLOCKS_PER_INSTANCE,
                block_size=BLOCK_SIZE, net=net)
            rows.append({
                "out_len": out_len,
                "mode": mode,
                "net_ms": 1e3 * res.net_time,
                "mean_ttft": res.mean_ttft,
                "throughput": res.throughput_tokens_per_s,
                "adopted_pages": res.adopted_pages,
                "borrowed_pages": res.borrowed_pages,
                "hit_rate": res.prefix_hit_rate or 0.0,
                "completed": res.completed_frac,
            })
            if verbose:
                r = rows[-1]
                print(f"out={out_len:4d} {mode:9s}  "
                      f"net={r['net_ms']:8.2f}ms  "
                      f"ttft={1e3 * r['mean_ttft']:7.2f}ms  "
                      f"thr={r['throughput']:8.1f} tok/s  "
                      f"adopted={r['adopted_pages']:4d}  "
                      f"borrowed={r['borrowed_pages']:5d}  "
                      f"hit={r['hit_rate']:5.1%}  "
                      f"done={r['completed']:.0%}")
    return rows


def headline(rows) -> str:
    """The acceptance check: a measured copy-vs-borrow crossover — borrow's
    network overhead undercuts copy's at the shortest decodes and exceeds
    it at the longest, with both modes completing the workload and the
    zero-copy runs actually borrowing pages."""
    def pick(out_len, mode):
        return next(r for r in rows
                    if r["out_len"] == out_len and r["mode"] == mode)

    outs = sorted({r["out_len"] for r in rows})
    short, long_ = outs[0], outs[-1]
    cs, zs = pick(short, "copy"), pick(short, "zero_copy")
    cl, zl = pick(long_, "copy"), pick(long_, "zero_copy")
    ok = (zs["net_ms"] < cs["net_ms"] and zl["net_ms"] > cl["net_ms"]
          and all(r["completed"] == 1.0 for r in rows)
          and zs["borrowed_pages"] > 0 and zl["borrowed_pages"] > 0
          and zs["adopted_pages"] == 0)
    return (f"crossover: out={short} borrow {zs['net_ms']:.1f}ms < copy "
            f"{cs['net_ms']:.1f}ms; out={long_} borrow {zl['net_ms']:.1f}ms "
            f"> copy {cl['net_ms']:.1f}ms "
            f"{'ok' if ok else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run exercising share_mode=copy AND "
                         "zero_copy; exits nonzero without a measured "
                         "copy-vs-borrow crossover")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--instances", type=int, default=N_INSTANCES)
    args = ap.parse_args()
    n = args.requests or (96 if args.smoke else 240)
    # the borrow overhead scales with (borrowing requests x decode length),
    # the copy cost with distinct (instance, prefix) adoptions — the smoke's
    # smaller request count needs a longer decode to reach the crossover
    out_lens = (16, 384) if args.smoke else (16, 48, 96, 192)
    rows = run(n_requests=n, out_lens=out_lens, n_instances=args.instances)
    line = headline(rows)
    print(line)
    if args.smoke and "FAIL" in line:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
