"""Training substrate: optimizer math, data pipeline, checkpointing, and a
learning test (loss must actually decrease on the synthetic corpus)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import Model
from repro.training import checkpoint, optimizer
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.optimizer import OptConfig
from repro.training.train_loop import TrainConfig, train


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=110,
                    min_lr_frac=0.1)
    assert float(optimizer.schedule(cfg, 0)) == 0.0
    assert float(optimizer.schedule(cfg, 10)) == pytest.approx(1e-3)
    assert float(optimizer.schedule(cfg, 110)) == pytest.approx(1e-4,
                                                                rel=1e-2)


def test_adamw_moves_toward_minimum():
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=400,
                    min_lr_frac=1.0, weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = optimizer.init(params)
    for _ in range(400):
        grads = {"w": 2 * params["w"]}  # d/dw of w^2
        params, state, m = optimizer.apply(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clip_bounds_update():
    cfg = OptConfig(lr=1.0, warmup_steps=0, grad_clip=1.0,
                    weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = optimizer.init(params)
    _, _, m = optimizer.apply(cfg, params, {"w": jnp.full(4, 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_data_pipeline_deterministic_and_packed():
    dcfg = DataConfig(vocab_size=512, seq_len=64, global_batch=4, seed=3)
    c1 = SyntheticCorpus(dcfg).batches()
    c2 = SyntheticCorpus(dcfg).batches()
    b1, b2 = next(c1), next(c2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 512
    # EOS separators present somewhere in the stream (documents are packed;
    # a single 256-token batch may fall inside one long document)
    total_eos = sum((next(c1)["tokens"] == dcfg.eos).sum()
                    for _ in range(10))
    assert total_eos > 0


def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16) * 1.5,
            "b": {"c": jnp.arange(5, dtype=jnp.int32)},
            "d": jnp.array(2.5, jnp.float32)}
    d = checkpoint.save("/tmp/repro_test_ckpt", 7, tree)
    assert checkpoint.latest_step("/tmp/repro_test_ckpt") == 7
    back = checkpoint.restore("/tmp/repro_test_ckpt", 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_model_learns_on_synthetic_corpus():
    cfg = smoke_config("h2o-danube-1.8b")
    res = train(cfg, TrainConfig(
        steps=80, log_every=79,
        opt=OptConfig(lr=1.5e-3, warmup_steps=10, total_steps=80)),
        verbose=False)
    first, last = res["losses"][0][1], res["losses"][-1][1]
    assert last < first - 0.25, f"no learning: {first:.3f} -> {last:.3f}"
