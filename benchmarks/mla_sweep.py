"""Latent-KV (MLA) paging vs classic GQA pages at a fixed HBM budget.

DeepSeek-V2's Multi-head Latent Attention caches one shared latent per
token (``kv_lora_rank + qk_rope_head_dim`` elements) instead of per-head
K/V (``2 * num_kv_heads * head_dim``). On the real deepseek-v2-236b
geometry that is ~57x fewer KV bytes per token, which converts directly
into serving capacity: the same HBM KV budget holds ~57x more pages, so a
long-context workload that thrashes (swap/evict churn) under GQA pages
runs resident under MLA pages.

The sweep prices both layouts through :class:`KVPageLayout` — the sim's
page count comes from ``budget // layout.page_bytes``, and the PCIe swap
lane charges the layout's true bytes per page (satellite 2: an MLA page
is ~57x cheaper to move, so ``swap_mode="auto"`` and cost-ranked victims
decide differently) — and reports, per layout:

* bytes/token and pages that fit the budget (capacity table);
* achievable concurrent batch at the long-context operating point;
* throughput / P99 normalized latency of the same workload replayed
  through the sim with that layout's page count.

The CI-guarded headline: the compression ratio must hold (>= 5x, it is
~57x) and the MLA run must beat the GQA run on throughput at the
long-context point — the capacity-bound win the PR claims.

    PYTHONPATH=src python benchmarks/mla_sweep.py [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses

from repro.configs import get_config
from repro.core.distkv.netmodel import NetworkModel
from repro.core.paging import KVPageLayout
from repro.core.scheduling.request import Request
from repro.serving.simulator import simulate_paged

BLOCK_SIZE = 16
# KV HBM budget: what one 80 GB device has left for KV after deepseek-v2
# weights are sharded across the serving group (the absolute number only
# scales both layouts' page counts; the *ratio* is the story)
HBM_KV_BUDGET = 48 * 1024 ** 3
# long-context operating point: (n, prompt_len, max_new, arrival_gap_s,
# token_budget) — sized so GQA pages thrash while MLA pages stay resident
POINT = (12, 3072, 256, 0.05, 4096)


def layouts():
    """(gqa, mla) KVPageLayouts for the same deepseek-v2-236b geometry."""
    cfg = get_config("deepseek-v2-236b")
    return (KVPageLayout.from_arch(dataclasses.replace(cfg,
                                                       attention="gqa")),
            KVPageLayout.from_arch(cfg))


def _workload(n: int, prompt_len: int, max_new: int, gap: float):
    return [Request(request_id=i, arrival_time=i * gap, prompt=[],
                    prompt_len=prompt_len, max_new_tokens=max_new)
            for i in range(n)]


def run(verbose: bool = True, hbm_budget: int = HBM_KV_BUDGET):
    gqa, mla = layouts()
    n, plen, mnew, gap, btok = POINT
    rows = []
    for name, lay in (("gqa", gqa), ("mla", mla)):
        pages = hbm_budget // lay.page_bytes(BLOCK_SIZE)
        tokens = pages * BLOCK_SIZE
        batch = tokens // (plen + mnew)
        res = simulate_paged(
            _workload(n, plen, mnew, gap), num_blocks=pages,
            block_size=BLOCK_SIZE, max_tokens_per_iter=btok,
            host_blocks=pages, swap_mode="auto", victim_policy="cost",
            net=NetworkModel.for_layout(lay, BLOCK_SIZE))
        rows.append({
            "layout": name,
            "schema": lay.schema,
            "bytes_per_token": lay.bytes_per_token,
            "pages": pages,
            "page_bytes": lay.page_bytes(BLOCK_SIZE),
            "achievable_batch": batch,
            "throughput": res.throughput_tokens_per_s,
            "p99_norm_lat": res.p99_normalized_latency,
            "preemptions": res.preemptions,
            "swapped_out": res.swapped_out,
            "completed": res.completed_frac,
        })
        if verbose:
            r = rows[-1]
            print(f"{name:4s} {r['schema']:26s} "
                  f"{r['bytes_per_token'] / 2 ** 20:6.2f} MiB/tok  "
                  f"pages={r['pages']:6d}  batch={r['achievable_batch']:3d}  "
                  f"thr={r['throughput']:7.1f} tok/s  "
                  f"p99={r['p99_norm_lat'] * 1e3:7.2f} ms/tok  "
                  f"swap={r['swapped_out']:3d} pre={r['preemptions']:3d} "
                  f"done={r['completed']:.0%}")
    return rows


def headline(rows) -> str:
    """The acceptance guard: the latent layout stores >= 5x fewer KV
    bytes per token (it is ~57x on this geometry) AND converts that into
    a throughput win at the long-context point (the GQA run is capacity-
    bound: it swaps/preempts, the MLA run stays resident)."""
    gqa = next(r for r in rows if r["layout"] == "gqa")
    mla = next(r for r in rows if r["layout"] == "mla")
    ratio = gqa["bytes_per_token"] / mla["bytes_per_token"]
    ok = (ratio >= 5.0
          and mla["throughput"] > gqa["throughput"]
          and mla["completed"] >= gqa["completed"]
          and mla["achievable_batch"] > gqa["achievable_batch"])
    return (f"mla_paging: {ratio:.1f}x fewer KV bytes/token, "
            f"batch {gqa['achievable_batch']}->{mla['achievable_batch']}, "
            f"thr {gqa['throughput']:.0f}->{mla['throughput']:.0f} tok/s "
            f"(+{mla['throughput'] / max(gqa['throughput'], 1e-9) - 1:.0%}), "
            f"p99 {gqa['p99_norm_lat'] * 1e3:.2f}->"
            f"{mla['p99_norm_lat'] * 1e3:.2f} ms/tok "
            f"guard={'ok' if ok else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI run (the sweep is already CI-sized); exits "
                         "nonzero unless the latent layout holds >= 5x "
                         "compression and wins the long-context point")
    args = ap.parse_args()
    rows = run()
    line = headline(rows)
    print(line)
    if args.smoke and "FAIL" in line:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
