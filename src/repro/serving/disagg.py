"""Disaggregated prefill/decode serving: roles, placement, KV handoff.

Chunked prefill (PR 4) *interleaves* prefill and decode inside one
instance; disaggregation (DistServe / Splitwise style) *separates* them.
A cluster is declared as role-tagged instances — ``InstanceSpec`` wraps a
child backend with a role:

* ``prefill`` — runs chunked prefill only. Its scheduler is put in
  ``prefill_only`` mode: it admits and chunks prompts at full token budget
  but never plans a decode, so prefill throughput is never taxed by decode
  batching and decode latency is never spiked by a co-scheduled chunk.
* ``decode``  — never sees a new prompt (the router only places arrivals on
  prefill-capable instances); its iterations are pure decode batches whose
  time is the small per-token cost, which is the whole point: P99 TBT drops
  from "budget-sized mixed iteration" to "decode-only iteration".
* ``mixed``   — the pre-existing do-both behavior (the default when a bare
  backend is passed, so an all-``mixed`` router is exactly the old one).

The seam between the roles is the **KV handoff**: when a prefill instance
finishes a prompt's final chunk (the request has its first token and is
sitting in ``Phase.INCREMENT`` with nowhere to decode), the
:class:`KVHandoff` coordinator moves its prompt KV to a decode instance
chosen by :class:`DecodePlacement` and re-homes the request mid-flight.
The move reuses the PR 5 cross-instance KV machinery, per-request:

* **migrate** — ``export_page_payload`` on the prefill host, fresh blocks +
  ``import_page_payloads`` on the decode host. One payload transfer,
  charged as ``NetworkModel.page_copy_time``; afterwards decode is fully
  local and the prefill host's pages are free for the next prompt.
* **zero_copy** — a :class:`~repro.core.distkv.rmanager.RemoteLease` on the
  prefill host's physical pages, served in place through the DistAttention
  partial merge. Near-instant handoff (``lease_time``), but the decode
  host pays a merge per iteration and the prefill host's pages stay pinned
  for the request's lifetime. Unlike an admission-time prefix lease (capped
  at ``prompt_len - 1`` so the final token's logits are computed locally),
  a handoff lease covers **all full prompt pages** — the first token was
  already sampled on the prefill host; only a partial tail page is copied.
* **auto** — ``NetworkModel.prefer_borrow`` per request on the remaining
  decode length: short decodes borrow, long decodes amortize a copy.

Telemetry: each handoff is a ``handoff.kv`` begin/end span on the router
track (begin stamped at the prefill host's clock, end at the decode host's
clock after transfer charges), and a leased handoff emits the same
``lease.acquire`` instant on the decode instance's tracer that an
admission-time lease would, so lease acquire/release events balance per
(instance, request) no matter which host finishes the request.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, List, Optional, Sequence, Union

from repro.core.paging.allocator import BlockTable, OutOfBlocks
from repro.core.scheduling.request import Phase, Request

ROLES = ("prefill", "decode", "mixed")
HANDOFF_MODES = ("migrate", "zero_copy", "auto")

_ROLE_OF_LETTER = {"p": "prefill", "d": "decode", "m": "mixed"}


@dataclasses.dataclass
class InstanceSpec:
    """One cluster member: a constructed child backend plus its role.

    ``RouterBackend`` accepts bare backends (role ``mixed`` — the previous
    N-identical-children behavior) or ``InstanceSpec``s, mixed freely."""

    backend: Any
    role: str = "mixed"

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(f"role must be one of {ROLES}, "
                             f"got {self.role!r}")


def parse_role_spec(spec: Union[str, Sequence[str]]) -> List[str]:
    """Expand a role spec into a per-instance role list.

    The compact string grammar is ``(<count><p|d|m>)+``: ``"2p2d"`` is two
    prefill + two decode instances, ``"1p2d1m"`` adds a mixed one. A
    sequence of role names (``["prefill", "decode"]``) passes through
    validated. Raises ValueError with the grammar on anything malformed."""
    if isinstance(spec, (list, tuple)):
        roles = list(spec)
        for r in roles:
            if r not in ROLES:
                raise ValueError(f"unknown role {r!r}: roles are {ROLES}")
        return roles
    s = str(spec).strip().lower()
    if not re.fullmatch(r"(?:\d+[pdm])+", s):
        raise ValueError(
            f"malformed role spec {spec!r}: expected one or more "
            f"<count><p|d|m> groups, e.g. '2p2d' = 2 prefill + 2 decode "
            f"instances (p=prefill, d=decode, m=mixed)")
    roles: List[str] = []
    for count, letter in re.findall(r"(\d+)([pdm])", s):
        roles.extend([_ROLE_OF_LETTER[letter]] * int(count))
    if not roles:
        raise ValueError(f"role spec {spec!r} names zero instances")
    return roles


class DecodePlacement:
    """Pick the decode instance that receives a finished prefill's KV.

    Free-slot- and lease-aware least-loaded: candidates are the
    decode-capable instances (role ``decode`` or ``mixed``, excluding the
    prefill host) that have a free decode slot and room for the pages the
    handoff will materialize; among those, fewest queued+running requests
    wins, then the smallest outstanding borrowed-page debt (every borrowed
    page is a partial-merge round the instance keeps paying each
    iteration — a debt-laden instance is slower than its queue length
    suggests), then the most free KV pages."""

    name = "decode_placement"

    def choose(self, router, *, exclude: int,
               needed_pages: int) -> Optional[int]:
        best, best_key = None, None
        for i in router.decode_capable:
            if i == exclude:
                continue
            child = router.children[i]
            slots = getattr(child, "free_decode_slots", None)
            if slots is None:  # sim child: scheduler capacity only
                sched = child.scheduler
                slots = sched.max_running - len(sched.running)
            if slots < 1:
                continue
            free = child.allocator.num_free
            if free < needed_pages:
                continue
            sched = child.scheduler
            load = len(sched.waiting) + len(sched.running)
            key = (load, router.g.borrowed_by(i), -free, i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best


class KVHandoff:
    """Moves finished-prefill requests from prefill to decode instances.

    Owned by the router; :meth:`drain` runs at the top of every router step
    (a fully-parked prefill instance makes no progress of its own, so the
    handoff cannot ride on an after-step hook). A request with no viable
    decode target stays parked on its prefill host and is retried next
    step — ``deferrals`` counts those waits.

    Deferral is not allowed to become starvation: a request deferred more
    than ``defer_cap`` consecutive times falls back to decoding on its
    prefill host, mixed-style. The rid is added to that scheduler's
    ``decode_exempt`` set (a ``prefill_only`` scheduler plans decodes for
    exempt rids only), so the request finishes locally instead of waiting
    forever on decode capacity that may never appear — its KV is already
    resident there, so fallback costs nothing but the prefill host's
    iteration time. Each wait emits a ``handoff.deferred`` instant and the
    cap trip a ``handoff.fallback`` instant on the router track."""

    def __init__(self, router, *, mode: str = "auto",
                 placement: Optional[DecodePlacement] = None,
                 defer_cap: int = 8):
        if mode not in HANDOFF_MODES:
            raise ValueError(f"handoff_mode must be one of {HANDOFF_MODES}, "
                             f"got {mode!r}")
        if defer_cap < 1:
            raise ValueError(f"defer_cap must be >= 1, got {defer_cap}")
        self.router = router
        self.mode = mode
        self.placement = placement or DecodePlacement()
        self.defer_cap = defer_cap
        self.handoffs_migrated = 0
        self.handoffs_leased = 0
        self.pages_copied = 0
        self.pages_leased = 0
        self.deferrals = 0
        self.fallbacks = 0
        self._defers: dict = {}  # rid -> consecutive failed handoff tries

    @property
    def handoffs(self) -> int:
        return self.handoffs_migrated + self.handoffs_leased

    def drain(self) -> int:
        """Hand off every prefill-complete request parked on a prefill-only
        instance. Returns the number moved this call."""
        moved = 0
        r = self.router
        for p_idx in r.prefill_only:
            sched = r.children[p_idx].scheduler
            ready = [req for req in list(sched.running)
                     if req.phase == Phase.INCREMENT
                     and req.prefilled_len >= req.prompt_len
                     and req.request_id not in sched.decode_exempt]
            for req in ready:
                if self._handoff(p_idx, req):
                    self._defers.pop(req.request_id, None)
                    moved += 1
                else:
                    self._defer(p_idx, req)
        if moved:
            r._heartbeat_all()
        return moved

    def _defer(self, p_idx: int, req: Request) -> None:
        """One more failed handoff try; trip the fallback at the cap."""
        self.deferrals += 1
        rid = req.request_id
        n = self._defers.get(rid, 0) + 1
        self._defers[rid] = n
        r = self.router
        ts = r.children[p_idx].clock()
        tr = r.trace
        if tr is not None:
            tr.instant("handoff", "deferred", rid=rid, ts=ts, src=p_idx,
                       tries=n)
        if n >= self.defer_cap:
            # starvation guard: decode where the KV already lives
            r.children[p_idx].scheduler.decode_exempt.add(rid)
            self._defers.pop(rid, None)
            self.fallbacks += 1
            if tr is not None:
                tr.instant("handoff", "fallback", rid=rid, ts=ts, src=p_idx,
                           tries=n)

    # -- one handoff ------------------------------------------------------------

    def _pick_mode(self, req: Request, full_pages: int,
                   page_size: int) -> str:
        r = self.router
        if self.mode == "migrate" or full_pages == 0 or not r.rms \
                or not r.handoff_zc_ok:
            return "migrate"
        if self.mode == "zero_copy":
            return "zero_copy"
        # auto: remaining decode length is the lease's lifetime — the first
        # token is already out, so the myopic borrow-vs-copy estimate uses
        # what is left, not max_new_tokens
        remaining = max(req.max_new_tokens - len(req.output), 1)
        if r.net is None or r.net.prefer_borrow(
                full_pages, page_size, remaining,
                page_bytes=r._kv_page_bytes(req.instance_id)):
            return "zero_copy"
        return "migrate"

    def _handoff(self, p_idx: int, req: Request) -> bool:
        r = self.router
        p = r.children[p_idx]
        table = p.scheduler.tables.get(req.request_id)
        if table is None:  # raced a finish/preempt — nothing to move
            return False
        ps = p.allocator.block_size
        full = req.prompt_len // ps
        tail = req.prompt_len - full * ps
        mode = self._pick_mode(req, full, ps)
        # pages the decode host must materialize, plus one page of headroom
        # so the first decode append cannot immediately OOM it
        needed = len(table.blocks) if mode == "migrate" else (1 if tail
                                                              else 0)
        d_idx = self.placement.choose(r, exclude=p_idx,
                                      needed_pages=needed + 1)
        if d_idx is None:
            return False  # no viable decode target: stay parked, retry
        d = r.children[d_idx]
        t0 = p.clock()
        if d.clock() is not None and t0 is not None and d.clock() < t0:
            # the KV leaves the prefill host at t0; a virtual decode host
            # idling in the past cannot have installed it earlier
            d.advance_to(t0)
        exp = getattr(p, "export_page_payload", None)
        write = getattr(d, "import_page_payloads", None)
        charge = getattr(d, "charge_network", None)
        m = getattr(d, "metrics", None)
        net = r.net
        lease = None
        if mode == "migrate":
            new_blocks: List[int] = []
            try:
                for _ in table.blocks:
                    new_blocks.append(d.allocator.alloc_block())
            except OutOfBlocks:  # placement raced another grower: roll back
                for b in new_blocks:
                    d.allocator.decref(b)
                return False
            if exp is not None and write is not None:
                write(new_blocks, [exp(b) for b in table.blocks])
            table_d = BlockTable(blocks=new_blocks,
                                 num_tokens=req.prompt_len)
            pages = len(new_blocks)
            if net is not None:
                pb = r._kv_page_bytes(d_idx)
                if charge is not None:
                    charge(net.page_copy_time(pages, page_bytes=pb))
                if m is not None:
                    m.count("net_bytes", r._net_bytes(d_idx, pages))
            self.handoffs_migrated += 1
            self.pages_copied += pages
        else:
            try:
                lease = r.rms[d_idx].borrow_blocks(p_idx,
                                                   table.blocks[:full])
            except (KeyError, ValueError):
                return False  # rBlock wiring missing/stale: retry next step
            tail_blocks: List[int] = []
            if tail:  # the partial tail page is copied, not leased
                tb = d.allocator.alloc_block()
                if exp is not None and write is not None:
                    write([tb], [exp(table.blocks[full])])
                tail_blocks = [tb]
            table_d = BlockTable(blocks=tail_blocks, num_tokens=tail)
            lease.commit()
            pages = full
            if net is not None:
                if charge is not None:
                    charge(net.lease_time(full) +
                           (net.page_copy_time(
                               1, page_bytes=r._kv_page_bytes(d_idx))
                            if tail else 0.0))
                if m is not None:
                    m.count("borrowed_pages", full)
            r.leases_granted += 1
            r.pages_borrowed += full
            self.handoffs_leased += 1
            self.pages_leased += full
            if tail:
                self.pages_copied += 1
        # the prefill side lets go only now that the KV is secured (payloads
        # exported above / blocks lent under the lease): releasing frees its
        # slot and block table without finishing the request
        release = getattr(p, "release_for_handoff", None)
        if release is not None:
            release(req)
        else:
            p.scheduler.release_request(req)
        req.instance_id = d_idx
        r._placement[req.request_id] = d_idx
        install = getattr(d, "install_for_handoff", None)
        if install is not None:
            install(req, table_d, lease)
        else:
            d.scheduler.install_running(req, table_d, lease)
        t1 = d.clock()
        if lease is not None:
            # mirror the admission-time lease.acquire instant on the decode
            # instance's own track: its scheduler will emit the matching
            # lease.release there at finish/preempt
            d_tr = getattr(d.scheduler, "trace", None)
            if d_tr is not None:
                d_tr.instant("lease", "acquire", rid=req.request_id, ts=t1,
                             home=p_idx, tokens=lease.num_tokens,
                             handoff=True)
        tr = r.trace
        if tr is not None:
            tr.begin("handoff", "kv", req.request_id, ts=t0, src=p_idx,
                     dst=d_idx, mode=mode, pages=pages,
                     prompt_len=req.prompt_len)
            tr.end("handoff", "kv", req.request_id, ts=t1)
        return True
