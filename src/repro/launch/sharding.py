"""Sharding rules: parameters, inputs, caches, and the activation policy.

Strategy (DESIGN.md §5):

* **weights** — 2-D sharded: penultimate dim over ``data`` (FSDP-style),
  last dim over ``model`` (tensor parallel); stacked-layer leading dims
  replicated. MoE expert stacks ``(E, d, ff)`` shard E over ``model``
  (expert parallelism) and d over ``data``.
* **train/prefill activations** — batch over (pod×)data; heads/ffn/vocab
  over ``model`` when divisible.
* **decode caches** — batch over data when divisible; the KV *sequence* axis
  over ``model`` (and over data too when batch==1, e.g. ``long_500k``) —
  this is DistAttention as the primary decode sharding mechanism.

Every rule checks divisibility and degrades to replication rather than
failing — heads counts like hymba's 25 do not divide 16 and simply stay
unsharded on that axis (GSPMD still partitions the surrounding matmuls).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs import ArchConfig, InputShape
from repro.launch.mesh import data_axes
from repro.models.layers import ShardingPolicy


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


class MeshPolicy(ShardingPolicy):
    """Activation sharding constraints, divisibility-guarded."""

    def __init__(self, mesh, cfg: ArchConfig, *, decode: bool = False,
                 megatron: bool = True):
        """``megatron``: inter-block activations replicated in d_model +
        explicit transient FSDP weight gathers (perf iterations 2+3). False
        reverts to the paper-faithful baseline layout (activations d@model,
        weights resident 2-D sharded)."""
        self.mesh = mesh
        self.cfg = cfg
        self.decode = decode
        self.megatron = megatron
        self.dp = data_axes(mesh)
        self.dp_size = 1
        for a in self.dp:
            self.dp_size *= mesh.shape[a]
        self.mp = "model" if "model" in mesh.axis_names else None
        self.mp_size = mesh.shape["model"] if self.mp else 1
        # GShard grouped MoE dispatch: one group per data shard
        self.moe_groups = self.dp_size

    # -- expert-parallel MoE via shard_map -----------------------------------
    def moe_apply(self, cfg, p, x, return_aux: bool):
        """Expert-parallel MoE (InfiniteLLM-era standard mapping): tokens are
        data-sharded and replicated over ``model``; each model shard owns
        E/mp whole experts, scatters its tokens locally (masked, no cross-
        shard scatter), runs its experts, and the combine is a single psum
        over ``model`` — the jax-native equivalent of the all-to-all +
        expert-compute + all-to-all pipeline, with zero GSPMD guesswork."""
        from functools import partial
        from repro.models import moe as moe_mod
        from repro.models.layers import mlp

        if not _div(cfg.num_experts, self.mp_size) or self.mp is None:
            return None  # fall back to the jnp path
        b, s, d = x.shape
        e, k = cfg.num_experts, cfg.moe_top_k
        e_loc = e // self.mp_size
        t = b * s
        t_loc = max(t // self.dp_size, 1)
        if t % self.dp_size:
            return None
        cap = max(8, int(t_loc * k * cfg.capacity_factor / e + 8) // 8 * 8)
        dpa = tuple(self.dp)

        def local(xt, router_w, gate_w, up_w, down_w):
            # xt: (T_loc, d); expert weights come in (e_loc, d/dp, f) —
            # FSDP-gather the contraction dim (reduce-scatter in backward)
            gate_w = jax.lax.all_gather(gate_w, dpa, axis=1, tiled=True)
            up_w = jax.lax.all_gather(up_w, dpa, axis=1, tiled=True)
            down_w = jax.lax.all_gather(down_w, dpa, axis=1, tiled=True)
            midx = jax.lax.axis_index(self.mp)
            logits = xt.astype(jnp.float32) @ router_w  # (T_loc, E) full E
            probs = jax.nn.softmax(logits, axis=-1)
            topv, topi = jax.lax.top_k(probs, k)
            topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
            # position within each expert's capacity (over full E, so every
            # shard agrees on positions; cheap: (T_loc*k, E) local cumsum)
            onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)
            flat = onehot.reshape(t_loc * k, e)
            pos_e = (jnp.cumsum(flat, axis=0) - flat).reshape(t_loc, k, e)
            pos = (pos_e * onehot).sum(-1)
            keep = pos < cap
            # my experts: [midx*e_loc, (midx+1)*e_loc)
            local_e = topi - midx * e_loc
            mine = (local_e >= 0) & (local_e < e_loc) & keep
            eidx = jnp.where(mine, local_e, e_loc)  # ->drop
            pidx = jnp.where(mine, pos, cap)
            contrib = jnp.where(mine[..., None], xt[:, None, :], 0)
            disp = jnp.zeros((e_loc, cap, d), x.dtype).at[
                eidx, pidx].add(contrib, mode="drop")
            g_ = jnp.einsum("ecd,edf->ecf", disp, gate_w)
            u_ = jnp.einsum("ecd,edf->ecf", disp, up_w)
            h = jax.nn.silu(g_) * u_
            out = jnp.einsum("ecf,efd->ecd", h, down_w)
            gathered = out[jnp.where(mine, local_e, 0),
                           jnp.where(mine, pos, 0)]  # (T_loc, k, d)
            w = (topv * mine).astype(x.dtype)
            y_part = (gathered * w[..., None]).sum(1)  # (T_loc, d)
            y = jax.lax.psum(y_part, self.mp)
            # load-balance aux (identical across mp; per-dp-shard value)
            frac_tok = jnp.mean(jax.nn.one_hot(topi[:, 0], e,
                                               dtype=jnp.float32), axis=0)
            aux = e * jnp.sum(frac_tok * jnp.mean(probs, axis=0))
            return y, aux[None]

        fn = shard_map(
            local, mesh=self.mesh,
            in_specs=(P(dpa, None), P(), P("model", dpa, None),
                      P("model", dpa, None), P("model", dpa, None)),
            out_specs=(P(dpa, None), P(dpa)),
        )
        y, aux = fn(x.reshape(t, d), p["router"]["w"].astype(jnp.float32),
                    p["gate"], p["up"], p["down"])
        y = y.reshape(b, s, d)
        if "shared" in p:
            y = y + mlp(p["shared"], x, self)
        aux = jnp.mean(aux)
        return (y, aux) if return_aux else y

    def _c(self, x, spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def prefers_flat_heads(self, h: int, hkv: int) -> bool:
        """True when flat-H sharding works but grouped Hkv sharding doesn't
        (e.g. 96 heads / 8 kv heads on a 16-way model axis)."""
        return (self.megatron and _div(h, self.mp_size)
                and not _div(hkv, self.mp_size))

    def param(self, w, kind: str):
        """Explicit FSDP weight gather (perf iteration 3): weights are
        *stored* (d_in@data, d_out@model); before each matmul they are
        gathered over `data` to a transient (d_in, d_out@model) — the
        Megatron column/row-parallel layout. Autodiff turns the gather into
        the grad reduce-scatter. Decode keeps weights resident (gathering
        per generated token would swamp the step)."""
        if kind != "matmul_weight" or w.ndim < 2 or self.decode \
                or not self.megatron:
            return w
        if _div(w.shape[-1], self.mp_size):
            return self._c(w, P(*(None,) * (w.ndim - 1), self.mp))
        return self._c(w, P(*(None,) * w.ndim))

    def act(self, x, kind: str):
        cfg, dp, mp = self.cfg, self.dp, self.mp
        b = x.shape[0]
        batch_ax = dp if _div(b, self.dp_size) else None
        if kind == "act_bsd":
            # Megatron layout: the d_model axis of inter-block activations is
            # REPLICATED over `model` — sharding it (d@mp) made GSPMD gather
            # x before every matmul whose weight holds d_in@data (17 GB/layer
            # on mistral prefill). Per-layer FSDP weight gathers are ~6x
            # cheaper and transient under the layer scan. (Perf iteration 2.)
            if self.megatron:
                return self._c(x, P(batch_ax, *(None,) * (x.ndim - 1)))
            if _div(x.shape[-1], self.mp_size):
                return self._c(x, P(batch_ax, *(None,) * (x.ndim - 2), mp))
            return self._c(x, P(batch_ax, *(None,) * (x.ndim - 1)))
        if kind in ("ffn_bsf",):
            if _div(x.shape[-1], self.mp_size):
                return self._c(x, P(batch_ax, None, mp))
            return x
        if kind == "logits_bsv":
            if _div(x.shape[-1], self.mp_size):
                return self._c(x, P(batch_ax, *(None,) * (x.ndim - 2), mp))
            return x
        if kind == "heads_bshd":
            h = x.shape[2]
            if _div(h, self.mp_size):
                return self._c(x, P(batch_ax, None, mp, None))
            return self._c(x, P(batch_ax, None, None, None))
        if kind == "kv_bshd":
            h = x.shape[2]
            if _div(h, self.mp_size):
                return self._c(x, P(batch_ax, None, mp, None))
            if _div(x.shape[1], self.mp_size):
                # non-divisible KV heads: shard the KV sequence (micro-
                # attention); scores/probs inherit s@model coherently
                return self._c(x, P(batch_ax, mp, None, None))
            return self._c(x, P(batch_ax, None, None, None))
        if kind in ("kvcache_bskd", "mlacache_bsr"):
            # decode: sequence axis over model (DistAttention); over
            # data too when the batch axis cannot absorb it (B==1)
            seq_ax = mp if batch_ax else (tuple(dp) + (mp,) if mp else dp)
            sdim = x.shape[1]
            size = self.mp_size * (1 if batch_ax else self.dp_size)
            if not _div(sdim, size):
                seq_ax = mp if _div(sdim, self.mp_size) else None
            if x.ndim == 4:
                return self._c(x, P(batch_ax, seq_ax, None, None))
            return self._c(x, P(batch_ax, seq_ax, None))
        if kind in ("expert_gecd", "expert_gecf"):
            # grouped dispatch (G, E, cap, D): groups over data (they ARE the
            # data shards), experts over model (expert parallelism)
            gax = dp if _div(x.shape[0], self.dp_size) else None
            eax = mp if _div(x.shape[1], self.mp_size) else None
            return self._c(x, P(gax, eax, None, None))
        if kind == "kvrep_bshd":  # broadcast KV, flat heads (iteration 4)
            return self._c(x, P(batch_ax, None, mp, None))
        if kind == "scores_bchs":
            return self._c(x, P(batch_ax, None, mp, None))
        if kind == "scores_bchgs":
            # attention scores (B, C, Hkv, G, Skv): prefer KV-head sharding;
            # non-divisible head counts fall back to KV-sequence sharding
            # (micro-attention; measured better than query-chunk sharding —
            # see EXPERIMENTS.md §Perf iteration 1, refuted)
            if _div(x.shape[2], self.mp_size):
                return self._c(x, P(batch_ax, None, mp, None, None))
            if _div(x.shape[-1], self.mp_size):
                return self._c(x, P(batch_ax, None, None, None, mp))
            return self._c(x, P(batch_ax, None, None, None, None))
        if kind == "ssm_bshp":
            if x.ndim == 4 and _div(x.shape[2], self.mp_size):
                return self._c(x, P(batch_ax, None, mp, None))
            return x
        return x


# ---------------------------------------------------------------------------
# parameter / input / cache shardings
# ---------------------------------------------------------------------------

def param_spec(path_keys, leaf, mesh, cfg: ArchConfig) -> P:
    dp = data_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    msize = mesh.shape["model"]
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path_keys]
    nd = leaf.ndim
    if nd <= 1:
        return P()
    # vocab-parallel embedding (Megatron): vocab over `model` so logits come
    # out (tokens@data, vocab@model) without materializing the full vocab dim
    if names[-1] == "table" and nd == 2:
        v, d = leaf.shape
        return P("model" if _div(v, msize) else None,
                 dp if _div(d, dsize) else None)
    # MoE expert stacks: [...]['mlp']['gate'|'up'|'down'] raw 3D/4D arrays.
    # 2-D sharded: experts over `model` (expert parallelism), the weight's
    # contraction dim over `data` (FSDP); the shard_map dispatch path
    # all-gathers the contraction dim per layer (reduce-scatter on backward).
    if names[-1] in ("gate", "up", "down") and nd >= 3 and cfg.is_moe:
        e, w_in = leaf.shape[-3], leaf.shape[-2]
        espec = "model" if _div(e, msize) else None
        wspec = dp if _div(w_in, dsize) else None
        return P(*(None,) * (nd - 3), espec, wspec, None)
    # generic matrices (possibly layer-stacked): shard last two dims
    d_in, d_out = leaf.shape[-2:]
    a = dp if _div(d_in, dsize) else None
    b = "model" if _div(d_out, msize) else None
    return P(*(None,) * (nd - 2), a, b)


def param_shardings(params_shape, mesh, cfg: ArchConfig):
    """Pytree of NamedShardings matching a params (or opt-state) shape tree."""
    def mk(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf, mesh, cfg))
    return jax.tree_util.tree_map_with_path(mk, params_shape)


def batch_shardings(specs, mesh, cfg: ArchConfig):
    """Input shardings for train/prefill token batches."""
    dp = data_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]

    def mk(leaf):
        b = leaf.shape[0]
        ax = dp if _div(b, dsize) else None
        return NamedSharding(mesh, P(ax, *(None,) * (leaf.ndim - 1)))
    return jax.tree.map(mk, specs)


def cache_shardings(cache_specs, mesh, cfg: ArchConfig, batch: int):
    """Decode-cache shardings: batch over data; sequence over model
    (+ data when batch==1) — DistAttention layout."""
    dp = data_axes(mesh)
    dsize = 1
    for a in dp:
        dsize *= mesh.shape[a]
    msize = mesh.shape["model"]
    batch_ok = _div(batch, dsize)

    def seq_axis_for(sdim: int):
        if batch_ok:
            return "model" if _div(sdim, msize) else None
        full = tuple(dp) + ("model",)
        if _div(sdim, dsize * msize):
            return full
        return "model" if _div(sdim, msize) else None

    def mk(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k)))
                 for k in path]
        nd = leaf.ndim
        shape = leaf.shape
        batch_ax = dp if batch_ok else None
        # identify which dim is batch: caches may carry a leading stacked-
        # layer dim; batch dim is where shape == `batch`
        lead = 1 if (nd >= 3 and shape[0] != batch and shape[1] == batch) \
            else 0
        spec = [None] * nd
        if shape[lead] == batch and batch_ok:
            spec[lead] = dp
        # sequence dim right after batch for kv/mla/pos leaves
        field = names[-1] if names else ""
        if field in ("k", "v", "ckv", "krope", "pos", "ck", "cv"):
            sdim_idx = lead + 1
            if sdim_idx < nd:
                spec[sdim_idx] = seq_axis_for(shape[sdim_idx])
        elif field == "state":  # SSM state (.., B, H, P, N): heads on model
            hidx = lead + 1
            if hidx < nd and _div(shape[hidx], msize):
                spec[hidx] = "model"
        elif field == "conv":  # (.., B, W-1, conv_dim)
            cidx = nd - 1
            if _div(shape[cidx], msize):
                spec[cidx] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(mk, cache_specs)


def replicated(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
