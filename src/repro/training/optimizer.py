"""AdamW + cosine schedule with warmup, pure JAX (no optax offline)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment  (fp32)
    nu: Any  # second moment (fp32)


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def _decay_mask(path: str) -> bool:
    """No weight decay on norms/biases (1-D params)."""
    return True


def apply(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
