"""InfiniteLLM distkv: gManager debt ledger, rManager borrowing, and the
DistAttention partial-merge math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.distkv import (GManager, Heartbeat, RManager,
                               dist_attention_ref, merge_partials_tree,
                               micro_attention_partial)
from repro.core.paging import BlockAllocator, OutOfBlocks


def _cluster(n=4, blocks=8, bs=16):
    g = GManager(n)
    rms = {i: RManager(i, BlockAllocator(blocks, bs), g) for i in range(n)}
    for r in rms.values():
        r.register_peers(rms)
    return g, rms


def test_local_alloc_no_debt():
    g, rms = _cluster()
    rms[0].append_tokens(1, 16 * 3)
    assert not g.ledger
    assert rms[0].remote_fraction(1) == 0.0


def test_borrow_then_repay():
    g, rms = _cluster(blocks=4)
    rms[0].append_tokens(1, 16 * 4)  # fills local
    rms[0].append_tokens(2, 16 * 2)  # both remote
    assert rms[0].remote_fraction(2) == 1.0
    assert g.borrowed_by(0) == 2
    rms[0].free_seq(2)
    assert g.borrowed_by(0) == 0
    assert all(rm.allocator.num_free + len(rm.allocator.refcount) == 4
               for rm in rms.values())


def test_creditor_selection_prefers_locality():
    g = GManager(6)
    for i in range(6):
        g.heartbeat(Heartbeat(i, free_blocks=5, total_blocks=8))
    recs = g.recommend_creditors(0, 1)
    # ring distance from 0: instances 1 and 5 are closest
    assert set(recs[:2]) == {1, 5}
    assert len(recs) == 3


def test_creditor_respects_safety_margin():
    g = GManager(3, safety_free=4)
    g.heartbeat(Heartbeat(1, free_blocks=4, total_blocks=8))  # spare <= 0
    g.heartbeat(Heartbeat(2, free_blocks=8, total_blocks=8))
    assert g.recommend_creditors(0, 1) == [2]


def test_cluster_exhaustion_raises_and_rolls_back():
    g, rms = _cluster(n=2, blocks=2)
    rms[0].append_tokens(1, 16 * 2)
    rms[1].append_tokens(2, 16 * 1)  # leaves 1 block cluster-wide (safety=2)
    with pytest.raises(OutOfBlocks):
        rms[0].append_tokens(3, 16 * 4)
    # rollback: nothing half-allocated
    assert g.borrowed_by(0) == 0
    assert rms[0].seqs[3].num_tokens == 0 if 3 in rms[0].seqs else True


def test_debt_ledger_snapshot_matches_paper_fig8_semantics():
    g, rms = _cluster(n=4, blocks=4)
    rms[1].append_tokens(10, 16 * 4)  # local full
    rms[1].append_tokens(11, 16 * 2)  # borrows 2
    snap = g.snapshot()
    creditors = [i for i, row in snap.items() if row["debtors"]]
    assert creditors, "someone lent blocks"
    for i, row in snap.items():
        for debtor, blocks in row["debtors"]:
            assert debtor == 1 and blocks > 0


# -- DistAttention math --------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_partial_merge_equals_full_softmax(seed, shards):
    """Property: merging shard partials == unsharded attention, any split."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, h, hkv, dh, s = 2, 4, 2, 16, 8 * shards
    q = jax.random.normal(ks[0], (b, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    lens = jnp.array([3, s], jnp.int32)
    want = dist_attention_ref(q, k, v, lens)

    pos = jnp.arange(s)
    os_, ms, ls = [], [], []
    per = s // shards
    for i in range(shards):
        sl = slice(i * per, (i + 1) * per)
        valid = (pos[sl][None, :] < lens[:, None])
        o, m, l = micro_attention_partial(q, k[:, sl], v[:, sl], valid)
        os_.append(o)
        ms.append(m)
        ls.append(l)
    merged = merge_partials_tree(os_, ms, ls)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_empty_shard_does_not_nan():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, h, hkv, dh, s = 1, 2, 1, 8, 4
    q = jax.random.normal(ks[0], (b, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    valid = jnp.zeros((b, s), bool)  # shard holds nothing valid
    o, m, l = micro_attention_partial(q, k, v, valid)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(l == 0))


# -- publication-board eviction (size-capped LRU) -------------------------------

def _publish_path(board, base, n_pages, ps=4, inst=0):
    toks = [base * 1000 + i for i in range(n_pages * ps)]
    board.publish(inst, toks, [f"payload-{base}-{i}" for i in range(n_pages)],
                  ps)
    return toks


def test_board_eviction_caps_resident_pages():
    from repro.core.distkv.prefixshare import PrefixShareBoard
    board = PrefixShareBoard(max_pages=4)
    a = _publish_path(board, 1, 2)
    b = _publish_path(board, 2, 2)
    assert board.num_pages == 4
    c = _publish_path(board, 3, 2)  # over cap: the LRU path (a) ages out
    assert board.num_pages == 4
    assert board.evicted_pages == 2
    assert len(board.match(a)) == 0, "LRU path must be gone"
    assert len(board.match(b)) == 2 and len(board.match(c)) == 2


def test_board_eviction_lru_respects_lookups():
    from repro.core.distkv.prefixshare import PrefixShareBoard
    board = PrefixShareBoard(max_pages=4)
    a = _publish_path(board, 1, 2)
    b = _publish_path(board, 2, 2)
    board.match(a)  # touch a: b becomes the LRU victim
    _publish_path(board, 3, 2)
    assert len(board.match(a)) == 2, "hot path must survive"
    assert len(board.match(b)) == 0


def test_board_eviction_keeps_surviving_paths_intact():
    """Leaf-only eviction: a long path shrinks from its tail, never from
    the middle — every surviving prefix stays matchable."""
    from repro.core.distkv.prefixshare import PrefixShareBoard
    board = PrefixShareBoard(max_pages=3)
    long_path = _publish_path(board, 1, 5)  # 5 pages -> 2 tail pages evicted
    assert board.num_pages == 3
    assert len(board.match(long_path)) == 3
    assert board.stats()["resident_pages"] == 3


def test_board_unbounded_by_default():
    from repro.core.distkv.prefixshare import PrefixShareBoard
    board = PrefixShareBoard()
    for i in range(30):
        _publish_path(board, i, 2)
    assert board.num_pages == 60 and board.evicted_pages == 0


def test_router_board_cap_end_to_end():
    """A cluster with a small board cap still completes and adopts
    cross-instance prefixes, the cap is actually plumbed through
    RouterBackend -> GManager -> PrefixShareBoard, and the board never
    exceeds it (evicting once the hot groups outgrow it)."""
    from repro.serving.api import LLMService
    from repro.serving.router import RouterBackend
    from repro.serving.simulator import (SimBackend,
                                         make_shared_prefix_workload)
    reqs = make_shared_prefix_workload(60, rate=60.0, n_groups=6,
                                       prefix_len=96, suffix_len=16,
                                       out_len=16, seed=5,
                                       group_draw="random")
    children = [SimBackend(num_blocks=400, block_size=16, prefix_cache=True)
                for _ in range(3)]
    router = RouterBackend(children, policy="round_robin",
                           prefix_share=True, board_pages=8)
    board = router.g.prefix_board
    assert board.max_pages == 8, "cap must reach the board"
    svc = LLMService(router)
    for r in sorted(reqs, key=lambda r: r.arrival_time):
        svc.submit_request(r)
    svc.drain()
    stats = svc.stats()
    assert stats.completed_frac == 1.0
    # 6 hot groups x 6 prefix pages overflow the 8-page cap: eviction ran
    # and the cap held, yet peers still adopted published pages
    assert board.num_pages <= 8
    assert board.evicted_pages > 0
    assert router.prefix_cache.adopted_pages > 0
