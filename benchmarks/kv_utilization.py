"""Paper §III.C claim: contiguous pre-allocation stores only 20.4-38.2% of
KV memory as real tokens; paging fixes this. Measured on identical
workloads through the real allocators."""

from __future__ import annotations

from repro.serving.simulator import (make_workload, simulate_paged,
                                     simulate_prealloc)


def run(verbose: bool = True):
    wl = lambda: make_workload(300, rate=8.0, dist="sharegpt", seed=3)
    rows = {}
    r = simulate_paged(wl(), num_blocks=2048, block_size=16)
    rows["vLLM-paged"] = r.kv_utilization
    for pol in ("oracle", "pow2", "max"):
        r = simulate_prealloc(wl(), total_slots=2048 * 16, policy=pol)
        rows[f"orca-{pol}"] = r.kv_utilization
    if verbose:
        print("KV-memory utilization (fraction of reserved slots holding "
              "real tokens):")
        for k, v in rows.items():
            marker = ""
            if k == "orca-max" and 0.15 <= v <= 0.45:
                marker = "   <- paper reports 20.4%-38.2% for this system"
            print(f"  {k:12s} {v:6.1%}{marker}")
    return rows


if __name__ == "__main__":
    run()
