"""ORCA iteration-level scheduler (paper §III.B Sol1) with selective batching.

Each call to :meth:`schedule` plans exactly ONE engine iteration: which
waiting requests to prefill (initiation phase) and which running requests to
advance by one token (increment phase). Early-finished requests leave the
batch immediately; late-joining requests enter at the next iteration — the
exact fix for ORCA's challenge C1.

Selective batching (Sol2) shows up as the *token budget*: attention is
per-sequence (paged cache), while MLP/linear layers run over the flattened
token buffer, so the scheduler bounds ``sum(prompt lens) + #decodes`` per
iteration rather than the sequence count.

Memory is delegated to a :class:`BlockAllocator` (vLLM §III.C) or any object
with the same interface; preemption-by-recompute evicts the youngest request
when pages run out (vLLM's recompute policy).

With a :class:`~repro.core.prefixcache.PrefixCache` attached, admission first
matches the prompt against the radix tree: matched pages are locked into the
request's block table (refcounted, no recompute) and only the *uncached
suffix* is charged against the token budget; prompt pages are inserted into
the tree as soon as prefill completes (and survive the request), and under
page pressure LRU cache eviction runs before any preemption.

``prefix_importer`` extends the match across instances: before committing
to a local match, admission offers the prompt to the importer (wired by a
cluster router to the distkv publication board), which may *adopt* pages a
peer instance published into the local tree — the admission then re-matches
and prefills only the suffix past the imported prefix.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.paging.allocator import BlockAllocator, BlockTable
from repro.core.prefixcache.radix import PrefixCache
from repro.core.scheduling.request import Phase, Request


@dataclasses.dataclass
class IterationPlan:
    prefill: List[Request]
    decode: List[Request]
    preempted: List[Request]
    # copy-on-write block replacements this iteration: the engine must copy
    # each old physical page into its new page before any decode write
    cow: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    @property
    def empty(self) -> bool:
        return not (self.prefill or self.decode)

    def token_count(self) -> int:
        """Tokens through the flattened MLP buffer this iteration (cached
        prefix pages are read, not recomputed — they cost no prefill FLOPs)."""
        return sum(r.prompt_len - r.num_cached_tokens
                   for r in self.prefill) + len(self.decode)


class IterationScheduler:
    def __init__(self, allocator: BlockAllocator, *,
                 max_running: int = 64,
                 max_tokens_per_iter: int = 8192,
                 watermark: float = 0.01,
                 prefix_cache: Optional[PrefixCache] = None,
                 max_preemptions: Optional[int] = None,
                 cache_generated: bool = True,
                 prefix_importer: Optional[
                     Callable[[Sequence[int], int], int]] = None):
        self.allocator = allocator
        self.max_running = max_running
        self.max_tokens = max_tokens_per_iter
        self.watermark_blocks = max(1, int(allocator.num_blocks * watermark))
        self.prefix_cache = prefix_cache
        # a request preempted more than this many times is dropped with
        # finish_reason "preempted-dropped" instead of recomputed forever
        self.max_preemptions = max_preemptions
        # insert *generated* tokens into the radix tree at finish, so a
        # multi-turn follow-up resending the assistant reply hits the cache
        # beyond the prompt. Disable when outputs are placeholder ids (sim).
        self.cache_generated = cache_generated
        # cross-instance sharing hook: (prompt, locally_cached_tokens) ->
        # #pages adopted from a peer's publication into the local tree.
        # Admission re-matches after a successful import.
        self.prefix_importer = prefix_importer
        self.waiting: List[Request] = []
        self.running: List[Request] = []
        self.tables: Dict[int, BlockTable] = {}
        self._cache_paths: Dict[int, list] = {}  # request id -> locked nodes

    # -- client API -------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        req.phase = Phase.WAITING
        self.waiting.append(req)

    def finish(self, req: Request, now: float,
               reason: Optional[str] = None) -> None:
        req.phase = Phase.FINISHED
        req.finish_time = now
        req.finish_reason = reason or req.finish_reason_if_done \
            or req.finish_reason
        if req.request_id in self.tables:
            table = self.tables[req.request_id]
            # adopt the *generated* tokens' full pages too (the prompt pages
            # were inserted at prefill completion): a multi-turn follow-up
            # that resends this reply as history then hits past the prompt.
            # KV exists for the first num_tokens context tokens — the final
            # sampled token was never fed back, so its page may be partial.
            if self.prefix_cache is not None and self.cache_generated \
                    and len(req.prompt) == req.prompt_len:
                toks = (req.prompt + req.output)[:table.num_tokens]
                self.prefix_cache.insert(toks, table.blocks)
            # the tree's increfs keep adopted pages alive past free_table
            self._release_cache_path(req)
            self.allocator.free_table(self.tables.pop(req.request_id))
        if req in self.running:
            self.running.remove(req)

    def _release_cache_path(self, req: Request) -> None:
        path = self._cache_paths.pop(req.request_id, None)
        if path:
            self.prefix_cache.release(path)

    # -- one iteration ------------------------------------------------------------
    def schedule(self) -> IterationPlan:
        prefill: List[Request] = []
        decode: List[Request] = []
        preempted: List[Request] = []
        cow: List[Tuple[int, int]] = []
        budget = self.max_tokens

        # 1) running decodes first (latency priority), preempting if needed
        for req in list(self.running):
            if budget <= 0:
                break
            if req.request_id not in self.tables:
                continue  # became a preemption victim earlier this iteration
            table = self.tables[req.request_id]
            if not self.allocator.can_append(table, 1) and \
                    self.prefix_cache is not None:
                # reclaim unreferenced cached pages before preempting anyone
                self.prefix_cache.evict(self.allocator.blocks_needed(table, 1))
            if not self.allocator.can_append(table, 1):
                victim = self._preempt_youngest(exclude=req)
                if victim is not None and victim in decode:
                    # victim was granted its decode token earlier this
                    # iteration; rescind it (its pages are gone)
                    decode.remove(victim)
                    budget += 1
                if victim is not None and self.prefix_cache is not None \
                        and not self.allocator.can_append(table, 1):
                    # the victim's prompt pages may survive only as
                    # tree-held (refcount-1) cache pages — reclaim them
                    # before giving up on this request too
                    self.prefix_cache.evict(
                        self.allocator.blocks_needed(table, 1))
                if victim is None or not self.allocator.can_append(table, 1):
                    # preempt this request itself
                    self._preempt(req)
                    preempted.append(req)
                    continue
                preempted.append(victim)
            cow.extend(self.allocator.append_tokens(table, 1))
            decode.append(req)
            budget -= 1

        # 2) admit waiting requests (FCFS) into leftover budget + memory
        while (self.waiting and budget > 0
               and len(self.running) < self.max_running):
            req = self.waiting[0]
            path: list = []
            cached = 0
            if self.prefix_cache is not None and \
                    len(req.prompt) == req.prompt_len:
                # cap at prompt_len-1: the last prompt token must be computed
                # for the first-token logits even if fully cached
                path = self.prefix_cache.match(req.prompt,
                                               max_tokens=req.prompt_len - 1)
                if self.prefix_importer is not None and self.prefix_importer(
                        req.prompt,
                        len(path) * self.allocator.block_size) > 0:
                    # adopt-imported-pages path: a peer published pages
                    # extending our local match and they were just grafted
                    # into the local tree — re-match over them
                    path = self.prefix_cache.match(
                        req.prompt, max_tokens=req.prompt_len - 1)
                cached = len(path) * self.allocator.block_size
            need_tokens = req.prompt_len - cached
            if need_tokens > budget:
                # chunked-prefill stand-in: a prompt larger than the whole
                # iteration budget may run alone when the instance is
                # otherwise idle — else huge prompts head-of-line-block
                # forever (same policy as the DistKV simulator)
                solo_ok = not decode and not prefill and \
                    budget == self.max_tokens
                if not solo_ok:
                    break
            # lock before checking supply so eviction cannot claim the
            # matched pages out from under us
            table = BlockTable()
            if path:
                table.blocks = self.prefix_cache.lock(path)
                table.num_tokens = cached
            short = (self.allocator.blocks_needed(table, need_tokens)
                     - (self.allocator.num_free - self.watermark_blocks))
            if short > 0 and self.prefix_cache is not None:
                self.prefix_cache.evict(short)
            if (self.allocator.blocks_needed(table, need_tokens)
                    > self.allocator.num_free - self.watermark_blocks):
                if path:  # roll back the lock
                    self.prefix_cache.release(path)
                    self.allocator.free_table(table)
                break
            self.waiting.pop(0)
            cow.extend(self.allocator.append_tokens(table, need_tokens))
            self.tables[req.request_id] = table
            if path:
                self._cache_paths[req.request_id] = path
            req.num_cached_tokens = cached
            if self.prefix_cache is not None:
                self.prefix_cache.record_admission(req.prompt_len, cached,
                                                   path)
            req.phase = Phase.INITIATION
            self.running.append(req)
            prefill.append(req)
            budget -= need_tokens

        return IterationPlan(prefill=prefill, decode=decode,
                             preempted=preempted, cow=cow)

    def complete_iteration(self, plan: IterationPlan, now: float) -> List[Request]:
        """Mark phases + retire finished requests. Returns finished list."""
        finished = []
        for req in plan.prefill:
            req.phase = Phase.INCREMENT
            if req.first_token_time is None:
                req.first_token_time = now
            # adopt the prompt's full pages into the radix tree as soon as
            # their KV exists — waiting for request completion would make
            # every member of a same-prefix burst recompute the shared
            # prefix (thundering herd)
            if self.prefix_cache is not None and \
                    len(req.prompt) == req.prompt_len and \
                    req.request_id in self.tables:
                self.prefix_cache.insert(
                    req.prompt, self.tables[req.request_id].blocks)
        for req in plan.prefill + plan.decode:
            if req.done:
                self.finish(req, now)
                finished.append(req)
        # preemption budget: a request churning through recomputes is dropped
        # (reported as "preempted-dropped") instead of thrashing forever
        if self.max_preemptions is not None:
            for req in plan.preempted:
                # still in waiting = not re-admitted this very iteration
                if req.preemptions > self.max_preemptions and \
                        req in self.waiting:
                    self.waiting.remove(req)
                    self.finish(req, now, reason="preempted-dropped")
                    finished.append(req)
        return finished

    # -- best-of-n forks ------------------------------------------------------
    def fork_from(self, parent: Request, child: Request) -> BlockTable:
        """COW-fork ``child`` off ``parent`` right after the parent's
        prefill: every prompt page is shared (refcounted; the first write
        into a shared partial page triggers copy-on-write in
        ``append_tokens``) and the child enters decode directly — no second
        prefill. The caller samples the child's first token from the
        parent's prefill logits."""
        table = self.allocator.fork(self.tables[parent.request_id])
        self.tables[child.request_id] = table
        child.prompt = list(parent.prompt)
        child.prompt_len = parent.prompt_len
        child.num_cached_tokens = parent.prompt_len  # nothing recomputed
        child.phase = Phase.INCREMENT
        self.running.append(child)
        return table

    # -- preemption ----------------------------------------------------------------
    def _preempt(self, req: Request) -> None:
        req.phase = Phase.PREEMPTED
        req.preemptions += 1
        # recompute policy: drop pages; generated tokens move into the prompt
        req.prompt = (req.prompt + req.output) if req.prompt else req.prompt
        req.prompt_len = req.context_len
        req.max_new_tokens -= req.n_generated
        req.committed_output.extend(req.output)
        req.output = []
        req.num_cached_tokens = 0  # re-matched at the next admission
        self._release_cache_path(req)
        self.allocator.free_table(self.tables.pop(req.request_id))
        if req in self.running:
            self.running.remove(req)
        self.waiting.insert(0, req)

    def _preempt_youngest(self, exclude: Request) -> Optional[Request]:
        for req in reversed(self.running):
            if req is not exclude:
                self._preempt(req)
                return req
        return None
