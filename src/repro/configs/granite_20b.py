"""Granite-20B code model — llama-arch with MQA [arXiv:2405.04324].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    attention="gqa",
    use_bias=True,
    gated_mlp=False,  # GPT-BigCode lineage keeps biases
)
