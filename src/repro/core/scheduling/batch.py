"""Batch-level scheduling baseline (the pre-ORCA status quo, §III.B C1).

The serving layer hands the engine a whole batch; the engine runs it to
completion (every request decodes until the *longest* one finishes — padding
waste) before results return and the next batch starts. This is the system
ORCA's iteration-level scheduling replaces; the benchmark quantifies the
gap (queueing delay + early-finish waste)."""

from __future__ import annotations

import dataclasses
from typing import List

from repro.core.scheduling.request import Phase, Request


@dataclasses.dataclass
class BatchPlan:
    batch: List[Request]

    @property
    def empty(self) -> bool:
        return not self.batch


class BatchScheduler:
    def __init__(self, *, max_batch: int = 8):
        self.max_batch = max_batch
        self.waiting: List[Request] = []
        self.current: List[Request] = []

    def add_request(self, req: Request) -> None:
        req.phase = Phase.WAITING
        self.waiting.append(req)

    def schedule(self) -> BatchPlan:
        """Next whole batch (only when the previous one fully completed)."""
        if self.current:
            return BatchPlan(self.current)
        self.current = self.waiting[:self.max_batch]
        del self.waiting[:len(self.current)]
        for r in self.current:
            r.phase = Phase.INITIATION
        return BatchPlan(self.current)

    def complete_batch(self, now: float) -> List[Request]:
        done = self.current
        for r in done:
            r.phase = Phase.FINISHED
            r.finish_time = now
        self.current = []
        return done
