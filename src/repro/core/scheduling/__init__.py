from repro.core.scheduling.request import Phase, Request  # noqa: F401
from repro.core.scheduling.iteration import (  # noqa: F401
    CHUNK_POLICIES, IterationPlan, IterationScheduler, PrefillChunk)
from repro.core.scheduling.batch import BatchPlan, BatchScheduler  # noqa: F401
