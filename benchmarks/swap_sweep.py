"""Swap-vs-sacrifice crossover: when is preempted KV worth keeping?

Under memory pressure the scheduler must take pages from a running victim.
``sacrifice`` frees them and re-prefills the whole context later (the
recompute bill grows quadratically with context via the attention term);
``swap`` moves the pages to host memory over a modeled PCIe lane and the
victim later resumes decode with **no re-prefill** (the bill is linear in
pages, paid twice). The crossover is the point where the PCIe round trip
undercuts recompute — short contexts recompute, long contexts swap — and
``auto`` must land on the winning side of it at both operating points:

* ``short`` — 192-token prompts, 96-token decodes on a tight 110-page
  device. Recompute of a ~288-token context costs ~4ms; a round trip of
  its ~18 pages costs ~15ms of PCIe. Sacrifice wins; informational.
* ``long``  — 6144-token prompts, 512-token decodes, 16 requests over
  1200 pages (3 fit; decode growth evicts). Re-prefilling 6k tokens
  costs ~0.7s; swapping its ~390 pages costs ~0.33s round trip. Swap
  must win on throughput AND P99 normalized latency — this is the
  CI-guarded headline.

A second table compares victim policies (lifo/fifo/lru/cost) under swap at
the long point, plus three overlapped rows: ``swap-overlap`` double-buffers
the PCIe DMAs against each iteration's compute, ``swap-overlap-cost`` adds
cost-ranked victims (the CI-guarded headline must beat the serial swap row
on throughput and P99), and ``swap-overlap-spec`` adds speculative early
swap-outs, which must stay ahead of the serial row (early issues replace
demand evictions rather than multiplying them). A traced run proves the
no-re-prefill claim structurally:
a request that swapped out while decoding must never emit another prefill
``req.chunk`` event after its ``sched.swap_in``, and its swap instants
must balance (``validate_swap_balance``).

    PYTHONPATH=src python benchmarks/swap_sweep.py [--smoke]
"""

from __future__ import annotations

import argparse

from repro.core.distkv.netmodel import NetworkModel
from repro.core.scheduling.request import Request
from repro.core.telemetry import to_chrome_trace, validate_swap_balance
from repro.serving.simulator import simulate_paged

BLOCK_SIZE = 16
SWAP_MODES = ("sacrifice", "swap", "auto")
VICTIM_POLICIES = ("lifo", "fifo", "lru", "cost")
# operating points: (n, prompt_len, max_new, arrival_gap_s, device_pages,
# host_pages, token_budget). Deterministic staggered bursts — pressure
# comes from decode growth after admission fills the device.
POINTS = {
    "short": (24, 192, 96, 0.02, 110, 256, 2048),
    "long": (16, 6144, 512, 0.05, 1200, 1536, 4096),
}


def _workload(n: int, prompt_len: int, max_new: int, gap: float):
    return [Request(request_id=i, arrival_time=i * gap, prompt=[],
                    prompt_len=prompt_len, max_new_tokens=max_new)
            for i in range(n)]


def _run_point(point: str, mode: str, *, victim_policy: str = "lifo",
               swap_overlap: bool = False, speculative_swap: bool = False,
               net: NetworkModel | None = None, trace: bool = False):
    n, plen, mnew, gap, blocks, host, btok = POINTS[point]
    return simulate_paged(
        _workload(n, plen, mnew, gap), num_blocks=blocks,
        block_size=BLOCK_SIZE, max_tokens_per_iter=btok, prefix_cache=False,
        host_blocks=0 if mode == "sacrifice" else host,
        swap_mode=mode, victim_policy=victim_policy,
        swap_overlap=swap_overlap, speculative_swap=speculative_swap,
        net=net, trace=trace)


def check_no_reprefill(events) -> list:
    """Structural proof that swap-in resumes decode without re-prefilling.

    For every request whose ``sched.swap_out`` happened while decoding
    (``generated > 0`` ⇒ fully prefilled), no prefill ``req.chunk`` event
    may follow its matching ``sched.swap_in``. Returns problems (empty ⇒
    proven)."""
    swap_ins = {}  # rid -> ts of last decode-phase swap_in
    for e in events:
        if e.cat == "sched" and e.name == "swap_in" \
                and (e.args or {}).get("generated", 0) > 0:
            swap_ins[e.rid] = e.ts
    problems = []
    for e in events:
        if e.cat == "req" and e.name == "chunk" and e.rid in swap_ins \
                and e.ts > swap_ins[e.rid]:
            problems.append(f"rid {e.rid}: prefill chunk at ts={e.ts:.4f} "
                            f"after decode-phase swap_in at "
                            f"ts={swap_ins[e.rid]:.4f}")
    if not swap_ins:
        problems.append("no decode-phase swap_in observed: the proof "
                        "workload exerted no swap pressure")
    return problems


def run(verbose: bool = True, pcie_gbps: float | None = None,
        t_swap_fixed: float | None = None):
    """``pcie_gbps`` / ``t_swap_fixed`` recalibrate the modeled PCIe swap
    lane (defaults: :class:`NetworkModel`); they are recorded in the BENCH
    artifact's config block so a run is reproducible from the json alone."""
    kw = {}
    if pcie_gbps is not None:
        kw["pcie_gbps"] = pcie_gbps
    if t_swap_fixed is not None:
        kw["t_swap_fixed"] = t_swap_fixed
    net = NetworkModel(**kw) if kw else None
    rows = []

    def record(point, system, res, **extra):
        rows.append(dict({
            "point": point,
            "system": system,
            "throughput": res.throughput_tokens_per_s,
            "p99_norm_lat": res.p99_normalized_latency,
            "preemptions": res.preemptions,
            "swapped_out": res.swapped_out,
            "swapped_in": res.swapped_in,
            "swap_cancels": res.swap_cancels,
            "swap_time": res.swap_time,
            "completed": res.completed_frac,
        }, **extra))
        if verbose:
            r = rows[-1]
            print(f"{point:5s} {system:17s} "
                  f"thr={r['throughput']:7.1f} tok/s  "
                  f"p99-norm-lat={r['p99_norm_lat'] * 1e3:7.2f} ms/tok  "
                  f"pre={r['preemptions']:3d} swap={r['swapped_out']:3d}/"
                  f"{r['swapped_in']:3d}  done={r['completed']:.0%}")

    for point in ("short", "long"):
        for mode in SWAP_MODES:
            record(point, mode, _run_point(point, mode, net=net))
    # victim-policy detail under swap at the long point: who gets moved to
    # host matters less than that nobody recomputes, but LRU should not
    # lose to blind stack order and cost should win outright
    for policy in VICTIM_POLICIES:
        record("long", f"swap-{policy}",
               _run_point("long", "swap", victim_policy=policy, net=net))
    # overlapped transfers: same swap traffic, but the PCIe DMAs double-
    # buffer against each iteration's compute — only the surplus past the
    # compute time hits the clock. ``swap-overlap-cost`` (overlap +
    # cost-ranked victims) is the CI-guarded headline; ``swap-overlap-spec``
    # adds speculative early swap-outs on top, which must stay in-band
    # (the early issues replace demand evictions, they must not multiply
    # them).
    record("long", "swap-overlap",
           _run_point("long", "swap", swap_overlap=True, net=net))
    record("long", "swap-overlap-cost",
           _run_point("long", "swap", victim_policy="cost",
                      swap_overlap=True, net=net))
    record("long", "swap-overlap-spec",
           _run_point("long", "swap", victim_policy="cost",
                      swap_overlap=True, speculative_swap=True, net=net))

    # structural no-re-prefill proof on a traced long-point swap run (with
    # overlap + speculation on, so the issue/complete spans are validated)
    res = _run_point("long", "swap", swap_overlap=True,
                     speculative_swap=True, net=net, trace=True)
    problems = check_no_reprefill(res.events)
    problems += validate_swap_balance(to_chrome_trace(res.events))
    rows.append({"point": "long", "system": "proof",
                 "reprefill_problems": problems})
    if verbose:
        print(f"no-re-prefill proof: "
              f"{'OK' if not problems else problems[:3]}")
    return rows


def headline(rows) -> str:
    """The acceptance guard, at the long-context operating point only:
    swap must beat sacrifice on throughput AND P99 normalized latency,
    ``auto`` must agree (it swaps, zero hard preemptions), every request
    must finish, and the traced run must prove no re-prefill after a
    decode-phase swap-in. The short point is the other side of the
    crossover (sacrifice wins) and is reported, not gated — its margin is
    a few ms of PCIe and too thin to gate CI on."""

    def pick(point, system):
        return next(r for r in rows if r["point"] == point
                    and r["system"] == system)

    sac, swp, auto = (pick("long", m) for m in SWAP_MODES)
    ovl = pick("long", "swap-overlap-cost")
    spec = pick("long", "swap-overlap-spec")
    proof = pick("long", "proof")["reprefill_problems"]
    ok = (swp["throughput"] > sac["throughput"]
          and swp["p99_norm_lat"] < sac["p99_norm_lat"]
          and swp["swapped_out"] > 0
          and auto["swapped_out"] > 0 and auto["preemptions"] == 0
          # overlapped + cost-ranked must not lose to the serial model —
          # hiding PCIe behind compute can only shrink the makespan
          and ovl["throughput"] >= swp["throughput"]
          and ovl["p99_norm_lat"] <= swp["p99_norm_lat"]
          # speculative early issues must replace demand evictions, not
          # multiply them: the row stays ahead of the serial swap model
          and spec["throughput"] >= swp["throughput"]
          and all(r["completed"] >= sac["completed"]
                  for r in (swp, auto, ovl, spec))
          and not proof)
    s_sac, s_swp = pick("short", "sacrifice"), pick("short", "swap")
    return (f"swap_crossover: long thr {sac['throughput']:.0f}->"
            f"{swp['throughput']:.0f} tok/s "
            f"(+{swp['throughput'] / sac['throughput'] - 1:.1%}), "
            f"overlap+cost {ovl['throughput']:.0f} tok/s "
            f"(+{ovl['throughput'] / swp['throughput'] - 1:.1%} vs serial), "
            f"p99-norm-lat {sac['p99_norm_lat'] * 1e3:.1f}->"
            f"{swp['p99_norm_lat'] * 1e3:.1f}->"
            f"{ovl['p99_norm_lat'] * 1e3:.1f} ms/tok; "
            f"short thr {s_sac['throughput']:.0f} (sacrifice) vs "
            f"{s_swp['throughput']:.0f} (swap) tok/s; "
            f"no-re-prefill {'proven' if not proof else 'VIOLATED'} "
            f"guard={'ok' if ok else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI run (the sweep is already CI-sized); exits "
                         "nonzero unless swap beats sacrifice at the "
                         "long-context point and the no-re-prefill proof "
                         "holds")
    args = ap.parse_args()
    rows = run()
    line = headline(rows)
    print(line)
    if args.smoke and "FAIL" in line:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
