"""DeepSeek-V2 236B — MLA + fine-grained MoE [arXiv:2405.04434].

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400; MLA kv_lora_rank=512;
2 shared + 160 routed experts, top-6; first layer dense (d_ff=12288 per the
paper's dense-layer intermediate size).
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,  # MLA: per-head K/V are decompressed from the shared latent
    head_dim=128,
    d_ff=12288,  # dense MLP hidden (first_k_dense layers)
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    first_k_dense=1,
)
