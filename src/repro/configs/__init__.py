"""Architecture config registry.

Every assigned architecture is a module in this package exporting ``CONFIG``.
``get_config(arch_id)`` resolves dashed ids (``--arch deepseek-v2-236b``) to the
module name, and ``smoke_config(arch_id)`` returns the reduced variant used by
the per-arch smoke tests (2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A composable architecture description covering all assigned families."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation for the config numbers

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention variants -------------------------------------------------
    attention: str = "gqa"  # gqa | mla | none
    sliding_window: Optional[int] = None  # SWA width (tokens) or None
    # trained context limit; serving sizes per-sequence block tables from it
    # (None = unbounded, the engine falls back to its page supply)
    max_seq_len: Optional[int] = None
    # Hymba-style: every Nth layer uses global attention, others sliding window.
    global_attn_every: Optional[int] = None
    rope_theta: float = 10_000.0
    use_bias: bool = False

    # --- MLA (deepseek-v2) ---------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden size (may differ from dense d_ff)
    first_k_dense: int = 0  # first K layers use the dense MLP (deepseek-v2: 1)
    capacity_factor: float = 1.25

    # --- SSM (mamba2 SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # --- enc-dec (seamless) ----------------------------------------------------
    encoder_layers: int = 0  # >0 => encoder-decoder

    # --- modality frontend (stubbed: precomputed embeddings) ------------------
    frontend: str = "none"  # none | vision | audio
    num_media_tokens: int = 0  # patches / audio frames prepended to the text

    gated_mlp: bool = True  # SwiGLU (3 mats) vs plain GeLU MLP (2 mats)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # compute the unembedding in float32. bf16 logits round near-ties onto
    # the same value, so greedy argmax can legitimately differ between two
    # correct implementations; fp32 logits make greedy decoding comparable
    # across engine/oracle (see tests/test_engine.py).
    logits_fp32: bool = False

    # ------------------------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def ssm_heads(self) -> int:
        d_inner = self.ssm_expand * self.d_model
        return d_inner // self.ssm_head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True when 524k decode is sub-quadratic / bounded-memory."""
        if self.family in ("ssm",):
            return True
        if self.is_hybrid:
            return True  # attention part is sliding-window (global layers excepted)
        return self.sliding_window is not None

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def n_params(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        return _count(self, active_only=False)

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts)."""
        return _count(self, active_only=True)


def _count(cfg: ArchConfig, active_only: bool) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d

    def attn_params() -> int:
        if cfg.attention == "mla":
            qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            p = d * cfg.kv_lora_rank + d * cfg.qk_rope_head_dim  # kv_a + k_rope
            if cfg.q_lora_rank:
                p += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * qd
            else:
                p += d * cfg.num_heads * qd
            p += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
            p += cfg.num_heads * cfg.v_head_dim * d  # o_proj
            return p
        if cfg.attention == "none":
            return 0
        q = d * cfg.num_heads * cfg.head_dim
        kv = 2 * d * cfg.num_kv_heads * cfg.head_dim
        o = cfg.num_heads * cfg.head_dim * d
        return q + kv + o

    def mlp_params(ff: int) -> int:
        return (3 if cfg.gated_mlp else 2) * d * ff

    def ssm_params() -> int:
        din = cfg.ssm_d_inner
        h = cfg.ssm_heads
        g = cfg.ssm_groups
        n = cfg.ssm_state
        in_proj = d * (2 * din + 2 * g * n + h)
        conv = cfg.ssm_conv_width * (din + 2 * g * n)
        out = din * d
        return in_proj + conv + out + 2 * h  # + A_log, D

    per_layer = 0
    for layer in range(cfg.num_layers):
        p = 0
        if cfg.family == "ssm":
            p += ssm_params()
        elif cfg.is_hybrid:
            p += attn_params() + ssm_params()
        else:
            p += attn_params()
        if cfg.is_moe and layer >= cfg.first_k_dense:
            e = (cfg.num_shared_experts + cfg.moe_top_k) if active_only else (
                cfg.num_shared_experts + cfg.num_experts)
            p += e * mlp_params(cfg.moe_d_ff)
            p += d * cfg.num_experts  # router
        elif cfg.d_ff:
            p += mlp_params(cfg.d_ff)
        per_layer += p
    total += per_layer
    # encoder (dense attention + mlp), cross-attention in decoder
    if cfg.is_encdec:
        enc = cfg.encoder_layers * (
            4 * d * cfg.num_heads * cfg.head_dim + mlp_params(cfg.d_ff))
        cross = cfg.num_layers * 4 * d * cfg.num_heads * cfg.head_dim
        total += enc + cross
    return total


# ----------------------------------------------------------------------------
# Input shapes (assigned)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


ARCH_IDS = [
    "hymba-1.5b",
    "deepseek-v2-236b",
    "llama4-scout-17b-a16e",
    "seamless-m4t-medium",
    "mamba2-1.3b",
    "granite-20b",
    "command-r-35b",
    "mistral-large-123b",
    "internvl2-26b",
    "h2o-danube-1.8b",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "p")


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ArchConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    cfg = get_config(arch_id)
    heads = min(cfg.num_heads, 4) or 0
    kv = min(cfg.num_kv_heads, heads) if cfg.num_kv_heads else 0
    updates = dict(
        num_layers=2,
        d_model=256,
        num_heads=heads,
        num_kv_heads=max(kv, 1) if cfg.attention != "none" else 0,
        head_dim=64 if cfg.attention != "none" else 0,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        encoder_layers=2 if cfg.is_encdec else 0,
        num_media_tokens=8 if cfg.frontend != "none" else 0,
    )
    if cfg.attention == "mla":
        updates.update(kv_lora_rank=64, q_lora_rank=96, qk_nope_head_dim=32,
                       qk_rope_head_dim=16, v_head_dim=32)
    if cfg.is_moe:
        # capacity_factor=E/topk => no token drops, so smoke decode matches
        # the teacher-forced oracle exactly
        updates.update(num_experts=4, moe_top_k=min(cfg.moe_top_k, 2), moe_d_ff=128,
                       num_shared_experts=min(cfg.num_shared_experts, 1),
                       capacity_factor=4.0)
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.sliding_window:
        updates.update(sliding_window=64)
    return dataclasses.replace(cfg, **updates)


def input_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input of ``shape``.

    No device allocation happens here; these feed ``jax.jit(...).lower``.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    media = {}
    if cfg.frontend != "none":
        media["media"] = sd((b, cfg.num_media_tokens, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        out = {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
        if cfg.is_encdec:
            out["encoder_tokens"] = sd((b, s // 4), i32)
        out.update(media)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sd((b, s), i32)}
        if cfg.is_encdec:
            out["encoder_tokens"] = sd((b, s // 4), i32)
        out.update(media)
        return out
    # decode: one new token against a cache of seq_len
    out = {
        "tokens": sd((b, 1), i32),
        "positions": sd((b,), i32),
    }
    return out
