from repro.core.distkv.gmanager import GManager, Heartbeat, DebtEntry  # noqa: F401
from repro.core.distkv.prefixshare import (  # noqa: F401
    PrefixShareBoard, PublishedPage)
from repro.core.distkv.rmanager import RManager, RBlock, SeqKV  # noqa: F401
from repro.core.distkv.dist_attention import (  # noqa: F401
    dist_attention, dist_attention_ref, micro_attention_partial,
    merge_partials, merge_partials_tree)
