"""KVPageLayout: the per-arch KV page-payload schema.

One page of KV cache is a fixed number of token slots, but *what a token
slot holds* depends on the attention flavor:

* ``gqa`` — two pools of per-head tensors: ``k``/``v`` with token shape
  ``(num_kv_heads, head_dim)`` each (also plain MHA / SWA).
* ``mla`` — two pools of *shared latent* vectors (DeepSeek-V2 Multi-head
  Latent Attention): ``ckv`` with token shape ``(kv_lora_rank,)`` and
  ``krope`` with token shape ``(qk_rope_head_dim,)`` — ~10x fewer bytes
  per token than the equivalent GQA layout.

Every subsystem that sizes, moves, or shares KV pages derives its numbers
from this object instead of assuming the GQA shape:

* the engine allocates its device/host pools from :meth:`pool_shapes`;
* the allocator exposes :attr:`page_bytes` for cost models;
* ``NetworkModel`` charges swap / peer-copy / adoption from the layout's
  actual bytes-per-page (compressed layouts transfer ~10x less);
* the share board, remote leases, and KV handoff carry :attr:`schema` and
  reject mismatched layouts loudly instead of corrupting pages.

The schema tag (e.g. ``"mla:ckv512+krope64:bf16"``) is the wire contract:
two instances may exchange page payloads iff their tags are equal.

This module is dependency-free (no jax) so the sim / cost-model side can
use it without pulling in the numerics stack.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

# bytes per element for the dtype names ArchConfig uses
_DTYPE_BYTES = {
    "bfloat16": 2, "float16": 2, "float32": 4, "float64": 8,
    "int8": 1, "uint8": 1, "fp8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1,
}


def _dtype_nbytes(name: str) -> int:
    try:
        return _DTYPE_BYTES[name]
    except KeyError:
        raise ValueError(f"unknown KV dtype {name!r}") from None


@dataclasses.dataclass(frozen=True)
class PoolSpec:
    """One physical page pool: ``name`` plus the per-token payload shape.

    A pool array is ``(num_layers, num_pages, page_size, *token_shape)``;
    every page-granular operation (COW, swap, spill, export) indexes only
    the pages axis, so the trailing ``token_shape`` is opaque to it.
    """

    name: str
    token_shape: Tuple[int, ...]

    @property
    def token_elems(self) -> int:
        return math.prod(self.token_shape)


@dataclasses.dataclass(frozen=True)
class KVPageLayout:
    """Attention flavor + page pool specs + dtype = the page schema."""

    flavor: str  # "gqa" | "mla"
    pools: Tuple[PoolSpec, ...]
    dtype_name: str
    num_layers: int

    @classmethod
    def from_arch(cls, cfg) -> "KVPageLayout":
        """Derive the layout from an ``ArchConfig``."""
        if getattr(cfg, "attention", None) == "mla":
            pools = (PoolSpec("ckv", (cfg.kv_lora_rank,)),
                     PoolSpec("krope", (cfg.qk_rope_head_dim,)))
            return cls("mla", pools, cfg.dtype, cfg.num_layers)
        pools = (PoolSpec("k", (cfg.num_kv_heads, cfg.head_dim)),
                 PoolSpec("v", (cfg.num_kv_heads, cfg.head_dim)))
        return cls("gqa", pools, cfg.dtype, cfg.num_layers)

    # -- byte accounting ----------------------------------------------------
    @property
    def dtype_bytes(self) -> int:
        return _dtype_nbytes(self.dtype_name)

    @property
    def bytes_per_token_layer(self) -> int:
        """KV bytes one token occupies in one layer, summed over pools."""
        return sum(p.token_elems for p in self.pools) * self.dtype_bytes

    @property
    def bytes_per_token(self) -> int:
        """KV bytes one token occupies across the whole stack."""
        return self.bytes_per_token_layer * self.num_layers

    def page_bytes(self, page_size: int) -> int:
        """Wire/HBM bytes of one logical page (all layers, all pools)."""
        return self.bytes_per_token * page_size

    # -- pool geometry ------------------------------------------------------
    def pool_shapes(self, num_pages: int, page_size: int):
        """Physical array shape per pool: (L, num_pages, page_size, *token)."""
        return tuple((self.num_layers, num_pages, page_size) + p.token_shape
                     for p in self.pools)

    # -- wire contract ------------------------------------------------------
    @property
    def schema(self) -> str:
        """Canonical schema tag, e.g. ``"gqa:k8x64+v8x64:bf16"``.

        Equal tags <=> page payloads are interchangeable. Carried on board
        publishes, remote leases, and handoff payloads; every import side
        validates it and raises instead of adopting foreign bytes.
        """
        pools = "+".join(
            f"{p.name}{'x'.join(str(d) for d in p.token_shape)}"
            for p in self.pools)
        short = {"bfloat16": "bf16", "float16": "f16", "float32": "f32"}
        return f"{self.flavor}:{pools}:{short.get(self.dtype_name, self.dtype_name)}"


def check_schema(expected: str, got, *, where: str) -> None:
    """Loud layout-mismatch guard used by every page-payload import path."""
    if got is not None and got != expected:
        raise ValueError(
            f"KV layout schema mismatch at {where}: local layout is "
            f"{expected!r} but payload/peer carries {got!r}; refusing to "
            "adopt foreign page bytes (would corrupt pages)")
