"""Chain selection (paper §II): Dijkstra baseline + NSGA-II, with property
tests for the NSGA-II invariants."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.chain import (Chain, ChainSequenceProblem, decode_chain,
                              find_best_chain, hypervolume_2d, knee_chain,
                              latency_throughput_tradeoff, make_fleet)
from repro.core.chain.nsga2 import (Individual, crowding_distance,
                                    fast_non_dominated_sort, nsga2)
from repro.core.chain.registry import Fleet, ServerInfo


# -- baseline ---------------------------------------------------------------

def test_dijkstra_single_server():
    fleet = Fleet(4, [ServerInfo(0, 0, 4, throughput=2.0, latency=0.1)])
    chain = find_best_chain(fleet)
    assert len(chain) == 1
    assert chain.total_time == pytest.approx(0.1 + 4 / 2.0)


def test_dijkstra_prefers_fast_single_hop_over_many_hops():
    servers = [
        ServerInfo(0, 0, 8, throughput=10.0, latency=0.05),  # spans all
        ServerInfo(1, 0, 4, throughput=100.0, latency=0.2),
        ServerInfo(2, 4, 8, throughput=100.0, latency=0.2),
    ]
    chain = find_best_chain(Fleet(8, servers))
    # single server: 0.05 + 0.8 = 0.85 < two hops: 0.4 + 0.08 = 0.48 -> two!
    assert len(chain) == 2
    assert chain.total_time == pytest.approx(0.4 + 8 / 100.0)


def test_dijkstra_optimality_brute_force():
    """Exhaustive check on a small random fleet."""
    import itertools
    fleet = make_fleet(6, 7, seed=3)
    best = find_best_chain(fleet).total_time

    def brute(block, elapsed):
        if block == fleet.num_blocks:
            return elapsed
        out = float("inf")
        for s in fleet.covering(block):
            for end in range(block + 1, s.end_block + 1):
                out = min(out, brute(end, elapsed + s.latency +
                                     s.compute_time(end - block)))
        return out

    assert best == pytest.approx(brute(0, 0.0))


def test_max_throughput_mode():
    fleet = make_fleet(12, 14, seed=5)
    chain = find_best_chain(fleet, mode="max_throughput")
    base = find_best_chain(fleet)
    assert chain.bottleneck_throughput >= base.bottleneck_throughput


# -- NSGA-II invariants -------------------------------------------------------

def _mk(f, cv=0.0):
    return Individual(x=np.zeros(1, np.int8), f=np.asarray(f, float),
                      cv=cv)


def test_non_dominated_sort_known_case():
    pop = [_mk([1, 1]), _mk([2, 2]), _mk([1, 2]), _mk([2, 1]),
           _mk([0.5, 3])]
    fronts = fast_non_dominated_sort(pop)
    assert set(fronts[0]) == {0, 4}   # (1,1) and (0.5,3) are non-dominated
    assert set(fronts[1]) == {2, 3}
    assert set(fronts[2]) == {1}


def test_constraint_domination_feasible_first():
    pop = [_mk([100, 100], cv=0.0), _mk([0, 0], cv=1.0)]
    fronts = fast_non_dominated_sort(pop)
    assert fronts[0] == [0]


def test_crowding_extremes_infinite():
    pop = [_mk([0, 3]), _mk([1, 2]), _mk([2, 1]), _mk([3, 0])]
    front = [0, 1, 2, 3]
    crowding_distance(pop, front)
    assert pop[0].crowding == np.inf and pop[3].crowding == np.inf
    assert 0 < pop[1].crowding < np.inf


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000))
def test_nsga2_front_is_mutually_nondominated(seed):
    """Property: no member of the returned Pareto set dominates another."""
    def evaluate(x):
        # two competing objectives over bits: ones vs leading zeros
        f0 = float(x.sum())
        f1 = float(len(x) - x.sum() + (x[0] * 3))
        return np.array([f0, f1]), 0.0

    res = nsga2(evaluate, n_var=12, pop_size=20, generations=10, seed=seed)
    front = res.pareto
    for a in front:
        for b in front:
            if a is b:
                continue
            assert not (np.all(a.f <= b.f) and np.any(a.f < b.f))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_tradeoff_chains_cover_all_blocks(seed):
    fleet = make_fleet(10, 12, seed=seed % 100)
    res = latency_throughput_tradeoff(fleet, pop_size=30, generations=15,
                                      seed=seed)
    assert res.chains, "NSGA-II produced no feasible chain"
    for chain in res.chains:
        covered = []
        for s, a, b in chain:
            assert s.start_block <= a and b <= s.end_block
            covered.extend(range(a, b))
        assert covered == list(range(fleet.num_blocks))


def test_knee_chain_is_valid():
    fleet = make_fleet(12, 16, seed=9)
    res = latency_throughput_tradeoff(fleet, pop_size=40, generations=20,
                                      seed=0)
    knee = knee_chain(res)
    assert knee is not None
    assert knee.total_time > 0


def test_hypervolume_2d():
    pts = np.array([[1.0, 2.0], [2.0, 1.0]])
    ref = np.array([3.0, 3.0])
    # (3-1)*(3-2) + (3-2)*(2-1) = 2 + 1 = 3
    assert hypervolume_2d(pts, ref) == pytest.approx(3.0)
    assert hypervolume_2d(np.array([[4.0, 4.0]]), ref) == 0.0
