"""Per-iteration metrics registry and shared percentile helper.

The registry is the numeric companion to the event tracer: where the
tracer answers *why* (which request triggered the preemption), the metrics
timeline answers *how much over time* (KV utilization, backlog tokens,
budget fill, hit rates) — one row per engine ``step()``, exportable as CSV
or JSON for plotting.

``percentile`` is also the single home for the nearest-rank percentile
used by ``ServiceStats`` and ``SimResult`` (previously hand-rolled in
both, with undefined behavior on empty input).
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile with defined small-n behavior.

    Returns ``sorted(values)[min(n - 1, int(q / 100 * n))]`` — the same
    clamped-index convention the serving stats always used — and ``inf``
    for empty input (a percentile over nothing is an unmet SLO, not a
    crash). ``q`` outside [0, 100] is clamped; indices never go negative.
    """
    n = len(values)
    if n == 0:
        return math.inf
    q = min(100.0, max(0.0, q))
    idx = min(n - 1, int(q / 100.0 * n))
    return sorted(values)[idx]


class Histogram:
    """A value reservoir summarized by count/sum/min/max and nearest-rank
    percentiles. Unbounded on purpose — per-run observation counts here
    are request-scale (thousands), not token-scale."""

    __slots__ = ("values",)

    def __init__(self):
        self.values: List[float] = []

    def observe(self, v: float) -> None:
        self.values.append(v)

    def summary(self) -> Dict[str, float]:
        vs = self.values
        if not vs:
            return {"count": 0}
        return {
            "count": len(vs),
            "sum": sum(vs),
            "min": min(vs),
            "max": max(vs),
            "p50": percentile(vs, 50),
            "p90": percentile(vs, 90),
            "p99": percentile(vs, 99),
        }


class MetricsRegistry:
    """Counters (cumulative), gauges (last value), histograms (reservoir),
    snapshotted into a timeline row per iteration.

    Like the tracer, the registry is held as ``None`` when telemetry is
    off — callers guard with one attribute test, so the disabled path
    allocates nothing.
    """

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.timeline: List[Dict[str, float]] = []

    def count(self, name: str, delta: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.observe(value)

    def snapshot(self, ts: float, iteration: int) -> Dict[str, float]:
        """Append one timeline row: current gauges + cumulative counters."""
        row: Dict[str, float] = {"ts": ts, "iteration": iteration}
        row.update(self.gauges)
        row.update(self.counters)
        self.timeline.append(row)
        return row

    def rows(self) -> List[Dict[str, float]]:
        return self.timeline

    def summaries(self) -> Dict[str, Dict[str, float]]:
        return {name: h.summary() for name, h in self.histograms.items()}
