from repro.core.distkv.gmanager import GManager, Heartbeat, DebtEntry  # noqa: F401
from repro.core.distkv.netmodel import NetworkModel  # noqa: F401
from repro.core.distkv.prefixshare import (  # noqa: F401
    PrefixShareBoard, PublishedPage)
from repro.core.distkv.rmanager import (  # noqa: F401
    RManager, RBlock, RemoteLease, SeqKV)
from repro.core.distkv.dist_attention import (  # noqa: F401
    attention_partial, dist_attention, dist_attention_ref,
    micro_attention_partial, merge_partials, merge_partials_tree)
