"""Paper Fig. 10: DistKV-LLM (gManager/rManager borrowing) vs vanilla paged
instances, sweeping the long-request fraction (1% / 5% / 10%).

Two regimes:
* long context FITS one instance  -> DistKV reduces preemption/queueing;
* long context EXCEEDS one instance -> the baseline must reject; DistKV is
  the only system that serves those requests at all (completion rate).

A third column replays the same trace through the LLMService front-end over
a single pooled-memory SimBackend (all instances' blocks in one allocator)
— the upper bound DistKV's borrowing approaches."""

from __future__ import annotations

from repro.serving.api import LLMService
from repro.serving.simulator import SimBackend, make_workload, simulate_distkv

N_INSTANCES = 4


def _pooled(wl, blocks_per_instance: int):
    """Single pooled instance with the cluster's total KV memory, fronted by
    LLMService (what perfect borrowing would look like)."""
    svc = LLMService(SimBackend(
        num_blocks=N_INSTANCES * blocks_per_instance, block_size=16,
        max_running=256))
    _, stats = svc.replay(wl())
    return stats


def run(n_requests: int = 240, verbose: bool = True):
    out = []
    for regime, long_len, bpi in (("fits", 10_000, 800),
                                  ("exceeds", 20_000, 800)):
        for lf in (0.01, 0.05, 0.10):
            wl = lambda: make_workload(n_requests, rate=12.0,
                                       dist="sharegpt", seed=1,
                                       long_frac=lf, long_len=long_len,
                                       max_len=2048)
            rd = simulate_distkv(wl(), borrow=True, blocks_per_instance=bpi,
                                 n_instances=N_INSTANCES)
            rn = simulate_distkv(wl(), borrow=False, blocks_per_instance=bpi,
                                 n_instances=N_INSTANCES)
            pooled = _pooled(wl, bpi)
            row = dict(regime=regime, long_frac=lf,
                       distkv_thr=rd.throughput_tokens_per_s,
                       distkv_done=rd.completed_frac,
                       local_thr=rn.throughput_tokens_per_s,
                       local_done=rn.completed_frac,
                       local_rejected=rn.rejected,
                       local_preempt=rn.preemptions,
                       pooled_thr=pooled.throughput_tokens_per_s,
                       pooled_done=pooled.completed_frac,
                       gain=rd.throughput_tokens_per_s /
                       max(rn.throughput_tokens_per_s, 1e-9))
            out.append(row)
            if verbose:
                print(f"[{regime:7s}] long={lf:4.0%}: "
                      f"DistKV {row['distkv_thr']:6.0f} tok/s "
                      f"(done {row['distkv_done']:.0%}) | "
                      f"local {row['local_thr']:6.0f} tok/s "
                      f"(done {row['local_done']:.0%}, "
                      f"rej {row['local_rejected']}, "
                      f"pre {row['local_preempt']}) | "
                      f"pooled {row['pooled_thr']:6.0f} tok/s "
                      f"(done {row['pooled_done']:.0%}) | "
                      f"gain {row['gain']:.2f}x")
    return out


if __name__ == "__main__":
    run()
