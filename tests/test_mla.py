"""Layout-agnostic KV pages + matrix-absorbed MLA decode.

Covers the PR's acceptance criteria and satellites: ``KVPageLayout``
schema derivation for GQA and MLA arches (pool shapes, page bytes, the
>=5x latent-KV compression on the full deepseek-v2 geometry),
layout-true network charges so swap/borrow decisions see the real wire
bytes (satellite 2), loud schema-mismatch rejection on every
page-payload exchange path — board publish, zero-copy lease grant,
payload import, KV handoff install, router prefix_share wiring
(satellite 1) — a cluster drain property over both layouts under random
share settings (satellite 3), and the MLA engine ACCEPTANCE proofs:
matrix-absorbed decode over latent ``ckv``/``krope`` pages is
token-identical to the fp32 decompress-then-GQA oracle, including a
host swap round trip and a zero-copy borrowed prefix."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_config
from repro.core.distkv import GManager, NetworkModel, RManager, RemoteLease
from repro.core.distkv.prefixshare import PrefixShareBoard
from repro.core.paging import BlockAllocator, KVPageLayout, check_schema
from repro.core.scheduling import Phase, Request
from repro.models import Model
from repro.serving.engine import EngineConfig, PagedEngine
from repro.serving.simulator import SimBackend, make_shared_prefix_workload

PS = 8  # page size used throughout

GQA_SMOKE = KVPageLayout.from_arch(smoke_config("h2o-danube-1.8b"))
MLA_SMOKE = KVPageLayout.from_arch(smoke_config("deepseek-v2-236b"))


# -- KVPageLayout: schema derivation + byte accounting -------------------------

def test_layout_gqa_schema_and_pools():
    lay = GQA_SMOKE
    assert lay.flavor == "gqa"
    assert lay.schema == "gqa:k4x64+v4x64:bf16"
    assert lay.bytes_per_token_layer == 2 * (4 * 64) * 2  # two pools, bf16
    assert lay.page_bytes(PS) == lay.bytes_per_token * PS
    shapes = lay.pool_shapes(6, PS)
    assert shapes == ((lay.num_layers, 6, PS, 4, 64),) * 2


def test_layout_mla_schema_and_pools():
    lay = MLA_SMOKE
    assert lay.flavor == "mla"
    assert lay.schema == "mla:ckv64+krope16:bf16"
    # one shared latent per token, NOT per kv head: ckv + krope elems
    assert lay.bytes_per_token_layer == (64 + 16) * 2
    (ca, cb) = lay.pool_shapes(6, PS)
    assert ca == (lay.num_layers, 6, PS, 64)   # ckv pool
    assert cb == (lay.num_layers, 6, PS, 16)   # krope pool


def test_full_deepseek_latent_compression_ratio():
    """ACCEPTANCE: the MLA layout stores >=5x fewer KV bytes per token
    than the equivalent GQA layout on the real deepseek-v2-236b geometry
    (it is ~57x: 2*128*128 head elems vs a 512+64 shared latent)."""
    cfg = get_config("deepseek-v2-236b")
    mla = KVPageLayout.from_arch(cfg)
    gqa = KVPageLayout.from_arch(dataclasses.replace(cfg, attention="gqa"))
    assert mla.schema == "mla:ckv512+krope64:bf16"
    ratio = gqa.bytes_per_token / mla.bytes_per_token
    assert ratio == pytest.approx((2 * 128 * 128) / (512 + 64))
    assert ratio >= 5.0


def test_check_schema_guard():
    check_schema("mla:ckv64+krope16:bf16", None, where="x")  # unknown: pass
    check_schema("mla:ckv64+krope16:bf16", "mla:ckv64+krope16:bf16",
                 where="x")
    with pytest.raises(ValueError, match="schema mismatch at lease read"):
        check_schema(MLA_SMOKE.schema, GQA_SMOKE.schema, where="lease read")


def test_layout_rejects_unknown_dtype():
    lay = dataclasses.replace(MLA_SMOKE, dtype_name="complex128")
    with pytest.raises(ValueError, match="dtype"):
        _ = lay.bytes_per_token


# -- satellite 2: network charges follow the layout's true page bytes ----------

def test_netmodel_charges_layout_bytes():
    base = NetworkModel()
    gqa_pb = GQA_SMOKE.page_bytes(PS)
    mla_pb = MLA_SMOKE.page_bytes(PS)
    assert mla_pb < gqa_pb
    # the per-call override reprices the transfer, leaving the default
    # (and thus the committed swap-sweep baselines) untouched
    assert base.swap_time(4, page_bytes=mla_pb) \
        < base.swap_time(4, page_bytes=gqa_pb) < base.swap_time(4)
    assert base.peer_copy_time(4, page_bytes=mla_pb) \
        < base.peer_copy_time(4, page_bytes=gqa_pb)
    net = NetworkModel.for_layout(MLA_SMOKE, PS)
    assert net.page_bytes == mla_pb
    assert net.swap_time(4) == base.swap_time(4, page_bytes=mla_pb)


def test_prefer_borrow_flips_for_compressed_layout():
    """The copy-vs-borrow break-even moves when a page is ~10x cheaper to
    copy: a decode length where GQA-priced pages favor borrowing must
    favor copying once the same decision is priced at MLA latent bytes."""
    net = NetworkModel()
    gqa_pb = get_config("deepseek-v2-236b").num_layers * 2 * 128 * 128 * 2 * 16
    mla_pb = KVPageLayout.from_arch(get_config("deepseek-v2-236b")) \
        .page_bytes(16)
    flipped = [t for t in (64, 256, 1024, 4096)
               if net.prefer_borrow(32, 16, est_decode_tokens=t,
                                    page_bytes=gqa_pb)
               and not net.prefer_borrow(32, 16, est_decode_tokens=t,
                                         page_bytes=mla_pb)]
    assert flipped, "some decode length must flip from borrow to copy"


def test_allocator_page_bytes_property():
    a = BlockAllocator(8, PS, layout=MLA_SMOKE)
    assert a.page_bytes == MLA_SMOKE.page_bytes(PS)
    assert BlockAllocator(8, PS).page_bytes is None  # layout-less sim


def test_sim_backend_swap_decider_sees_layout_bytes():
    """A swap that is not worth its PCIe time at default (GQA-sized) page
    bytes becomes worth it when the pages are MLA latents."""
    kw = dict(num_blocks=16, block_size=16, swap_mode="auto",
              host_blocks=16)
    fat = SimBackend(**kw)  # default page_bytes: ~13 MB
    thin = SimBackend(layout=KVPageLayout.from_arch(
        get_config("deepseek-v2-236b")), **kw)
    assert thin.kv_page_bytes < fat.swap_net.page_bytes
    req = Request(0, 0.0, [], prompt_len=160, max_new_tokens=8)
    req.prefilled_len = 160  # the decider prices the COMPUTED context
    n_pages = 10
    flips = thin._swap_worth_it(req, n_pages) \
        and not fat._swap_worth_it(req, n_pages)
    assert flips, "layout bytes must flip the swap-vs-recompute decision"


# -- satellite 1: every payload exchange path refuses foreign layouts ----------

def test_board_refuses_mixed_schema_publish():
    board = PrefixShareBoard()
    board.publish(0, list(range(PS)), [None], PS, schema=GQA_SMOKE.schema)
    assert board.schema == GQA_SMOKE.schema
    before = board.num_pages
    with pytest.raises(ValueError, match="schema mismatch on one board"):
        board.publish(1, list(range(100, 100 + PS)), [None], PS,
                      schema=MLA_SMOKE.schema)
    assert board.num_pages == before, "the refused path must not land"
    # schema-less (sim) publishers still interoperate
    board.publish(1, list(range(200, 200 + PS)), [None], PS)


def _mixed_cluster():
    g = GManager(2)
    rms = {0: RManager(0, BlockAllocator(8, PS, layout=MLA_SMOKE), g),
           1: RManager(1, BlockAllocator(8, PS, layout=GQA_SMOKE), g)}
    for r in rms.values():
        r.register_peers(rms)
    return g, rms


def test_lease_grant_refuses_mixed_layouts():
    """REGRESSION: the zero-copy wiring used to validate only page size, so
    a GQA home could lend pages to an MLA debtor (or vice versa) and the
    debtor would attend over reinterpreted garbage. The grant must refuse
    loudly, before any pin or ledger entry."""
    g, rms = _mixed_cluster()
    b = rms[1].allocator.alloc_block()
    with pytest.raises(ValueError, match="schema mismatch on lease grant"):
        rms[0].borrow_blocks(1, [b])
    assert not g.ledger, "a refused grant must not touch the debt ledger"
    assert rms[1].allocator.refcount_of(b) == 1, "no stray lease pin"


def test_lease_carries_creditor_schema():
    g = GManager(2)
    rms = {i: RManager(i, BlockAllocator(8, PS, layout=MLA_SMOKE), g)
           for i in range(2)}
    for r in rms.values():
        r.register_peers(rms)
    b = rms[1].allocator.alloc_block()
    lease = rms[0].borrow_blocks(1, [b])
    assert lease.schema == MLA_SMOKE.schema, \
        "the lease must carry the creditor's layout for the install check"
    lease.release()


def test_router_refuses_mixed_layout_children():
    from repro.serving.router import RouterBackend
    children = [SimBackend(num_blocks=16, block_size=PS, prefix_cache=True,
                           layout=lay) for lay in (GQA_SMOKE, MLA_SMOKE)]
    with pytest.raises(ValueError, match="schema mismatch across"):
        RouterBackend(children, prefix_share=True)
    # same layout everywhere is fine
    ok = [SimBackend(num_blocks=16, block_size=PS, prefix_cache=True,
                     layout=MLA_SMOKE) for _ in range(2)]
    RouterBackend(ok, prefix_share=True)


# -- satellite 3: cluster ledgers drain to empty for both layouts --------------

def _check_cluster_drain(layout, seed, share_mode, swap_overlap):
    from repro.serving.router import RouterBackend
    children = [SimBackend(num_blocks=32, block_size=PS, max_running=8,
                           max_tokens_per_iter=128, prefix_cache=True,
                           host_blocks=16, swap_mode="swap",
                           swap_overlap=swap_overlap, layout=layout)
                for _ in range(2)]
    router = RouterBackend(children, prefix_share=True,
                           share_mode=share_mode, net=NetworkModel())
    for r in make_shared_prefix_workload(16, rate=200.0, n_groups=2,
                                         prefix_len=2 * PS, suffix_len=PS,
                                         out_len=8, seed=seed,
                                         group_draw="random"):
        router.add_request(r)
    for _ in range(5000):
        if not router.has_work:
            break
        router.step()
        for c in children:
            a = c.allocator
            assert a.num_used + a.num_free == a.num_blocks
            assert a.swapped_pages + a.host_num_free == a.num_host_blocks
    else:
        raise AssertionError("cluster did not drain")
    for c in children:
        c.prefix_cache.clear()
    # pages the board still pins as lendable (zero_copy homes keep their
    # published blocks referenced until board eviction) are accounted, not
    # leaked: residual usage must equal exactly the pin count
    pinned = {i: 0 for i in range(len(children))}
    stack = [router.g.prefix_board._root]
    while stack:
        node = stack.pop()
        for ch in node.children.values():
            if ch.block is not None:
                pinned[ch.home] += 1
            stack.append(ch)
    for i, c in enumerate(children):
        a = c.allocator
        assert a.num_used == pinned[i] and a.swapped_pages == 0
        assert a.pending_out_pages == 0
        assert router.g.lent_by(i) == 0 and router.g.borrowed_by(i) == 0, \
            "every lease must be repaid at drain"


@settings(max_examples=8, deadline=None)
@given(mla=st.booleans(), seed=st.integers(0, 10_000),
       zero_copy=st.booleans(), swap_overlap=st.booleans())
def test_cluster_conservation_over_layouts(mla, seed, zero_copy,
                                           swap_overlap):
    """Property: device/host/pending ledgers hold every iteration and the
    allocators, spill budgets, and lease debt all drain to empty — for
    BOTH page layouts, under random share/overlap settings. The layout
    changes every byte charge but must never change ledger accounting."""
    _check_cluster_drain(MLA_SMOKE if mla else GQA_SMOKE, seed,
                         "zero_copy" if zero_copy else "copy", swap_overlap)


@pytest.mark.parametrize("layout", [GQA_SMOKE, MLA_SMOKE],
                         ids=["gqa", "mla"])
@pytest.mark.parametrize("share_mode", ["copy", "zero_copy"])
def test_cluster_conservation_examples(layout, share_mode):
    """Example-based companion so both layouts are exercised even where
    hypothesis is unavailable."""
    _check_cluster_drain(layout, 7, share_mode, swap_overlap=True)


# -- MLA engine: matrix-absorbed decode over latent pages (ACCEPTANCE) ---------

@pytest.fixture(scope="module")
def mla_setup():
    cfg = smoke_config("deepseek-v2-236b")
    cfg = dataclasses.replace(cfg, dtype="float32", logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, n):
    """Greedy reference: naive decompress-then-attend MLA forward (the
    ``mla_forward`` path inside ``Model``), fp32, ring caches."""
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def _engine(cfg, params, **kw):
    kw.setdefault("num_pages", 48)
    kw.setdefault("page_size", PS)
    kw.setdefault("max_slots", 4)
    return PagedEngine(cfg, params, EngineConfig(**kw))


def test_mla_engine_pools_follow_layout(mla_setup):
    cfg, model, params = mla_setup
    eng = _engine(cfg, params)
    lay = eng.kv_layout
    assert lay.flavor == "mla"
    assert lay.schema == "mla:ckv64+krope16:f32"
    shapes = lay.pool_shapes(48 + 1, PS)  # +1: the trash page
    assert eng.k_pages.shape == shapes[0]  # ckv pool (L, P+1, ps, r)
    assert eng.v_pages.shape == shapes[1]  # krope pool (L, P+1, ps, dr)
    assert eng.allocator.page_bytes == lay.page_bytes(PS)


def test_mla_engine_rejects_kernel_and_window(mla_setup):
    cfg, model, params = mla_setup
    with pytest.raises(ValueError, match="kernel"):
        _engine(cfg, params, use_kernel=True)


def test_mla_engine_token_identity(mla_setup):
    """ACCEPTANCE (the tentpole): matrix-absorbed MLA decode over paged
    latent ckv/krope — W_UK absorbed into the query path, W_UV into the
    output path, never materializing per-head K/V — produces exactly the
    oracle's greedy tokens (fp32 decompress-then-attend)."""
    cfg, model, params = mla_setup
    eng = _engine(cfg, params)
    rng = np.random.default_rng(3)
    reqs = [Request(i, 0.0,
                    rng.integers(1, cfg.vocab_size, 13 + i).tolist(),
                    max_new_tokens=8) for i in range(3)]
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion()
    for r in reqs:
        want = _oracle(model, params, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_mla_engine_swap_round_trip_token_identity(mla_setup):
    """ACCEPTANCE: an MLA request swapped to host mid-decode and back
    resumes mid-sequence with its latent pages intact — the device<->host
    copies move ckv/krope pools, and the greedy tokens still match."""
    cfg, model, params = mla_setup
    eng = _engine(cfg, params, num_pages=8, max_slots=2, host_pages=16,
                  swap_mode="swap")
    # seed 4: both prompts individually match the sequential oracle in a
    # roomy no-swap run (some seeds hit unrelated fp32 near-ties), so any
    # mismatch here is attributable to the swap round trip
    rng = np.random.default_rng(4)
    reqs = [Request(i, 0.0,
                    rng.integers(1, cfg.vocab_size, 17).tolist(),
                    max_new_tokens=20) for i in range(2)]
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion()
    assert eng.swapped_out == eng.swapped_in > 0, \
        "the crunch must force a swap round trip"
    for r in reqs:
        assert r.preemptions == 0
        want = _oracle(model, params, r.prompt, len(r.full_output))
        assert r.full_output == want, f"req {r.request_id}"
    assert eng.allocator.num_free == eng.allocator.num_blocks
    assert eng.allocator.swapped_pages == 0


class _Script:
    def __init__(self, script):
        self.script = list(script)

    def choose(self, req, children):
        return self.script.pop(0)


def test_mla_engine_zero_copy_token_identity(mla_setup):
    """ACCEPTANCE: instance B decodes with its prefix ckv/krope pages
    living in instance A's pools, served through the latent partial merge
    — no payload copy — and B's output matches the fp32 oracle."""
    from repro.serving.router import RouterBackend
    cfg, model, params = mla_setup
    engines = [_engine(cfg, params, enable_prefix_cache=True)
               for _ in range(2)]
    router = RouterBackend(engines, policy=_Script([0, 0, 1]),
                           prefix_share=True, share_mode="zero_copy",
                           hot_threshold=1)
    rng = np.random.default_rng(12)
    prefix = rng.integers(1, cfg.vocab_size, 2 * PS).tolist()
    prompts = [prefix + rng.integers(1, cfg.vocab_size, 4).tolist()
               for _ in range(3)]
    reqs = [Request(i, 0.0, list(p), max_new_tokens=3)
            for i, p in enumerate(prompts)]
    for r in reqs:
        router.add_request(r)
        while router.has_work:
            router.step()
    assert reqs[2].instance_id == 1
    assert router.pages_borrowed >= 2, "the prefix must be borrowed"
    assert engines[1].prefix_cache.adopted_pages == 0, \
        "zero_copy must not copy latent payloads"
    assert reqs[2].num_cached_tokens == 2 * PS
    assert not router.g.ledger, "every lease repaid at request finish"
    for r, p in zip(reqs, prompts):
        want = _oracle(model, params, p, 3)
        assert r.full_output == want, f"req {r.request_id}"


def test_mla_engine_payload_export_import_round_trip(mla_setup):
    """Copy-mode sharing of latent pages: exported payloads carry the MLA
    schema tag and re-import bit-identically; a foreign-schema payload is
    refused before any pool is touched."""
    cfg, model, params = mla_setup
    eng = _engine(cfg, params)
    rng = np.random.default_rng(7)
    r = Request(0, 0.0, rng.integers(1, cfg.vocab_size, 2 * PS).tolist(),
                max_new_tokens=2)
    eng.add_request(r)
    eng.run_to_completion()
    payload = eng.export_page_payload(0)
    assert payload[0] == eng.kv_layout.schema
    assert payload[1].shape == (eng.nlayers, PS) \
        + eng.kv_layout.pools[0].token_shape
    blk = eng.allocator.alloc_block()
    eng.import_page_payloads([blk], [payload])
    np.testing.assert_array_equal(np.asarray(eng.k_pages[:, blk]),
                                  payload[1])
    np.testing.assert_array_equal(np.asarray(eng.v_pages[:, blk]),
                                  payload[2])
    eng.allocator.decref(blk)
    foreign = (GQA_SMOKE.schema, payload[1], payload[2])
    with pytest.raises(ValueError, match="payload import"):
        eng.import_page_payloads([0], [foreign])


def test_mla_engine_handoff_install_refuses_foreign_lease(mla_setup):
    """REGRESSION: the disaggregated handoff used to install any lease
    whose page size matched; a lease over GQA pages must be refused before
    a slot is claimed."""
    cfg, model, params = mla_setup
    eng = _engine(cfg, params)
    eng.remote_reader = lambda home: (eng.k_pages, eng.v_pages)
    lease = RemoteLease(home=1, debtor=0, blocks=[0], page_size=PS,
                        schema=GQA_SMOKE.schema)
    req = Request(0, 0.0, [1, 2, 3], max_new_tokens=1)
    req.output.append(5)
    free_before = len(eng.free_slots)
    with pytest.raises(ValueError, match="handoff install"):
        eng.install_for_handoff(req, None, lease=lease)
    assert len(eng.free_slots) == free_before, "no slot may leak"
