"""ORCA §III.B: iteration-level vs batch-level scheduling (the paper's C1
motivation — early-finished and late-joining requests)."""

from __future__ import annotations

from repro.serving.simulator import (make_workload, simulate_batch_level,
                                     simulate_paged)


def run(n_requests: int = 300, verbose: bool = True):
    rows = []
    for rate in (2.0, 4.0, 8.0):
        wl = lambda: make_workload(n_requests, rate=rate, dist="sharegpt",
                                   seed=11)
        it = simulate_paged(wl(), num_blocks=4096, block_size=16)
        bl = simulate_batch_level(wl(), max_batch=32)
        rows.append(dict(rate=rate,
                         iter_lat=it.mean_normalized_latency,
                         batch_lat=bl.mean_normalized_latency,
                         iter_thr=it.throughput_tokens_per_s,
                         batch_thr=bl.throughput_tokens_per_s))
        if verbose:
            r = rows[-1]
            print(f"rate={rate:4.1f}: iteration-level "
                  f"{1e3*r['iter_lat']:7.1f} ms/tok vs batch-level "
                  f"{1e3*r['batch_lat']:7.1f} ms/tok "
                  f"({r['batch_lat']/r['iter_lat']:.1f}x worse); "
                  f"thr {r['iter_thr']:.0f} vs {r['batch_thr']:.0f} tok/s")
    return rows


if __name__ == "__main__":
    run()
