"""Disaggregated prefill/decode serving: roles, placement, KV handoff.

Covers the PR's acceptance criteria and satellites: role-spec parsing and
launcher-grade validation, the expected-reuse amortization in the borrow-
vs-copy decision (lease hit-counts on the share board), promote-to-copy
after N leases, the sim cluster end-to-end (frontier machinery +
trace-conservation: every ``handoff.kv`` begin has its end, lease
acquire/release balance per (instance, request) no matter which host
finishes), and the token identity of a request whose prompt KV was
prefilled on instance P and decoded on instance D — for the migrate AND
the zero-copy (leased, DistAttention-merged) handoff paths — vs the
single-instance fp32 oracle."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distkv import NetworkModel
from repro.core.distkv.prefixshare import PrefixShareBoard
from repro.core.scheduling import Phase, Request
from repro.core.telemetry.tracer import PH_BEGIN, PH_END
from repro.serving.disagg import (HANDOFF_MODES, InstanceSpec,
                                  parse_role_spec)
from repro.serving.simulator import (SimBackend, make_workload,
                                     simulate_disagg)

PS = 8  # page size for the engine tests


# -- role specs ------------------------------------------------------------------

def test_parse_role_spec_grammar():
    assert parse_role_spec("2p2d") == ["prefill"] * 2 + ["decode"] * 2
    assert parse_role_spec("1p1d1m") == ["prefill", "decode", "mixed"]
    assert parse_role_spec("10d") == ["decode"] * 10
    assert parse_role_spec(" 2P1D ") == ["prefill"] * 2 + ["decode"]
    # a role-name list passes through validated
    assert parse_role_spec(["prefill", "mixed"]) == ["prefill", "mixed"]


@pytest.mark.parametrize("bad", ["", "2pXd", "pd", "2x", "2p 2d", "p2"])
def test_parse_role_spec_rejects_malformed(bad):
    with pytest.raises(ValueError, match="malformed"):
        parse_role_spec(bad)


def test_parse_role_spec_rejects_zero_and_unknown():
    with pytest.raises(ValueError, match="zero instances"):
        parse_role_spec("0p")
    with pytest.raises(ValueError, match="unknown role"):
        parse_role_spec(["prefill", "gpu"])
    with pytest.raises(ValueError, match="role"):
        InstanceSpec(backend=None, role="router")


def test_router_role_validation():
    from repro.serving.router import RouterBackend
    sims = [SimBackend(num_blocks=32, block_size=8) for _ in range(2)]
    with pytest.raises(ValueError, match="decode"):
        RouterBackend(sims, roles="2p")  # nobody could ever decode
    with pytest.raises(ValueError, match="prefill"):
        RouterBackend(sims, roles="2d")  # nobody admits a prompt
    with pytest.raises(ValueError, match="2"):
        RouterBackend(sims, roles="1p2d")  # count != len(children)
    with pytest.raises(ValueError, match="handoff_mode"):
        RouterBackend(sims, roles="1p1d", handoff_mode="rdma")
    # all-mixed spec is exactly the old router: no handoff coordinator
    r = RouterBackend(sims, roles="2m")
    assert r.handoff is None and not r.disaggregated


# -- expected-reuse amortization (satellite) -------------------------------------

def test_prefer_borrow_amortizes_copy_over_expected_reuse():
    """SATELLITE: the borrow-vs-copy decision was myopic — it charged the
    full payload copy to the single request at hand, so a prefix leased
    over and over never flipped to a copy. Amortized over the observed
    lease count, a popular prefix flips."""
    net = NetworkModel()
    # one short-decode request on its own: the copy never pays off
    assert net.prefer_borrow(32, 16, est_decode_tokens=16)
    # the Nth identical request: the same copy split N ways does pay off
    assert not net.prefer_borrow(32, 16, est_decode_tokens=16,
                                 expected_reuse=200)
    # neutral default: expected_reuse=1 is exactly the old decision
    assert net.prefer_borrow(32, 16, est_decode_tokens=16,
                             expected_reuse=1.0) == \
        net.prefer_borrow(32, 16, est_decode_tokens=16)


def test_board_counts_lease_hits_per_instance():
    board = PrefixShareBoard()
    toks = list(range(16))
    board.publish(0, toks, [None, None], 8, blocks=[4, 5])
    pages = board.match(toks)
    assert board.lease_hits_of(1, pages) == 0
    assert board.record_lease(1, pages) == 1
    assert board.record_lease(1, pages) == 2
    # counts are per borrowing instance: 2's history is its own
    assert board.lease_hits_of(2, pages) == 0
    assert board.lease_hits_of(1, pages) == 2
    assert board.lease_hits_of(1, []) == 0


def test_promote_to_copy_after_n_leases():
    """SATELLITE: after ``promote_after`` leases of the same prefix by the
    same instance, the router materializes a local copy (one transfer) and
    stops leasing — ending the pay-the-merge-every-iteration pathology."""
    from repro.serving.router import RouterBackend

    class ToOne:
        def choose(self, req, children):
            return 1 if len(children) > 1 else 0

    sims = [SimBackend(num_blocks=64, block_size=8, prefix_cache=True)
            for _ in range(2)]
    router = RouterBackend(sims, policy=ToOne(), prefix_share=True,
                           share_mode="zero_copy", hot_threshold=1,
                           promote_after=2, net=NetworkModel())
    prefix = list(range(1000, 1016))  # 2 pages at bs=8

    def serve(rid, route_to):
        router.policy = route_to
        r = Request(rid, 0.0, prefix + [rid] * 3, max_new_tokens=2)
        router.add_request(r)
        while router.has_work:
            router.step()
        return r

    class ToZero:
        def choose(self, req, children):
            return 0

    serve(0, ToZero())  # warm instance 0's radix tree
    serve(1, ToZero())  # second local hit crosses hot_threshold: publish
    leased = [serve(i, ToOne()) for i in (2, 3)]  # two leases -> hits = 2
    assert router.leases_granted == 2 and router.promotions == 0
    assert all(r.num_cached_tokens == 16 for r in leased)
    promoted = serve(4, ToOne())  # prior hits >= promote_after: copy
    assert router.promotions == 1
    assert router.leases_granted == 2, "the promoted request must not lease"
    assert sims[1].prefix_cache.adopted_pages == 2
    assert promoted.num_cached_tokens >= 16, "admission hits the fresh copy"
    assert not router.g.ledger, "all leases repaid"


# -- sim cluster end-to-end ------------------------------------------------------

def _mixed_wl(n=40, rate=30.0, seed=3):
    return make_workload(n, rate=rate, dist="sharegpt", seed=seed,
                         max_len=320, long_frac=0.1, long_len=2048)


def test_sim_disagg_end_to_end():
    res = simulate_disagg(_mixed_wl(), roles="2p2d", handoff_mode="auto",
                          blocks_per_instance=512, block_size=16,
                          max_tokens_per_iter=512)
    assert res.completed_frac == 1.0
    assert res.handoffs_migrated + res.handoffs_leased > 0
    # prompts land only on prefill instances; decode instances get all
    # their requests through the handoff
    for i, row in res.per_instance.items():
        if row["role"] == "decode":
            assert row["requests"] == 0
    # no outstanding lease debt once everything drained
    for row in res.per_instance.values():
        assert row.get("borrowed_pages", 0) == 0
        assert row.get("lent_pages", 0) == 0


def test_sim_disagg_modes_generate_same_tokens():
    """The handoff mode moves KV differently but must not change WHAT is
    generated (the sim emits one token per granted iteration either way)."""
    results = [simulate_disagg(_mixed_wl(), roles="2p2d", handoff_mode=m,
                               blocks_per_instance=512, block_size=16,
                               max_tokens_per_iter=512)
               for m in HANDOFF_MODES]
    for res in results:
        assert res.completed_frac == 1.0
    for ra, rb in zip(results[0].requests, results[1].requests):
        assert ra.total_generated == rb.total_generated
    for ra, rb in zip(results[0].requests, results[2].requests):
        assert ra.total_generated == rb.total_generated


def test_sim_disagg_trace_conservation():
    """ACCEPTANCE (telemetry): every ``handoff.kv`` begin span has a
    matching end for the same request, and lease acquire/release instants
    balance per (instance, request) even though a leased handoff acquires
    on the decode host while the prefill host granted the pages."""
    res = simulate_disagg(_mixed_wl(n=50), roles="2p2d",
                          handoff_mode="zero_copy",
                          blocks_per_instance=512, block_size=16,
                          max_tokens_per_iter=512, trace=True)
    assert res.completed_frac == 1.0 and res.handoffs_leased > 0
    begins, ends = {}, {}
    acq, rel = {}, {}
    for ev in res.events:
        if ev.cat == "handoff" and ev.name == "kv":
            d = begins if ev.ph == PH_BEGIN else ends
            d[ev.rid] = d.get(ev.rid, 0) + 1
        if ev.cat == "lease" and ev.rid is not None:
            if ev.name == "acquire":
                acq[(ev.instance, ev.rid)] = \
                    acq.get((ev.instance, ev.rid), 0) + 1
            elif ev.name == "release":
                rel[(ev.instance, ev.rid)] = \
                    rel.get((ev.instance, ev.rid), 0) + 1
    assert begins and begins == ends, "unbalanced handoff spans"
    assert acq == rel, "lease acquire/release must balance per " \
        "(instance, request)"
    # handoff spans begin at the prefill host's clock and end at the decode
    # host's, but never run backwards on the merged timeline
    spans = {}
    for ev in res.events:
        if ev.cat == "handoff":
            spans.setdefault(ev.rid, {})[ev.ph] = ev.ts
    assert all(s[PH_END] >= s[PH_BEGIN] for s in spans.values())


def test_sim_disagg_role_timelines_split():
    res = simulate_disagg(_mixed_wl(), roles="2p2d", handoff_mode="auto",
                          blocks_per_instance=512, block_size=16,
                          max_tokens_per_iter=512, trace=True)
    assert set(res.role_timelines) == {"prefill", "decode"}
    for role, rows in res.role_timelines.items():
        assert rows, f"no metric rows for {role} instances"
        ts = [row.get("ts", 0.0) for row in rows]
        assert ts == sorted(ts)
    # decode instances never run a prefill chunk; prefill instances never
    # decode — the split is the whole point of the role tags
    pre = res.role_timelines["prefill"]
    dec = res.role_timelines["decode"]
    assert sum(r.get("decode_tokens", 0) for r in pre) == 0
    assert sum(r.get("prefill_tokens", 0) for r in dec) == 0
    assert sum(r.get("decode_tokens", 0) for r in dec) > 0


# -- engine: cross-instance handoff token identity (ACCEPTANCE) ------------------

def _fresh_engine(cfg, params, **kw):
    from repro.serving.engine import EngineConfig, PagedEngine
    kw.setdefault("num_pages", 64)
    kw.setdefault("page_size", PS)
    kw.setdefault("max_slots", 4)
    return PagedEngine(cfg, params, EngineConfig(**kw))


@pytest.fixture(scope="module")
def model_setup():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None, logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _oracle(model, params, prompt, n):
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, tokens, seq_capacity=128)
    tok = int(jnp.argmax(logits[0]))
    out = [tok]
    pos = len(prompt)
    while len(out) < n:
        lg, caches = model.decode_step(params, jnp.array([[tok]], jnp.int32),
                                       jnp.array([pos], jnp.int32), caches)
        tok = int(jnp.argmax(lg[0]))
        out.append(tok)
        pos += 1
    return out


def _run_disagg_cluster(cfg, params, mode, prompts, n_new=4):
    from repro.serving.router import RouterBackend
    engines = [_fresh_engine(cfg, params) for _ in range(2)]
    router = RouterBackend(engines, roles=["prefill", "decode"],
                           handoff_mode=mode)
    reqs = [Request(i, 0.0, list(p), max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        router.add_request(r)
        while router.has_work:
            router.step()
    return router, engines, reqs


def test_engine_handoff_migrate_token_identity(model_setup):
    """ACCEPTANCE: prompt KV prefilled on P, payload-migrated to D, decoded
    there — token-identical to the single-instance fp32 oracle. Covers the
    page-aligned and partial-tail-page prompt shapes and the first-decode
    seam (input = first sampled token, position = prompt_len)."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (2 * PS, 2 * PS + 4)]  # tail-less and tailed
    router, engines, reqs = _run_disagg_cluster(cfg, params, "migrate",
                                                prompts)
    assert router.handoff.handoffs_migrated == 2
    assert router.handoff.handoffs_leased == 0
    for r, prompt in zip(reqs, prompts):
        assert r.phase == Phase.FINISHED
        assert r.instance_id == 1, "decode must have moved to the D host"
        assert r.full_output == _oracle(model, params, prompt, 4)
    # migration is a full KV move: nothing borrowed, nothing left pinned
    assert not router.g.ledger
    assert engines[1].allocator.num_free == 64, "D freed all pages"


def test_engine_handoff_zero_copy_token_identity(model_setup):
    """ACCEPTANCE: the handoff lease covers ALL full prompt pages (the
    first token was already sampled on P) and D's every decode step merges
    P's pages through DistAttention — token-identical to the oracle, and
    every lease repaid at finish."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(33)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist()
               for n in (2 * PS, 2 * PS + 4)]
    router, engines, reqs = _run_disagg_cluster(cfg, params, "zero_copy",
                                                prompts)
    assert router.handoff.handoffs_leased == 2
    assert router.handoff.pages_leased == 4, "all full pages leased"
    assert router.handoff.pages_copied == 1, "only the partial tail copied"
    for r, prompt in zip(reqs, prompts):
        assert r.phase == Phase.FINISHED
        assert r.instance_id == 1
        assert r.full_output == _oracle(model, params, prompt, 4)
    assert not router.g.ledger, "every handoff lease repaid at finish"


def test_engine_handoff_skips_single_token_requests(model_setup):
    """max_new_tokens=1 finishes on the prefill host with its sampled
    token — there is no decode left to hand off."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(35)
    prompt = rng.integers(0, cfg.vocab_size, 2 * PS + 2).tolist()
    router, engines, reqs = _run_disagg_cluster(cfg, params, "auto",
                                                [prompt], n_new=1)
    assert router.handoff.handoffs == 0
    assert reqs[0].instance_id == 0
    assert reqs[0].full_output == _oracle(model, params, prompt, 1)
