"""SeamlessM4T-medium — enc-dec multimodal translation [arXiv:2308.11596].

12L(enc) + 12L(dec) d_model=1024 16H d_ff=4096 vocab=256206. The speech
frontend (mel-spectrogram + conv feature extractor) is a STUB per the
assignment carve-out: ``input_specs`` provides precomputed frame embeddings;
this config is the text/unit transformer backbone.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    attention="gqa",
    use_bias=True,
    gated_mlp=False,
    frontend="audio",
    num_media_tokens=512,  # precomputed speech-frame embeddings fed to the encoder
)
