"""Paged serving engine: ORCA iteration-level scheduling + vLLM paging + the
paged-attention kernel, on a real JAX model.

Execution model per iteration (continuous batching):

1. the :class:`IterationScheduler` plans prefill *chunks* + decodes under
   the token budget and page supply (Sarathi-style chunked prefill: a prompt
   larger than the budget is admitted once and then contributes budget-sized
   chunks across successive iterations, piggybacked with ongoing decodes —
   ``EngineConfig.chunk_policy`` picks decode-first / prefill-first / the
   legacy solo baseline);
2. each planned chunk runs through one jitted ``_prefill_chunk_fn``: the
   chunk's K/V is scattered into the **paged physical cache** at per-token
   (page, offset) slots through the request's block table, and its queries
   attend causally at absolute RoPE positions over every context page —
   radix-cached prefix pages (``enable_prefix_cache``), chunks written in
   earlier iterations, and the chunk itself. Only the final chunk samples a
   token. Chunk starts need not be page-aligned, which is what lets a
   token-level (mid-page) prefix-cache hit resume from an unaligned
   boundary;
3. all running sequences advance one token in a single batched decode step
   over fixed slots — attention reads scattered pages via the block table
   (``repro.kernels.paged_attention``; a pure-XLA reference path is the
   default on CPU, the Pallas kernel is switchable via ``use_kernel``), and
   sampling runs **fused with vectorized per-slot parameters**: each slot
   applies its own request's temperature / top-k / top-p / seed
   (``repro.models.sampling.sample_batch``), and stop/eos/length finish
   reasons are checked per request.

The engine implements the :class:`~repro.serving.api.ServingBackend`
protocol; drive it through :class:`~repro.serving.api.LLMService` rather
than hand-rolling ``step()`` loops. Per-request sampling lives on
``Request.sampling`` (:class:`~repro.serving.api.SamplingParams`);
``EngineConfig.temperature`` is **deprecated** and only seeds the default
params for requests submitted without any. Best-of-n requests
(``SamplingParams.n > 1``) COW-fork the parent's block table right after
its prefill — siblings share every prompt page and diverge through the
allocator's copy-on-write on the first partial-page write, with the engine
copying the physical page contents for each ``(old, new)`` pair the
scheduler reports.

Divergence from paper noted (DESIGN.md §2.2): ORCA's selective batching fuses
prefill+decode tokens into one ragged batch; XLA needs static shapes, so
prefills run as separate padded calls while decodes fuse across slots — the
iteration-level scheduling semantics (early exit, late join) are identical.

Supports every *attention-cached* arch family (GQA/MQA/SWA). For paging, the
block tables, COW forks and preemption come straight from ``core.paging``.
The per-layer math (ln → qkv+rope → attend → wo → mlp) is the shared
:func:`repro.models.attention.gqa_layer` body, parameterized here by paged
attends.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core.distkv.dist_attention import (attention_partial,
                                              merge_partials_tree)
from repro.core.paging.allocator import BlockAllocator, BlockTable
from repro.core.paging.layout import KVPageLayout, check_schema
from repro.core.prefixcache.radix import PrefixCache
from repro.core.scheduling.iteration import IterationScheduler
from repro.core.scheduling.request import Phase, Request
from repro.core.telemetry import MetricsRegistry, Tracer
from repro.kernels import ops, ref
from repro.models import Model
from repro.models import moe as moe_mod
from repro.models import sampling
from repro.models.layers import embed, rms_norm, unembed
from repro.models.attention import (_mla_scale, blockwise_attention,
                                    gqa_layer, mla_effective_ctx,
                                    mla_effective_kv, mla_layer)
from repro.serving.api import SamplingParams


def _pow2_bucket(n: int, floor: int = 8) -> int:
    """Smallest power of two >= n (>= floor): jit shape buckets, so a mixed
    chunk-length workload compiles O(log) variants instead of one per
    (chunk_len, n_pages) pair."""
    p = floor
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class EngineConfig:
    num_pages: int = 512
    page_size: int = 16
    max_slots: int = 8
    max_tokens_per_iter: int = 2048
    use_kernel: bool = False  # True => Pallas paged_attention (interpret on CPU)
    # DEPRECATED: per-request SamplingParams (serving.api) supersede the
    # engine-global temperature; this only seeds the default params applied
    # to requests submitted without `sampling` set.
    temperature: float = 0.0
    seed: int = 0
    # per-sequence context cap; None falls back to ArchConfig.max_seq_len and
    # then to the whole page supply. Sizes the (n, max_pages) block-table
    # transfer each decode step, so keep it at the real serving limit.
    max_context_len: Optional[int] = None
    # radix-tree prefix KV cache: share prompt pages across requests and
    # prefill only the uncached suffix
    enable_prefix_cache: bool = False
    # drop a request after this many preemptions (finish_reason
    # "preempted-dropped"); None = recompute forever
    max_preemptions: Optional[int] = None
    # chunked-prefill budget policy: "decode_first" (Sarathi stall-free:
    # running decodes get budget before prefill chunks), "prefill_first"
    # (TTFT-optimal, decodes may stall), "monolithic" (no chunking: the
    # whole prompt prefills in one iteration alongside the decodes), or
    # "solo" (legacy: over-budget prompts wait for an idle engine)
    chunk_policy: str = "decode_first"
    # host swap tier: host-memory pages a preemption victim's KV can move
    # to (0 = disabled, classic sacrifice-and-recompute). With pages
    # available, swap_mode ("sacrifice" | "swap" | "auto") and
    # victim_policy ("lifo" | "fifo" | "lru" | "cost") pick who loses
    # device pages and whether their KV survives on host — see
    # core.scheduling.iteration.SWAP_MODES / VICTIM_POLICIES
    host_pages: int = 0
    swap_mode: str = "sacrifice"
    victim_policy: str = "lifo"
    # speculative double-buffered swap-outs: the scheduler issues a decode
    # victim's swap-out one iteration early when free pages trend under the
    # watermark (issue/complete halves behind the allocator's pending
    # ledger), cancelling if pressure recedes before the DMA resolves
    speculative_swap: bool = False
    # prefix-cache spill: cold radix pages move to host pages (bounded LRU
    # budget, drawn from the same host_pages pool) instead of dying — a
    # later match restores them over PCIe instead of recomputing
    cache_spill_pages: int = 0
    # structured event tracing + per-iteration metric timelines
    # (repro.core.telemetry) on this engine's wall clock. Off by default —
    # the disabled path constructs no event objects at all.
    enable_telemetry: bool = False


class PagedEngine:
    """Single-host engine instance (one "LLM service instance" in
    InfiniteLLM terms). Implements the ServingBackend protocol."""

    def __init__(self, cfg: ArchConfig, params, ecfg: EngineConfig):
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.model = Model(cfg, remat=False)
        mixers = {seg.mixer for seg in self.model.plan}
        assert len(mixers) == 1 and mixers <= {"gqa", "mla"}, \
            "PagedEngine serves uniform GQA or MLA stacks; others use " \
            "Model.decode_step"
        # the page-payload schema every pool / payload / lease goes through:
        # GQA pools are per-head (k, v); MLA pools are the shared latent
        # (ckv, krope) — ~10x fewer bytes per token
        self.kv_layout = KVPageLayout.from_arch(cfg)
        self.flavor = self.kv_layout.flavor
        if self.flavor == "mla" and ecfg.use_kernel:
            raise ValueError("the Pallas paged_attention kernel is GQA-only;"
                             " MLA decode runs the pure-XLA latent path")
        if self.flavor == "mla" and cfg.sliding_window:
            raise ValueError("MLA + sliding window is unsupported")
        self.nlayers = cfg.num_layers
        L, P, ps = cfg.num_layers, ecfg.num_pages, ecfg.page_size
        # +1 trash page: inactive decode slots park their writes there.
        # Pool attribute names stay ``k_pages``/``v_pages`` for every
        # layout — they are "pool A"/"pool B" of ``kv_layout.pools`` (MLA:
        # ckv / krope); all page-granular plumbing (COW, swap, spill,
        # export) indexes only axis 1 and never the trailing token shape.
        shape_a, shape_b = self.kv_layout.pool_shapes(P + 1, ps)
        self.k_pages = jnp.zeros(shape_a, cfg.param_dtype)
        self.v_pages = jnp.zeros(shape_b, cfg.param_dtype)
        self.allocator = BlockAllocator(P, ps,
                                        host_blocks=ecfg.host_pages,
                                        layout=self.kv_layout)
        self.prefix_cache = PrefixCache(
            self.allocator, spill_budget=ecfg.cache_spill_pages) \
            if ecfg.enable_prefix_cache else None
        self.scheduler = IterationScheduler(
            self.allocator, max_running=ecfg.max_slots,
            max_tokens_per_iter=ecfg.max_tokens_per_iter,
            prefix_cache=self.prefix_cache,
            max_preemptions=ecfg.max_preemptions,
            chunk_policy=ecfg.chunk_policy,
            swap_mode=ecfg.swap_mode, victim_policy=ecfg.victim_policy,
            speculative_swap=ecfg.speculative_swap)
        # host swap tier: pinned-host-memory stand-ins (numpy arrays, same
        # page geometry as the device pools minus the trash page). The
        # scheduler's swap hooks move payloads synchronously at schedule
        # time — swap-out MUST copy before anything later in the same
        # schedule() can reallocate-and-write the freed device pages
        if ecfg.host_pages:
            H = ecfg.host_pages
            h_shape_a, h_shape_b = self.kv_layout.pool_shapes(H, ps)
            self.h_k_pages = np.zeros(h_shape_a, self.k_pages.dtype)
            self.h_v_pages = np.zeros(h_shape_b, self.v_pages.dtype)
            self.scheduler.swap_out_hook = self._swap_out_copy
            self.scheduler.swap_in_hook = self._swap_in_copy
            # double-buffered (issue/complete) halves for speculative
            # swap-outs: the allocator's pending ledger keeps the source
            # pages allocated and immutable while "in flight"
            self.scheduler.swap_issue_hook = self._swap_out_issue
            self.scheduler.swap_complete_hook = self._swap_out_complete
            self.scheduler.swap_cancel_hook = self._swap_out_cancel
            if self.prefix_cache is not None:
                self.prefix_cache.spill_out_fn = self._spill_out_copy
                self.prefix_cache.spill_in_fn = self._spill_in_copy
        else:
            self.h_k_pages = self.h_v_pages = None
        self.swapped_out = 0
        self.swapped_in = 0
        # block-table width: the real per-sequence context limit, not the
        # whole page supply — shrinks the (n, max_pages) host->device
        # transfer every decode step
        max_ctx = ecfg.max_context_len or cfg.max_seq_len or P * ps
        self.max_context_len = min(max_ctx, P * ps)
        self.max_pages_per_seq = -(-self.max_context_len // ps)  # ceil
        self.slots: Dict[int, int] = {}  # request_id -> slot
        self.free_slots = list(range(ecfg.max_slots - 1, -1, -1))
        self.last_token = np.zeros(ecfg.max_slots, np.int32)
        self.iterations = 0
        self.preemptions = 0
        # requests submitted without sampling params fall back to the
        # (deprecated) engine-global temperature, greedy by default
        self._default_sp = SamplingParams(temperature=ecfg.temperature)
        self._sample_fn = jax.jit(sampling.sample_batch)
        # best-of-n children awaiting their parent's prefill (COW fork)
        self._pending_forks: Dict[int, List[Request]] = {}
        # zero-copy cluster serving: reader(home_instance) -> (k_pages,
        # v_pages) of the creditor engine's pools, wired by RouterBackend
        # when borrowed-rBlock serving is enabled
        self.remote_reader = None
        # per-lease gathered creditor K/V (immutable while leased)
        self._lease_kv_cache: Dict[int, tuple] = {}
        # modeled network seconds (payload copies / lease RPCs) — a
        # wall-clock engine cannot advance time, so observability only
        self.net_time = 0.0
        # telemetry: events are stamped off the caller-supplied `now` (the
        # tracer's mutable .now, updated each step) with jitted-call
        # durations measured on the monotonic clock
        if ecfg.enable_telemetry:
            self.trace = Tracer()
            self.metrics = MetricsRegistry()
            self.scheduler.trace = self.trace
        else:
            self.trace = None
            self.metrics = None
        self._window = cfg.sliding_window \
            if any(seg.attn_kind == "swa" for seg in self.model.plan) \
            else None

    # -- jitted model steps ----------------------------------------------------

    def _mlp_fn(self, seg):
        """Per-segment MLP dispatch for the shared layer bodies: dense
        segments use the layer default, MoE segments route through the
        expert dispatch (DeepSeek-V2's plan is 1 dense + N-1 MoE layers)."""
        if seg.mlp_kind == "moe":
            return lambda pm, h: moe_mod.moe_forward(self.cfg, pm, h)
        return None

    def _run_segments(self, params, k_pages, v_pages, rk, rv, x, body):
        """Thread ``x`` through every segment of the plan, slicing the
        layer axis of both page pools (and the remote payload arrays) per
        segment. ``body(seg, p_i, poolA, poolB, rA_i, rB_i, x) ->
        (x, poolA', poolB')`` runs ONE layer; stacked segments (seg.n > 1)
        ``lax.scan`` it over their stacked params + pool slices. Returns
        (x, k_pages, v_pages) with the pools reassembled along the layer
        axis."""
        off = 0
        a_parts, b_parts = [], []
        for seg, p_seg in zip(self.model.plan, params["segments"]):
            kp_seg = k_pages[off:off + seg.n]
            vp_seg = v_pages[off:off + seg.n]
            rk_seg = rk[off:off + seg.n]
            rv_seg = rv[off:off + seg.n]
            if seg.n == 1:
                x, kp2, vp2 = body(seg, p_seg, kp_seg[0], vp_seg[0],
                                   rk_seg[0], rv_seg[0], x)
                a_parts.append(kp2[None])
                b_parts.append(vp2[None])
            else:
                def scan_body(carry, scanned, seg=seg):
                    xx, = carry
                    p_i, kp, vp, rk_i, rv_i = scanned
                    xx, kp2, vp2 = body(seg, p_i, kp, vp, rk_i, rv_i, xx)
                    return (xx,), (kp2, vp2)

                (x,), (kp2, vp2) = jax.lax.scan(
                    scan_body, (x,), (p_seg, kp_seg, vp_seg, rk_seg, rv_seg))
                a_parts.append(kp2)
                b_parts.append(vp2)
            off += seg.n
        if len(a_parts) == 1:
            return x, a_parts[0], b_parts[0]
        return x, jnp.concatenate(a_parts, 0), jnp.concatenate(b_parts, 0)

    def _no_remote(self, dtype):
        """Zero-token remote payload arrays (one per pool) for calls
        without a zero-copy lease — shape (L, 0, *token_shape)."""
        a, b = self.kv_layout.pools
        L = self.nlayers
        return (jnp.zeros((L, 0) + a.token_shape, dtype),
                jnp.zeros((L, 0) + b.token_shape, dtype))

    @partial(jax.jit, static_argnums=(0,))
    def _prefill_chunk_fn(self, params, k_pages, v_pages, tokens, page_ids,
                          start, length, r_base, rk, rv):
        """One prefill chunk at absolute positions ``[start, start+length)``.

        tokens: (1, S) chunk token ids padded to a power-of-two bucket
        (positions past ``length`` are pad: their K/V scatters to the trash
        page and their outputs are discarded); page_ids: (n,) physical pages
        — also pow2-padded with the trash page — covering *local* context
        positions ``[r_base, start+length)`` in order: radix-cached prefix
        pages, pages written by earlier chunks, and the pages this chunk
        lands in. ``start`` / ``length`` / ``r_base`` are traced scalars, so
        chunk boundaries (and token-level cache hits mid-page) recompile
        only per shape *bucket* — a mixed-length workload compiles O(log)
        variants, not one per (chunk_len, n_pages) pair. Each chunk token's
        K/V is scattered to its (page, offset) slot, then the chunk queries
        attend causally over every gathered context page — positions beyond
        each query are masked, so stale contents past the chunk's end (and
        the pad pages, which sit at even higher positions) are never read.

        Zero-copy remote prefix: ``rk``/``rv`` (L, R, *token_shape) carry
        the borrowed pages' payloads (gathered from the creditor instance's
        pools — K/V for GQA, ckv/krope for MLA), serving absolute positions
        ``[0, r_base)``; the local causal partial and the remote partial are
        combined with the DistAttention log-sum-exp merge. ``R = 0`` (the
        common case) keeps the original single-softmax path bit-for-bit.

        MLA stacks scatter the *latent* per-token payload (ckv, krope) into
        the two pools and attend with the matrix-absorbed effective
        single-kv-head form (``mla_effective_kv``), so pages hold
        ``kv_lora_rank + qk_rope_head_dim`` elements per token per layer
        instead of ``2 * Hkv * Dh``.

        Returns (logits (V,) of the last real chunk position, k_pages,
        v_pages); callers ignore the logits for non-final chunks. Subsumes
        whole-prompt prefill (start=0, one chunk) and cached-suffix prefill
        (start = cached tokens).
        """
        cfg = self.cfg
        ecfg = self.ecfg
        ps = ecfg.page_size
        s = tokens.shape[1]
        npg = page_ids.shape[0]
        n_remote = rk.shape[1]
        positions = start + jnp.arange(s)        # (s,) absolute
        valid_tok = jnp.arange(s) < length
        loc_pos = positions - r_base             # position within local pages
        # pad tokens park their writes on the trash page, like inactive
        # decode slots — real pages never see pad K/V
        tok_pages = jnp.where(
            valid_tok, page_ids[jnp.clip(loc_pos // ps, 0, npg - 1)],
            ecfg.num_pages)
        in_page = loc_pos % ps
        x = embed(params["embed"], tokens)  # (1, s, d)

        if self.flavor == "mla":
            r_lat, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            scale = _mla_scale(cfg)

            def body(seg, p_i, cp, rp, rc_i, rr_i, xx):
                # cp/rp: (P+1, ps, r) / (P+1, ps, dr) latent pools;
                # rc_i/rr_i: (R, r) / (R, dr) borrowed latent payloads

                def attend_latent(q_lat, qr, ckv_new, krope_new):
                    cp2 = cp.at[tok_pages, in_page].set(
                        ckv_new[0].astype(cp.dtype))
                    rp2 = rp.at[tok_pages, in_page].set(
                        krope_new[0].astype(rp.dtype))
                    ckv_all = cp2[page_ids].reshape(1, npg * ps, r_lat)
                    kr_all = rp2[page_ids].reshape(1, npg * ps, dr)
                    q_eff, k_eff, v_eff = mla_effective_kv(
                        q_lat, qr, ckv_all.astype(q_lat.dtype),
                        kr_all.astype(q_lat.dtype))
                    if n_remote == 0:
                        ctx = blockwise_attention(q_eff, k_eff, v_eff,
                                                  causal=True, q_offset=start,
                                                  scale=scale)
                    else:
                        key_pos = r_base + jnp.arange(npg * ps)
                        mask_l = positions[None, :, None] >= \
                            key_pos[None, None, :]
                        o_l, m_l, l_l = attention_partial(
                            q_eff, k_eff, v_eff, mask_l, scale=scale)
                        kr_eff, vr_eff = mla_effective_ctx(
                            rc_i[None].astype(q_lat.dtype),
                            rr_i[None].astype(q_lat.dtype))
                        mask_r = (jnp.arange(n_remote) < r_base)[None, None, :] \
                            & jnp.ones((1, s, 1), bool)
                        o_r, m_r, l_r = attention_partial(
                            q_eff, kr_eff, vr_eff, mask_r, scale=scale)
                        ctx = merge_partials_tree([o_l, o_r], [m_l, m_r],
                                                  [l_l, l_r])
                    return ctx[..., :r_lat].astype(q_lat.dtype), (cp2, rp2)

                y, (cp2, rp2) = mla_layer(cfg, p_i, xx, positions,
                                          attend_latent,
                                          mlp_fn=self._mlp_fn(seg))
                return y, cp2, rp2
        else:
            def body(seg, p_i, kp, vp, rk_i, rv_i, xx):
                window = cfg.sliding_window if seg.attn_kind == "swa" \
                    else None

                def attend(q, k, v):
                    kp2 = kp.at[tok_pages, in_page].set(k[0].astype(kp.dtype))
                    vp2 = vp.at[tok_pages, in_page].set(v[0].astype(vp.dtype))
                    kall = kp2[page_ids].reshape(
                        1, npg * ps, cfg.num_kv_heads, cfg.head_dim)
                    vall = vp2[page_ids].reshape(
                        1, npg * ps, cfg.num_kv_heads, cfg.head_dim)
                    if n_remote == 0:
                        ctx = blockwise_attention(q, kall.astype(k.dtype),
                                                  vall.astype(v.dtype),
                                                  causal=True, window=window,
                                                  q_offset=start)
                        return ctx, (kp2, vp2)
                    # zero-copy: local causal partial + remote partial,
                    # merged by log-sum-exp (DistAttention). Local keys sit
                    # at absolute positions r_base + [0, npg*ps); remote
                    # keys at [0, r_base) — all remote positions precede
                    # every chunk query, so only validity masks the remote
                    # side.
                    key_pos = r_base + jnp.arange(npg * ps)
                    mask_l = positions[None, :, None] >= key_pos[None, None, :]
                    o_l, m_l, l_l = attention_partial(q, kall, vall, mask_l)
                    mask_r = (jnp.arange(n_remote) < r_base)[None, None, :] \
                        & jnp.ones((1, s, 1), bool)
                    o_r, m_r, l_r = attention_partial(q, rk_i[None],
                                                      rv_i[None], mask_r)
                    ctx = merge_partials_tree([o_l, o_r], [m_l, m_r],
                                              [l_l, l_r])
                    return ctx.astype(q.dtype), (kp2, vp2)

                y, (kp2, vp2) = gqa_layer(cfg, p_i, xx, positions, attend,
                                          mlp_fn=self._mlp_fn(seg))
                return y, kp2, vp2

        x, k_pages, v_pages = self._run_segments(params, k_pages, v_pages,
                                                 rk, rv, x, body)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        # logits of the last REAL chunk position (pad rows are garbage)
        last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        logits = unembed(params["embed"], last, cfg.vocab_size,
                         fp32=cfg.logits_fp32)
        return logits[0, 0], k_pages, v_pages

    @partial(jax.jit, static_argnums=(0,))
    def _decode_fn(self, params, k_pages, v_pages, tokens, positions,
                   block_tables, ctx_lens):
        """Batched one-token step over slots.

        tokens: (n,), positions: (n,), block_tables: (n, max_pages),
        ctx_lens: (n,) (0 = inactive slot). Returns (logits (n, V), pages).

        GQA runs the Pallas/reference paged-attention kernel; MLA gathers
        the latent pools and attends in the matrix-absorbed effective
        single-kv-head form (the Pallas kernel is GQA-shaped)."""
        cfg = self.cfg
        ecfg = self.ecfg
        n = tokens.shape[0]
        ps = ecfg.page_size

        x = embed(params["embed"], tokens[:, None])  # (n, 1, d)
        page_slot = block_tables[jnp.arange(n), positions // ps]  # (n,)
        # inactive slots (ctx_len == 0) write to the trash page
        page_slot = jnp.where(ctx_lens > 0, page_slot, ecfg.num_pages)
        in_page = positions % ps

        if self.flavor == "mla":
            r_lat, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            scale = _mla_scale(cfg)

            def body(seg, p_i, cp, rp, rc_i, rr_i, xx):
                def attend_latent(q_lat, qr, ckv_new, krope_new):
                    cp2 = cp.at[page_slot, in_page].set(
                        ckv_new[:, 0].astype(cp.dtype))
                    rp2 = rp.at[page_slot, in_page].set(
                        krope_new[:, 0].astype(rp.dtype))
                    ckv_all = cp2[block_tables].reshape(n, -1, r_lat)
                    kr_all = rp2[block_tables].reshape(n, -1, dr)
                    q_eff, k_eff, v_eff = mla_effective_kv(
                        q_lat, qr, ckv_all.astype(q_lat.dtype),
                        kr_all.astype(q_lat.dtype))
                    s_loc = k_eff.shape[1]
                    mask = (jnp.arange(s_loc)[None, :] <
                            ctx_lens[:, None])[:, None, :]  # (n, 1, S)
                    o, m, l = attention_partial(q_eff, k_eff, v_eff, mask,
                                                scale=scale)
                    ctx = merge_partials_tree([o], [m], [l])
                    return ctx[..., :r_lat].astype(q_lat.dtype), (cp2, rp2)

                y, (cp2, rp2) = mla_layer(cfg, p_i, xx, positions[:, None],
                                          attend_latent,
                                          mlp_fn=self._mlp_fn(seg))
                return y, cp2, rp2
        else:
            def body(seg, p_i, kp, vp, rk_i, rv_i, xx):
                window = cfg.sliding_window if seg.attn_kind == "swa" \
                    else None

                def attend(q, k, v):
                    # write each slot's new K/V into its page, then paged
                    # attention over the block tables
                    kp2 = kp.at[page_slot, in_page].set(
                        k[:, 0].astype(kp.dtype))
                    vp2 = vp.at[page_slot, in_page].set(
                        v[:, 0].astype(vp.dtype))
                    att_fn = ops.paged_attention if ecfg.use_kernel \
                        else ref.paged_attention_ref
                    att = att_fn(q[:, 0], kp2, vp2, block_tables, ctx_lens,
                                 page_size=ps, window=window)
                    return att.reshape(n, 1, cfg.num_heads, cfg.head_dim), \
                        (kp2, vp2)

                y, (kp2, vp2) = gqa_layer(cfg, p_i, xx, positions[:, None],
                                          attend, mlp_fn=self._mlp_fn(seg))
                return y, kp2, vp2

        rk, rv = self._no_remote(k_pages.dtype)
        x, k_pages, v_pages = self._run_segments(params, k_pages, v_pages,
                                                 rk, rv, x, body)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size,
                         fp32=cfg.logits_fp32)[:, 0]
        return logits, k_pages, v_pages

    @partial(jax.jit, static_argnums=(0,))
    def _decode_zc_fn(self, params, k_pages, v_pages, tokens, positions,
                      block_tables, ctx_lens, r_base, rk, rv):
        """Batched one-token step where some slots serve their leading
        context from pages *borrowed* from a peer instance (zero-copy
        prefix lease). Arguments mirror :meth:`_decode_fn` plus:

        r_base: (n,) borrowed tokens per slot (0 = fully local — such slots
        reduce to the plain paged path numerically); rk, rv:
        (L, n, R, *token_shape) the borrowed pages' payloads gathered from
        each creditor's pools (K/V for GQA, ckv/krope for MLA), covering
        absolute positions ``[0, r_base[i])`` of slot ``i``. Per layer, the
        local paged partial and the remote partial are combined with the
        DistAttention log-sum-exp merge — exactly the InfiniteLLM
        micro-attention aggregation, with the borrower reading the
        creditor's pages in place of an RDMA fetch.
        """
        cfg = self.cfg
        ecfg = self.ecfg
        n = tokens.shape[0]
        ps = ecfg.page_size
        n_remote = rk.shape[2]

        x = embed(params["embed"], tokens[:, None])  # (n, 1, d)
        loc_pos = jnp.maximum(positions - r_base, 0)  # write slot, local
        loc_lens = jnp.maximum(ctx_lens - r_base, 0)  # local context length
        page_slot = block_tables[jnp.arange(n), loc_pos // ps]  # (n,)
        page_slot = jnp.where(ctx_lens > 0, page_slot, ecfg.num_pages)
        in_page = loc_pos % ps

        if self.flavor == "mla":
            r_lat, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            scale = _mla_scale(cfg)

            def body(seg, p_i, cp, rp, rc_i, rr_i, xx):
                # rc_i: (n, R, r), rr_i: (n, R, dr)
                def attend_latent(q_lat, qr, ckv_new, krope_new):
                    cp2 = cp.at[page_slot, in_page].set(
                        ckv_new[:, 0].astype(cp.dtype))
                    rp2 = rp.at[page_slot, in_page].set(
                        krope_new[:, 0].astype(rp.dtype))
                    ckv_all = cp2[block_tables].reshape(n, -1, r_lat)
                    kr_all = rp2[block_tables].reshape(n, -1, dr)
                    q_eff, k_eff, v_eff = mla_effective_kv(
                        q_lat, qr, ckv_all.astype(q_lat.dtype),
                        kr_all.astype(q_lat.dtype))
                    s_loc = k_eff.shape[1]
                    mask_l = (jnp.arange(s_loc)[None, :] <
                              loc_lens[:, None])[:, None, :]
                    o_l, m_l, l_l = attention_partial(q_eff, k_eff, v_eff,
                                                      mask_l, scale=scale)
                    kr_eff, vr_eff = mla_effective_ctx(
                        rc_i.astype(q_lat.dtype), rr_i.astype(q_lat.dtype))
                    mask_r = (jnp.arange(n_remote)[None, :] <
                              r_base[:, None])[:, None, :]
                    o_r, m_r, l_r = attention_partial(q_eff, kr_eff, vr_eff,
                                                      mask_r, scale=scale)
                    att = merge_partials_tree([o_l, o_r], [m_l, m_r],
                                              [l_l, l_r])
                    return att[..., :r_lat].astype(q_lat.dtype), (cp2, rp2)

                y, (cp2, rp2) = mla_layer(cfg, p_i, xx, positions[:, None],
                                          attend_latent,
                                          mlp_fn=self._mlp_fn(seg))
                return y, cp2, rp2
        else:
            def body(seg, p_i, kp, vp, rk_i, rv_i, xx):
                # rk_i: (n, R, Hkv, Dh)
                def attend(q, k, v):
                    kp2 = kp.at[page_slot, in_page].set(
                        k[:, 0].astype(kp.dtype))
                    vp2 = vp.at[page_slot, in_page].set(
                        v[:, 0].astype(vp.dtype))
                    kall = kp2[block_tables].reshape(
                        n, -1, cfg.num_kv_heads, cfg.head_dim)
                    vall = vp2[block_tables].reshape(
                        n, -1, cfg.num_kv_heads, cfg.head_dim)
                    s_loc = kall.shape[1]
                    mask_l = (jnp.arange(s_loc)[None, :] <
                              loc_lens[:, None])[:, None, :]  # (n, 1, S_loc)
                    o_l, m_l, l_l = attention_partial(q, kall, vall, mask_l)
                    mask_r = (jnp.arange(n_remote)[None, :] <
                              r_base[:, None])[:, None, :]
                    o_r, m_r, l_r = attention_partial(q, rk_i, rv_i, mask_r)
                    att = merge_partials_tree([o_l, o_r], [m_l, m_r],
                                              [l_l, l_r])  # (n, 1, H, Dh)
                    return att.astype(q.dtype), (kp2, vp2)

                y, (kp2, vp2) = gqa_layer(cfg, p_i, xx, positions[:, None],
                                          attend, mlp_fn=self._mlp_fn(seg))
                return y, kp2, vp2

        x, k_pages, v_pages = self._run_segments(params, k_pages, v_pages,
                                                 rk, rv, x, body)
        x = rms_norm(params["final_norm"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x, cfg.vocab_size,
                         fp32=cfg.logits_fp32)[:, 0]
        return logits, k_pages, v_pages

    # -- ServingBackend protocol -------------------------------------------------

    def add_request(self, req: Request) -> None:
        if req.prompt_len + req.max_new_tokens > self.max_context_len:
            raise ValueError(
                f"request {req.request_id} needs "
                f"{req.prompt_len + req.max_new_tokens} context tokens, "
                f"engine limit is {self.max_context_len}")
        if req.parent_id is not None and any(
                r.request_id == req.parent_id for r in self.scheduler.waiting):
            # best-of-n sibling: COW-forked off the parent's prefill instead
            # of prefilling again (falls back to a plain request if no slot
            # is free at fork time)
            self._pending_forks.setdefault(req.parent_id, []).append(req)
            return
        self.scheduler.add_request(req)

    @property
    def has_work(self) -> bool:
        return bool(self.scheduler.waiting or self.scheduler.running
                    or self._pending_forks)

    def clock(self) -> Optional[float]:
        return None  # wall-clock backend: the caller supplies `now`

    def _ctx_arrays(self):
        n = self.ecfg.max_slots
        bt = np.zeros((n, self.max_pages_per_seq), np.int32)
        lens = np.zeros(n, np.int32)
        pos = np.zeros(n, np.int32)
        toks = np.zeros(n, np.int32)
        return bt, lens, pos, toks

    def charge_network(self, seconds: float) -> None:
        """Record modeled network time (payload copy / lease RPC). A
        wall-clock engine cannot advance its clock, so this only feeds the
        ``net_time`` stat (the virtual-clock SimBackend advances time)."""
        self.net_time += seconds
        if self.trace is not None:
            self.trace.instant("net", "charge", seconds=seconds)

    # -- zero-copy remote prefixes (borrowed rBlocks) -----------------------------

    def _check_zero_copy_ok(self) -> None:
        if self.remote_reader is None:
            raise RuntimeError(
                "request holds a zero-copy lease but no remote_reader is "
                "wired — RouterBackend must connect creditor pools")
        if self._window is not None:
            raise RuntimeError(
                "zero-copy remote prefixes are unsupported with sliding-"
                "window attention (the remote partial ignores the window)")

    def _lease_kv(self, lease):
        """(L, R, *token_shape) payloads of a lease's borrowed pages (one
        array per pool), gathered from the creditor's pools ONCE per lease
        and cached: the pages are pinned on the board, refcounted through
        the home allocator, and never written (any writer COWs a shared
        page first), so their contents are immutable for the lease's
        lifetime — re-gathering per decode step would put a pool-sized
        gather on the hot path."""
        key = id(lease)
        hit = self._lease_kv_cache.get(key)
        if hit is None:
            check_schema(self.kv_layout.schema,
                         getattr(lease, "schema", None),
                         where="zero-copy lease read")
            hk, hv = self.remote_reader(lease.home)
            idx = jnp.asarray(lease.blocks, jnp.int32)
            L = self.nlayers
            pa, pb = self.kv_layout.pools
            hit = (hk[:, idx].reshape((L, -1) + pa.token_shape),
                   hv[:, idx].reshape((L, -1) + pb.token_shape))
            self._lease_kv_cache[key] = hit
        return hit

    def _prune_lease_cache(self) -> None:
        live = {id(l) for l in self.scheduler.leases.values()}
        for key in [k for k in self._lease_kv_cache if k not in live]:
            del self._lease_kv_cache[key]

    def _lease_kv_chunk(self, lease):
        """(L, Rpad, *token_shape) borrowed payloads, pow2-padded (pad
        tokens are masked by ``r_base`` inside the jitted chunk fn)."""
        k, v = self._lease_kv(lease)
        pad = _pow2_bucket(lease.num_pages, 1) * self.ecfg.page_size \
            - lease.num_tokens
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * (k.ndim - 2))
            v = jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
        return k, v

    def _lease_kv_batch(self, row_reqs):
        """(L, n, Rpad, *token_shape) stacked borrowed payloads for a
        decode batch (zero rows for slots without a lease)."""
        leases = self.scheduler.leases
        L = self.nlayers
        pa, pb = self.kv_layout.pools
        rmax = max(leases[r.request_id].num_pages for r in row_reqs
                   if r is not None and r.request_id in leases)
        rpad = _pow2_bucket(rmax, 1) * self.ecfg.page_size
        rk = jnp.zeros((L, self.ecfg.max_slots, rpad) + pa.token_shape,
                       self.k_pages.dtype)
        rv = jnp.zeros((L, self.ecfg.max_slots, rpad) + pb.token_shape,
                       self.v_pages.dtype)
        for slot, req in enumerate(row_reqs):
            if req is None or req.request_id not in leases:
                continue
            lease = leases[req.request_id]
            k, v = self._lease_kv(lease)
            rk = rk.at[:, slot, :lease.num_tokens].set(k)
            rv = rv.at[:, slot, :lease.num_tokens].set(v)
        return rk, rv

    # -- per-request sampling ----------------------------------------------------

    def _sp_of(self, req: Request) -> SamplingParams:
        return req.sampling if req.sampling is not None else self._default_sp

    def _seed_of(self, req: Request) -> int:
        sp = self._sp_of(req)
        if sp.seed is not None:
            return sp.seed & 0x7FFFFFFF
        return (self.ecfg.seed * 1_000_003 + req.request_id * 7919
                + 0x5BD1) & 0x7FFFFFFF

    def _sample_rows(self, logits, reqs_by_row):
        """Fused per-slot sampling. ``reqs_by_row``: list (len = batch rows)
        of Request or None (inactive row). Returns (tokens, logprobs) np."""
        n = logits.shape[0]
        temp = np.zeros(n, np.float32)
        topk = np.zeros(n, np.int32)
        topp = np.ones(n, np.float32)
        seeds = np.zeros(n, np.int32)
        steps = np.zeros(n, np.int32)
        for i, req in enumerate(reqs_by_row):
            if req is None:
                continue
            sp = self._sp_of(req)
            temp[i] = sp.temperature
            topk[i] = sp.top_k
            topp[i] = sp.top_p
            seeds[i] = self._seed_of(req)
            # cumulative token index: keeps the stream aligned across
            # preemption/recompute (committed tokens advance the counter)
            steps[i] = req.total_generated
        toks, lps = self._sample_fn(logits, jnp.asarray(seeds),
                                    jnp.asarray(steps), jnp.asarray(temp),
                                    jnp.asarray(topk), jnp.asarray(topp))
        return np.asarray(toks), np.asarray(lps)

    def _sample_one(self, req: Request, logits_row):
        toks, lps = self._sample_rows(logits_row[None], [req])
        return int(toks[0]), float(lps[0])

    def _emit(self, req: Request, slot: int, tok: int, lp: float,
              now: float) -> None:
        req.output.append(tok)
        req.cumulative_logprob += lp
        req.logprobs.append(lp)
        req.record_token_time(now)
        self.last_token[slot] = tok

    # -- engine loop ------------------------------------------------------------

    def step(self, now: Optional[float] = None) -> List[Request]:
        """Run ONE iteration (ORCA's unit of scheduling)."""
        now = time.monotonic() if now is None else now
        tr = self.trace
        t_wall0 = 0.0
        if tr is not None:
            # scheduler events default to `now`; sub-iteration slices
            # (chunk executions) are offset by elapsed monotonic time
            tr.now = now
            tr.iteration = self.iterations
            t_wall0 = time.monotonic()
        plan = self.scheduler.schedule()
        if self._lease_kv_cache:  # drop gathers of released leases
            self._prune_lease_cache()
        # release slots of preempted requests
        self.preemptions += len(plan.preempted)
        for req in plan.preempted:
            if req.request_id in self.slots:
                self.free_slots.append(self.slots.pop(req.request_id))
        # swap transfers already ran via the scheduler hooks; here only the
        # decode slots move: a swapped-out request gives its slot up, a
        # swapped-in one claims a fresh slot and re-arms its input token
        # (the last sampled token, whose KV was never written — it resumes
        # decode exactly where the swap interrupted it)
        for req, _pairs in plan.swap_out + plan.swap_issue:
            if req.request_id in self.slots:
                self.free_slots.append(self.slots.pop(req.request_id))
        # a cancelled speculative swap re-enters decode this iteration:
        # its pages never left the device, so only the slot comes back
        for req, _pairs in plan.swap_in + plan.swap_cancel:
            slot = self.free_slots.pop()
            self.slots[req.request_id] = slot
            if req.output:
                self.last_token[slot] = req.output[-1]
        if plan.empty:
            # a self-preempted request can leave an otherwise-empty plan:
            # run completion anyway so the max_preemptions drop policy
            # applies (otherwise it bounces in waiting forever)
            return self.scheduler.complete_iteration(plan, now) \
                if plan.preempted else []
        # COW: copy replaced shared pages before anything writes this
        # iteration (the old block keeps its pre-iteration contents until
        # the decode/prefill writes below)
        if plan.cow:
            old = jnp.asarray([o for o, _ in plan.cow], jnp.int32)
            new = jnp.asarray([w for _, w in plan.cow], jnp.int32)
            self.k_pages = self.k_pages.at[:, new].set(self.k_pages[:, old])
            self.v_pages = self.v_pages.at[:, new].set(self.v_pages[:, old])

        # --- prefill chunks (initiation phase) ---
        forked: List[Request] = []
        ps = self.ecfg.page_size
        for ch in plan.chunks:
            req = ch.req
            if req.request_id not in self.slots:
                # first chunk: claim the decode slot the request will keep
                self.slots[req.request_id] = self.free_slots.pop()
            slot = self.slots[req.request_id]
            if req.scheduled_time is None:
                req.scheduled_time = now
            table = self.scheduler.tables[req.request_id]
            # positions [0, r_base) are served from a creditor's pages
            # (zero-copy lease); the local table covers [r_base, end)
            r_base = self.scheduler.remote_tokens_of(req.request_id)
            n_ctx_pages = -(-(ch.end - r_base) // ps)  # ceil, local pages
            npg_pad = _pow2_bucket(n_ctx_pages, 1)
            # pad with a REAL page, not the trash page: pad key positions
            # are causally masked either way (they sit past every real
            # query), but the trash page holds NaN K/V (inactive decode
            # slots write their fully-masked attention output there) and a
            # gathered NaN poisons the masked value einsum (0 * NaN = NaN)
            page_arr = np.full(npg_pad, table.blocks[0], np.int32)
            page_arr[:n_ctx_pages] = table.blocks[:n_ctx_pages]
            s_pad = _pow2_bucket(ch.length)
            tok_arr = np.zeros(s_pad, np.int32)
            tok_arr[:ch.length] = req.prompt[ch.start:ch.end]
            if r_base:
                self._check_zero_copy_ok()
                rk, rv = self._lease_kv_chunk(
                    self.scheduler.leases[req.request_id])
            else:
                rk, rv = self._no_remote(self.k_pages.dtype)
            t_chunk0 = time.monotonic() if tr is not None else 0.0
            logits, self.k_pages, self.v_pages = self._prefill_chunk_fn(
                self.params, self.k_pages, self.v_pages,
                jnp.asarray(tok_arr)[None], jnp.asarray(page_arr),
                jnp.int32(ch.start), jnp.int32(ch.length), jnp.int32(r_base),
                rk, rv)
            if tr is not None:
                tr.complete("engine", "chunk", rid=req.request_id,
                            ts=now + (t_chunk0 - t_wall0),
                            dur=time.monotonic() - t_chunk0,
                            start=ch.start, length=ch.length,
                            last=ch.is_last)
            if ch.is_last:
                tok, lp = self._sample_one(req, logits)
                self._emit(req, slot, tok, lp, now)
                forked.extend(self._fork_children(req, logits, now))

        # best-of-n children join the plan so completion/insertion sees them
        plan.prefill.extend(forked)

        # --- fused decode step (increment phase) ---
        decode_reqs = [r for r in plan.decode]
        if decode_reqs:
            bt, lens, pos, toks = self._ctx_arrays()
            rbase = np.zeros(self.ecfg.max_slots, np.int32)
            row_reqs: List[Optional[Request]] = [None] * self.ecfg.max_slots
            for req in decode_reqs:
                slot = self.slots[req.request_id]
                table = self.scheduler.tables[req.request_id]
                bt[slot, :len(table.blocks)] = table.blocks
                # input token t_g sits at absolute position ctx_len-1; after
                # its KV is written the attention span is ctx_len tokens
                # (scheduler already grew the table by one for it)
                lens[slot] = req.context_len
                pos[slot] = req.context_len - 1
                toks[slot] = self.last_token[slot]
                rbase[slot] = self.scheduler.remote_tokens_of(req.request_id)
                row_reqs[slot] = req
            if rbase.any():
                # >=1 slot reads a borrowed prefix: local paged partial +
                # remote partial, merged (DistAttention). Fully-local slots
                # ride along with r_base = 0.
                self._check_zero_copy_ok()
                rk, rv = self._lease_kv_batch(row_reqs)
                logits, self.k_pages, self.v_pages = self._decode_zc_fn(
                    self.params, self.k_pages, self.v_pages,
                    jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(bt),
                    jnp.asarray(lens), jnp.asarray(rbase), rk, rv)
            else:
                logits, self.k_pages, self.v_pages = self._decode_fn(
                    self.params, self.k_pages, self.v_pages,
                    jnp.asarray(toks), jnp.asarray(pos), jnp.asarray(bt),
                    jnp.asarray(lens))
            sampled, lps = self._sample_rows(logits, row_reqs)
            for req in decode_reqs:
                slot = self.slots[req.request_id]
                self._emit(req, slot, int(sampled[slot]), float(lps[slot]),
                           now)

        finished = self.scheduler.complete_iteration(plan, now)
        for req in finished:
            if req.request_id in self.slots:
                self.free_slots.append(self.slots.pop(req.request_id))
        if tr is not None:
            dur = time.monotonic() - t_wall0
            tr.complete("engine", "iteration", ts=now, dur=dur,
                        tokens=plan.token_count(),
                        decodes=len(plan.decode), chunks=len(plan.chunks))
            m = self.metrics
            m.gauge("kv_util_frac",
                    self.allocator.num_used / self.allocator.num_blocks)
            m.gauge("prefill_backlog_tokens",
                    self.scheduler.prefill_backlog_tokens())
            m.gauge("budget_fill_frac",
                    plan.token_count() / self.scheduler.max_tokens)
            m.gauge("running", len(self.scheduler.running))
            m.gauge("waiting", len(self.scheduler.waiting))
            m.gauge("net_time_s", self.net_time)
            if self.allocator.num_host_blocks:
                m.gauge("swapped_pages", self.allocator.swapped_pages)
                m.gauge("swap_pending_pages",
                        self.allocator.pending_out_pages)
            if self.prefix_cache is not None:
                m.gauge("prefix_hit_rate", self.prefix_cache.hit_rate)
            m.count("tokens", plan.token_count())
            m.count("decode_tokens", len(plan.decode))
            m.count("prefill_tokens", sum(c.length for c in plan.chunks))
            m.count("preemptions", len(plan.preempted))
            m.count("swap_outs", len(plan.swap_out) + len(plan.swap_complete))
            m.count("swap_ins", len(plan.swap_in))
            m.count("swap_issues", len(plan.swap_issue))
            m.count("swap_cancels", len(plan.swap_cancel))
            m.observe("iteration_time_s", dur)
            m.snapshot(now, self.iterations)
        self.iterations += 1
        return finished

    def _fork_children(self, parent: Request, logits, now) -> List[Request]:
        """COW-fork best-of-n siblings off ``parent``'s fresh prefill: each
        child shares the prompt pages (no second prefill) and samples its
        own first token from the same last-position logits."""
        children = self._pending_forks.pop(parent.request_id, [])
        forked = []
        for child in children:
            if self.free_slots and \
                    len(self.scheduler.running) < self.scheduler.max_running:
                self.scheduler.fork_from(parent, child)
                slot = self.free_slots.pop()
                self.slots[child.request_id] = slot
                child.scheduled_time = now
                child.first_token_time = now
                if self.trace is not None:
                    self.trace.instant("req", "first_token",
                                       rid=child.request_id)
                tok, lp = self._sample_one(child, logits)
                self._emit(child, slot, tok, lp, now)
                forked.append(child)
            else:
                # no slot free: fall back to an ordinary request (with the
                # prefix cache on it still reuses the parent's prompt pages)
                self.scheduler.add_request(child)
        return forked

    # -- host swap tier -----------------------------------------------------------

    def _swap_out_copy(self, pairs) -> None:
        """Device -> host page payloads for one table's swap-out (scheduler
        hook, called before the freed device pages can be reallocated)."""
        devs = jnp.asarray([d for d, _ in pairs], jnp.int32)
        hosts = [h for _, h in pairs]
        self.h_k_pages[:, hosts] = np.asarray(self.k_pages[:, devs])
        self.h_v_pages[:, hosts] = np.asarray(self.v_pages[:, devs])
        self.swapped_out += 1

    def _swap_out_issue(self, pairs) -> None:
        """Issue half of a double-buffered swap-out: the DMA is in flight
        against the next iteration's compute. The source device pages stay
        allocated through the allocator's pending ledger and are never
        written while pending, so the payload copy is deferred to the
        complete half — byte-identical to copying now, with nothing
        serialized into this iteration."""

    def _swap_out_complete(self, pairs) -> None:
        """Complete half: materialize the device->host payloads (sources
        untouched since issue), called by the scheduler *before* the
        allocator decrefs the device pages."""
        self._swap_out_copy(pairs)

    def _swap_out_cancel(self, pairs) -> None:
        """Pressure receded before the transfer resolved: the pages never
        left the device, nothing to copy (host blocks are returned by the
        allocator's cancel path)."""

    def _swap_in_copy(self, pairs) -> None:
        """Host -> device onto the freshly allocated blocks (batched: one
        pool update per direction, same idiom as the COW copy in step)."""
        hosts = [h for h, _ in pairs]
        devs = jnp.asarray([d for _, d in pairs], jnp.int32)
        self.k_pages = self.k_pages.at[:, devs].set(
            jnp.asarray(self.h_k_pages[:, hosts]))
        self.v_pages = self.v_pages.at[:, devs].set(
            jnp.asarray(self.h_v_pages[:, hosts]))
        self.swapped_in += 1

    def _spill_out_copy(self, pairs) -> None:
        """Prefix-cache spill movers: same transfers as a table swap, kept
        out of the swapped_out/in event counters."""
        devs = jnp.asarray([d for d, _ in pairs], jnp.int32)
        hosts = [h for _, h in pairs]
        self.h_k_pages[:, hosts] = np.asarray(self.k_pages[:, devs])
        self.h_v_pages[:, hosts] = np.asarray(self.v_pages[:, devs])

    def _spill_in_copy(self, pairs) -> None:
        hosts = [h for h, _ in pairs]
        devs = jnp.asarray([d for _, d in pairs], jnp.int32)
        self.k_pages = self.k_pages.at[:, devs].set(
            jnp.asarray(self.h_k_pages[:, hosts]))
        self.v_pages = self.v_pages.at[:, devs].set(
            jnp.asarray(self.h_v_pages[:, hosts]))

    # -- cross-instance prefix sharing -------------------------------------------

    def export_page_payload(self, block: int):
        """KV contents of one physical page as host arrays, tagged with the
        engine's :attr:`KVPageLayout.schema` — the payload a cluster router
        publishes to the distkv board so a peer engine (same arch + params)
        can adopt the page without recomputing it. An importer with a
        different layout refuses the payload loudly."""
        return (self.kv_layout.schema,
                np.asarray(self.k_pages[:, block]),
                np.asarray(self.v_pages[:, block]))

    def import_page_payloads(self, blocks, payloads) -> None:
        """Materialize published pages into freshly adopted local blocks
        (counterpart of :meth:`export_page_payload`). Every payload's
        schema tag is validated against the local layout before any pool is
        touched — reinterpreting foreign-layout bytes would corrupt pages
        silently. Batched: one update per KV pool regardless of page count
        — ``.at[].set`` outside jit copies the whole pool, so per-page
        calls would copy it 2x per page (same batching the COW path in
        :meth:`step` uses)."""
        if not blocks:
            return
        for p in payloads:
            check_schema(self.kv_layout.schema, p[0],
                         where="page-payload import")
        idx = jnp.asarray(list(blocks), jnp.int32)
        k = jnp.stack([jnp.asarray(p[1], self.k_pages.dtype)
                       for p in payloads], axis=1)  # (L, n, ps, *token_shape)
        v = jnp.stack([jnp.asarray(p[2], self.v_pages.dtype)
                       for p in payloads], axis=1)
        self.k_pages = self.k_pages.at[:, idx].set(k)
        self.v_pages = self.v_pages.at[:, idx].set(v)

    # -- disaggregated prefill/decode handoff -------------------------------------

    @property
    def free_decode_slots(self) -> int:
        """Decode slots a KVHandoff placement can still claim."""
        return min(len(self.free_slots),
                   self.scheduler.max_running - len(self.scheduler.running))

    def release_for_handoff(self, req: Request) -> None:
        """Prefill side of a KV handoff: return the request's decode slot
        and detach it from the scheduler WITHOUT finishing. The caller must
        already have secured the KV (exported payloads / lent the blocks)."""
        slot = self.slots.pop(req.request_id, None)
        if slot is not None:
            self.free_slots.append(slot)
        self.scheduler.release_request(req)

    def install_for_handoff(self, req: Request, table: BlockTable,
                            lease=None) -> None:
        """Decode side of a KV handoff: claim a slot and enter decode
        directly. ``table`` holds the locally-materialized KV pages (all of
        them under migration; only the partial tail page under a zero-copy
        lease, whose full pages stay on the prefill host)."""
        if lease is not None:
            self._check_zero_copy_ok()
            check_schema(self.kv_layout.schema,
                         getattr(lease, "schema", None),
                         where="KV handoff install")
        slot = self.free_slots.pop()
        self.slots[req.request_id] = slot
        # the decode input token is the first token, sampled on the prefill
        # instance from its final chunk's logits
        self.last_token[slot] = req.output[-1]
        self.scheduler.install_running(req, table, lease)

    def run_to_completion(self, max_iters: int = 10_000) -> None:
        for _ in range(max_iters):
            self.step()
            if not self.has_work:
                return
        raise RuntimeError("engine did not drain")

    # -- stats ------------------------------------------------------------------
    def kv_utilization(self) -> float:
        return self.allocator.utilization(list(self.scheduler.tables.values()))

    def prefix_cache_stats(self) -> Dict[str, float]:
        if self.prefix_cache is None:
            return {}
        return self.prefix_cache.stats()
