"""Mixture-of-Experts with capacity-based dispatch (expert parallelism).

Top-k routing with a static per-expert capacity so all shapes are
XLA-friendly; expert weights are stacked ``(E, d, ff)`` and sharded over the
``model`` mesh axis (expert parallelism). FLOP cost scales with
``top_k x tokens`` (via capacity), not ``num_experts x tokens`` — the roofline
sees the *active* compute, as in the real system.

Shared experts (DeepSeek-V2) are a plain dense MLP of width
``num_shared_experts x moe_d_ff`` applied to every token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import NO_POLICY, ShardingPolicy, dense_init, mlp, mlp_init


def moe_init(cfg, key, dtype):
    ks = jax.random.split(key, 5)
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "gate": jax.random.normal(ks[1], (e, d, ff), dtype) * scale,
        "up": jax.random.normal(ks[2], (e, d, ff), dtype) * scale,
        "down": jax.random.normal(ks[3], (e, ff, d), dtype) * (1.0 / jnp.sqrt(ff)),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, cfg.num_shared_experts * ff, dtype,
                               gated=True)
    return p


def capacity(cfg, num_tokens: int) -> int:
    c = int(num_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_forward(cfg, p, x, *, policy: ShardingPolicy = NO_POLICY,
                return_aux: bool = False):
    """x: (B, S, D) -> (B, S, D) [+ aux load-balance loss].

    GShard-style *grouped* dispatch: tokens are split into G groups (the
    launcher's policy sets G = data-parallel shards), each group computes
    its own expert positions with a group-local cumsum (no cross-shard
    sequential dependency) and gets a private slice of every expert's
    capacity. Dispatch/combine are scatters with ``mode='drop'`` so overflow
    tokens fall through to the residual without a dummy expert row — keeping
    the expert axis exactly E for clean expert-parallel sharding.

    Under a mesh policy the expert-parallel ``shard_map`` path
    (:meth:`MeshPolicy.moe_apply`) replaces this function entirely."""
    if hasattr(policy, "moe_apply"):
        out = policy.moe_apply(cfg, p, x, return_aux)
        if out is not None:
            return out
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    t = b * s
    g = getattr(policy, "moe_groups", 1)
    if t % g:
        g = 1
    tg = t // g
    xt = x.reshape(g, tg, d)
    cap = max(8, capacity(cfg, tg))

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])  # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)  # (G, Tg, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # group-local position of each (token, choice) in its expert's capacity
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)  # (G, Tg, k, E)
    flat = onehot.reshape(g, tg * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tg, k, e)
    pos = (pos_in_expert * onehot).sum(-1)  # (G, Tg, k)
    keep = pos < cap

    # dispatch: (G, E, cap, D); out-of-capacity scatters are dropped
    gidx = jnp.broadcast_to(jnp.arange(g)[:, None, None], (g, tg, k))
    pidx = jnp.where(keep, pos, cap)  # cap is out of range -> mode=drop
    contrib = jnp.where(keep[..., None], xt[:, :, None, :], 0)
    disp = jnp.zeros((g, e, cap, d), x.dtype).at[
        gidx, topi, pidx].add(contrib, mode="drop")
    disp = policy.act(disp, "expert_gecd")

    # expert MLPs: gated SwiGLU, batched over (G, E)
    gate = jnp.einsum("gecd,edf->gecf", disp, p["gate"])
    up = jnp.einsum("gecd,edf->gecf", disp, p["up"])
    h = jax.nn.silu(gate) * up
    h = policy.act(h, "expert_gecf")
    out = jnp.einsum("gecf,efd->gecd", h, p["down"])
    out = policy.act(out, "expert_gecd")

    # combine: gather each (token, choice)'s slot back (group-local)
    gathered = out[gidx, topi, jnp.where(keep, pos, 0)]  # (G, Tg, k, D)
    combined = (gathered * (topv * keep).astype(x.dtype)[..., None]).sum(2)
    y = combined.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x, policy)

    if return_aux:
        # Switch-style load-balance loss
        frac_tokens = jnp.mean(
            jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
        frac_probs = jnp.mean(probs, axis=(0, 1))
        aux = e * jnp.sum(frac_tokens * frac_probs)
        return y, aux
    return y
