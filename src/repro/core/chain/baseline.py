"""PETALS' original ``find_best_chain`` (paper §II.A.3).

Shortest path from block 0 to block L over a DAG whose nodes are block
boundaries; an edge (i -> j, server s) exists when s hosts blocks [i, j) and
costs network latency + compute time — exactly the paper's description of
the client routing in [Borzunov et al., 2023, Alg. 1].

Two single-objective modes (as in PETALS):
* ``min_latency``  — edge weight = s.latency + (j - i) / s.throughput
* ``max_throughput`` — pick, per block, the fastest server (bottleneck
  throughput maximization for batched fine-tuning workloads).
"""

from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

from repro.core.chain.registry import Fleet, ServerInfo


class Chain(List[Tuple[ServerInfo, int, int]]):
    """[(server, start_block, end_block), ...] consecutive spans."""

    @property
    def total_latency(self) -> float:
        return sum(s.latency for s, _, _ in self)

    @property
    def total_compute(self) -> float:
        return sum(s.compute_time(e - b) for s, b, e in self)

    @property
    def total_time(self) -> float:
        return self.total_latency + self.total_compute

    @property
    def bottleneck_throughput(self) -> float:
        return min((s.throughput for s, _, _ in self), default=0.0)


def find_best_chain(fleet: Fleet, *, mode: str = "min_latency") -> Optional[Chain]:
    """Dijkstra over block boundaries 0..L. Edge relaxation considers every
    server s and every usable sub-span of s starting at the current boundary."""
    L = fleet.num_blocks
    if mode == "max_throughput":
        return _greedy_throughput_chain(fleet)

    dist = [float("inf")] * (L + 1)
    prev: List[Optional[Tuple[int, ServerInfo]]] = [None] * (L + 1)
    dist[0] = 0.0
    pq = [(0.0, 0)]
    while pq:
        d, i = heapq.heappop(pq)
        if d > dist[i] or i == L:
            continue
        for s in fleet.servers:
            if not s.hosts(i):
                continue
            # use server s for blocks [i, j), any j up to its end
            for j in range(i + 1, min(s.end_block, L) + 1):
                w = s.latency + s.compute_time(j - i)
                if d + w < dist[j]:
                    dist[j] = d + w
                    prev[j] = (i, s)
                    heapq.heappush(pq, (dist[j], j))
    if dist[L] == float("inf"):
        return None
    chain = Chain()
    j = L
    while j > 0:
        i, s = prev[j]
        chain.insert(0, (s, i, j))
        j = i
    return chain


def _greedy_throughput_chain(fleet: Fleet) -> Optional[Chain]:
    """Maximize bottleneck throughput: binary-search the throughput floor,
    keep only servers above it, and check reachability."""
    thrs = sorted({s.throughput for s in fleet.servers}, reverse=True)
    best = None
    for floor in thrs:
        sub = Fleet(fleet.num_blocks,
                    [s for s in fleet.servers if s.throughput >= floor])
        chain = find_best_chain(sub, mode="min_latency") if sub.servers else None
        if chain is not None:
            return chain  # highest floor that still covers -> done
    return best
