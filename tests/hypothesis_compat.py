"""Import hypothesis if available; otherwise skip property tests gracefully.

The tier-1 container does not ship ``hypothesis``; without this shim the
modules using ``@given`` fail at *collection* and take the whole ``-x`` run
down with them. With it, property tests simply skip and every example-based
test still runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``strategies``: any strategy call returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")
