"""Exporters: Chrome/Perfetto trace-event JSON and metrics-timeline dumps.

Trace-event mapping (see the Trace Event Format spec the Chrome tools
consume): timestamps are microseconds; ``pid`` is the serving instance
(one process track per instance, named via ``M`` metadata); complete
(``X``) events carry ``dur``; per-request spans use async-nestable
``b``/``e`` pairs matched on (cat, id); counter (``C``) events render as
stacked area tracks. Open the output in https://ui.perfetto.dev or
``chrome://tracing``.

``validate_trace_events`` is the structural schema check shared by the
``tools/validate_trace.py`` CLI and the exporter golden tests.
"""

from __future__ import annotations

import csv
import json
from typing import Dict, Iterable, List, Optional

from .tracer import Event, PH_BEGIN, PH_COMPLETE, PH_COUNTER, PH_END, \
    PH_INSTANT

_S_TO_US = 1e6


def to_chrome_trace(events: Iterable[Event]) -> dict:
    """Convert tracer events to a trace-event JSON object (dict)."""
    out: List[dict] = []
    instances = set()
    for e in events:
        instances.add(e.instance)
        te: Dict[str, object] = {
            "name": e.name,
            "cat": e.cat,
            "ph": e.ph,
            "ts": e.ts * _S_TO_US,
            "pid": e.instance,
            "tid": 0,
        }
        if e.ph == PH_COMPLETE:
            te["dur"] = (e.dur or 0.0) * _S_TO_US
        elif e.ph == PH_INSTANT:
            te["s"] = "t"  # thread-scoped instant
        elif e.ph in (PH_BEGIN, PH_END):
            # async-nestable span keyed by request id
            te["id"] = e.rid if e.rid is not None else 0
        args: Dict[str, object] = dict(e.args) if e.args else {}
        if e.ph != PH_COUNTER:
            if e.rid is not None:
                args.setdefault("rid", e.rid)
            args.setdefault("iteration", e.it)
        if args:
            te["args"] = args
        out.append(te)
    # name the per-instance process tracks
    for inst in sorted(instances):
        out.append({
            "name": "process_name",
            "ph": "M",
            "pid": inst,
            "tid": 0,
            "ts": 0,
            "args": {"name": f"instance {inst}"},
        })
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def export_chrome_trace(events: Iterable[Event], path: str) -> dict:
    """Write trace-event JSON to ``path``; returns the exported object."""
    obj = to_chrome_trace(events)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# ---------------------------------------------------------------------------
# Metrics timelines


def _flatten_timelines(
        timelines: Dict[int, List[Dict[str, float]]]) -> List[Dict]:
    """One row stream across instances, with an ``instance`` column."""
    rows: List[Dict] = []
    for inst in sorted(timelines):
        for row in timelines[inst]:
            r = {"instance": inst}
            r.update(row)
            rows.append(r)
    return rows


def export_metrics_csv(timelines: Dict[int, List[Dict[str, float]]],
                       path: str) -> int:
    """Write per-iteration metric rows as CSV (union of columns, blank
    where a row lacks a metric). Returns the number of data rows."""
    rows = _flatten_timelines(timelines)
    lead = ["instance", "ts", "iteration"]
    keys = sorted({k for r in rows for k in r} - set(lead))
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=lead + keys, restval="")
        w.writeheader()
        w.writerows(rows)
    return len(rows)


def export_metrics_json(timelines: Dict[int, List[Dict[str, float]]],
                        path: str) -> int:
    rows = _flatten_timelines(timelines)
    with open(path, "w") as f:
        json.dump(rows, f)
    return len(rows)


# ---------------------------------------------------------------------------
# Trace-event schema validation

_KNOWN_PH = {"X", "i", "I", "b", "e", "n", "B", "E", "C", "M", "s", "t",
             "f", "P"}


def validate_trace_events(obj: object) -> List[str]:
    """Structural validation of a trace-event JSON object.

    Returns a list of human-readable problems (empty ⇒ valid): top-level
    shape, required fields per event, known phase codes, non-negative
    durations, and async ``b``/``e`` balance per (cat, id, name).
    """
    errors: List[str] = []
    if isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level object lacks a 'traceEvents' list"]
    elif isinstance(obj, list):
        events = obj
    else:
        return ["trace must be a JSON object with 'traceEvents' or a list"]

    open_spans: Dict[tuple, int] = {}
    for i, e in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(e, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if not isinstance(ph, str) or ph not in _KNOWN_PH:
            errors.append(f"{where}: unknown or missing ph {ph!r}")
            continue
        if "name" not in e:
            errors.append(f"{where}: missing name")
        if ph != "M":
            if not isinstance(e.get("ts"), (int, float)):
                errors.append(f"{where}: missing numeric ts")
            if "pid" not in e:
                errors.append(f"{where}: missing pid")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)):
                errors.append(f"{where}: complete event missing dur")
            elif dur < 0:
                errors.append(f"{where}: negative dur {dur}")
        if ph in ("b", "e"):
            if "id" not in e:
                errors.append(f"{where}: async event missing id")
            else:
                key = (e.get("cat"), e.get("id"), e.get("name"))
                if ph == "b":
                    open_spans[key] = open_spans.get(key, 0) + 1
                else:
                    n = open_spans.get(key, 0)
                    if n <= 0:
                        errors.append(
                            f"{where}: async end without begin for {key}")
                    else:
                        open_spans[key] = n - 1
        if ph == "C" and not isinstance(e.get("args"), dict):
            errors.append(f"{where}: counter event missing args")
    for key, n in open_spans.items():
        if n != 0:
            errors.append(f"unclosed async span {key} (depth {n})")
    return errors


_SWAP_OUTCOMES = ("complete", "cancel", "orphaned")


def validate_swap_balance(obj: object) -> List[str]:
    """Check the host-swap invariants on an exported trace.

    Per request, ``sched.swap_out`` / ``sched.swap_in`` instants must
    alternate starting with an out: at any point in time a request is
    either device-resident (balance 0) or host-resident (balance 1).
    A trailing unmatched ``swap_out`` is legal — the request finished or
    was abandoned while swapped — so the final balance per rid may be 0
    or 1, never more.

    Overlapped (speculative) swap-outs add the ``swap.pending`` async
    span: every issue (``b``) must resolve in exactly one matching ``e``
    whose ``outcome`` is complete | cancel | orphaned — never two, never
    none — and while a request's pages are mid-flight it must do no work:
    no ``sched.admit``, ``sched.swap_in``, or prefill ``req.chunk`` may
    land strictly inside the span. Returns human-readable problems
    (empty ⇒ valid).
    """
    if isinstance(obj, dict):
        events = obj.get("traceEvents", [])
    elif isinstance(obj, list):
        events = obj
    else:
        return ["trace must be a JSON object with 'traceEvents' or a list"]
    swaps = []
    pending_open: Dict[object, float] = {}
    flights: Dict[object, List[tuple]] = {}  # rid -> [(issue_ts, end_ts)]
    activity: List[tuple] = []  # (ts, rid, cat.name)
    errors: List[str] = []
    for e in events:  # list order == emission order
        if not isinstance(e, dict):
            continue
        cat, name, ph = e.get("cat"), e.get("name"), e.get("ph")
        args = e.get("args") or {}
        ts = e.get("ts", 0.0)
        if cat == "sched" and name in ("swap_out", "swap_in"):
            swaps.append((ts, args.get("rid"), name))
        if (cat, name) in (("sched", "admit"), ("sched", "swap_in"),
                           ("req", "chunk")):
            activity.append((ts, args.get("rid"), f"{cat}.{name}"))
        if cat == "swap" and name == "pending":
            rid = e.get("id", args.get("rid"))
            if ph == "b":
                if rid in pending_open:
                    errors.append(f"rid {rid}: swap issue at ts={ts} while "
                                  f"a swap is already in flight")
                else:
                    pending_open[rid] = ts
            elif ph == "e":
                if rid not in pending_open:
                    errors.append(f"rid {rid}: swap resolution at ts={ts} "
                                  f"without an open issue")
                else:
                    flights.setdefault(rid, []).append(
                        (pending_open.pop(rid), ts))
                if args.get("outcome") not in _SWAP_OUTCOMES:
                    errors.append(
                        f"rid {rid}: swap resolution at ts={ts} has "
                        f"outcome {args.get('outcome')!r} (must be one of "
                        f"{'|'.join(_SWAP_OUTCOMES)})")
    for rid, ts in sorted(pending_open.items(), key=lambda kv: str(kv[0])):
        errors.append(f"rid {rid}: swap issued at ts={ts} never resolved")
    # a request whose pages are mid-flight does no work
    for ts, rid, what in activity:
        for t0, t1 in flights.get(rid, ()):
            if t0 < ts < t1:
                errors.append(f"rid {rid}: {what} at ts={ts} while its "
                              f"pages were in flight ({t0}..{t1})")
    balance: Dict[object, int] = {}
    for ts, rid, name in sorted(swaps, key=lambda s: s[0]):
        if rid is None:
            errors.append(f"sched.{name} at ts={ts} lacks a rid")
            continue
        b = balance.get(rid, 0)
        if name == "swap_out":
            if b != 0:
                errors.append(f"rid {rid}: swap_out at ts={ts} while "
                              f"already swapped out (balance {b})")
            balance[rid] = b + 1
        else:
            if b != 1:
                errors.append(f"rid {rid}: swap_in at ts={ts} without a "
                              f"prior swap_out (balance {b})")
            balance[rid] = b - 1
    for rid, b in sorted(balance.items(), key=lambda kv: str(kv[0])):
        if b not in (0, 1):
            errors.append(f"rid {rid}: final swap balance {b} "
                          f"(must be 0 or 1)")
    return errors
