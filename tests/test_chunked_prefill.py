"""Chunked prefill as a first-class scheduler mode.

Covers the PR's acceptance criteria: token-identity of chunked vs monolithic
prefill on both backends (same first sampled token AND same KV state),
chunked *suffix* prefill after a radix-cache hit, token-level (mid-page)
cache hits through the partial-page COW, preemption mid-prefill resuming
cleanly, and the budget invariant (no iteration exceeds
``max_tokens_per_iter`` under the chunking policies).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.paging import BlockAllocator
from repro.core.prefixcache import PrefixCache
from repro.core.scheduling import (CHUNK_POLICIES, IterationScheduler, Phase,
                                   Request)
from repro.serving.engine import EngineConfig, PagedEngine
from repro.serving.simulator import SimBackend, make_workload, simulate_paged

PS = 8  # page size used throughout


def _drive(s, *reqs, max_iters=500, start_it=0.0):
    for r in reqs:
        s.add_request(r)
    it = start_it
    for _ in range(max_iters):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            return it
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    raise AssertionError("scheduler did not drain")


# -- scheduler: chunk composition ----------------------------------------------

def test_long_prompt_chunks_across_iterations():
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=16)
    r = Request(0, 0.0, list(range(40)), max_new_tokens=2)
    s.add_request(r)

    plan = s.schedule()  # admission: first chunk
    assert [(c.start, c.length) for c in plan.chunks] == [(0, 16)]
    assert not plan.prefill and not plan.decode
    assert r.prefilled_len == 16 and r.phase == Phase.INITIATION
    s.complete_iteration(plan, 0.0)
    assert r.first_token_time is None, "TTFT must span all chunks"

    plan = s.schedule()  # continuation
    assert [(c.start, c.length) for c in plan.chunks] == [(16, 16)]
    assert not plan.prefill and not plan.decode
    s.complete_iteration(plan, 1.0)

    plan = s.schedule()  # final chunk: the request samples its first token
    assert [(c.start, c.length) for c in plan.chunks] == [(32, 8)]
    assert plan.prefill == [r]
    r.output.append(0)
    s.complete_iteration(plan, 2.0)
    assert r.first_token_time == 2.0
    assert r.phase == Phase.INCREMENT

    plan = s.schedule()  # now it decodes
    assert plan.decode == [r] and not plan.chunks


def test_decode_first_piggybacks_decodes_with_chunks():
    """Sarathi stall-free: the running decode gets its token EVERY iteration
    while the long prompt prefills in leftover-budget chunks."""
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=16,
                           chunk_policy="decode_first")
    short = Request(0, 0.0, list(range(4)), max_new_tokens=8)
    s.add_request(short)
    plan = s.schedule()
    short.output.append(0)
    s.complete_iteration(plan, 0.0)

    long = Request(1, 0.0, list(range(100, 145)), max_new_tokens=2)
    s.add_request(long)
    it = 1.0
    while long.prefilled_len < long.prompt_len:
        plan = s.schedule()
        assert short in plan.decode, \
            "decode must never stall behind the chunked prefill"
        assert plan.token_count() <= 16
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
        if short.phase == Phase.FINISHED:
            break
    # 45 tokens at 15/iter (budget 16 - 1 decode) = 3 iterations
    assert long.prefilled_len == long.prompt_len


def test_prefill_first_gives_budget_to_chunks():
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=16,
                           chunk_policy="prefill_first")
    short = Request(0, 0.0, list(range(4)), max_new_tokens=8)
    s.add_request(short)
    plan = s.schedule()
    short.output.append(0)
    s.complete_iteration(plan, 0.0)

    long = Request(1, 0.0, list(range(100, 164)), max_new_tokens=2)
    s.add_request(long)
    plan = s.schedule()
    # the chunk takes the whole budget; the decode stalls this iteration
    assert [(c.start, c.length) for c in plan.chunks] == [(0, 16)]
    assert short not in plan.decode
    assert plan.token_count() == 16


def test_prefill_first_no_decode_in_final_chunk_iteration():
    """Under prefill_first the decode planner runs AFTER the chunk
    planners: a request whose final chunk runs this iteration must not be
    granted a decode token too (it samples its first token from the
    prefill logits and decodes NEXT iteration) — else max_new_tokens=1
    would emit two tokens at once."""
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=32,
                           chunk_policy="prefill_first")
    r = Request(0, 0.0, list(range(8)), max_new_tokens=1)
    s.add_request(r)
    plan = s.schedule()
    assert plan.prefill == [r]
    assert r not in plan.decode, \
        "final-chunk request must not decode in the same iteration"
    # end to end on the sim: exactly one token comes out
    backend = SimBackend(num_blocks=100, block_size=PS,
                         chunk_policy="prefill_first")
    from repro.serving.api import LLMService
    svc = LLMService(backend)
    one = Request(0, 0.0, [], max_new_tokens=1, prompt_len=8)
    svc.submit_request(one)
    svc.drain()
    assert one.total_generated == 1


def test_monolithic_admits_over_budget_next_to_decodes():
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=16,
                           chunk_policy="monolithic")
    short = Request(0, 0.0, list(range(4)), max_new_tokens=8)
    s.add_request(short)
    plan = s.schedule()
    short.output.append(0)
    s.complete_iteration(plan, 0.0)

    long = Request(1, 0.0, list(range(100, 140)), max_new_tokens=2)
    s.add_request(long)
    plan = s.schedule()
    # one giant prefill right next to the decode (the stall baseline)
    assert short in plan.decode
    assert [(c.start, c.length) for c in plan.chunks] == [(0, 40)]
    assert plan.prefill == [long]


def test_solo_waits_for_idle_engine():
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=16, chunk_policy="solo")
    short = Request(0, 0.0, list(range(4)), max_new_tokens=3)
    s.add_request(short)
    plan = s.schedule()
    short.output.append(0)
    s.complete_iteration(plan, 0.0)

    long = Request(1, 0.0, list(range(100, 140)), max_new_tokens=2)
    s.add_request(long)
    it = 1.0
    while short.phase != Phase.FINISHED:
        plan = s.schedule()
        assert not plan.chunks, "legacy solo must wait for an idle engine"
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    plan = s.schedule()  # idle now: the whole prompt runs alone
    assert [(c.start, c.length) for c in plan.chunks] == [(0, 40)]


def test_preempt_resets_prefill_progress():
    """The recompute policy restarts chunked prefill from the front: a
    preempted mid-prefill request re-enters waiting with zero progress."""
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=8)
    long = Request(0, 0.0, list(range(40)), max_new_tokens=2)
    s.add_request(long)
    plan = s.schedule()
    s.complete_iteration(plan, 0.0)
    assert long.prefilled_len == 8  # one chunk in
    s._preempt(long)
    assert long.prefilled_len == 0
    assert long in s.waiting and long not in s.running
    assert a.num_free == 64 and not a.refcount


def test_preemption_mid_prefill_resumes_and_completes():
    """Engineered crunch: a decode needs a page while a long prompt is one
    token short of finishing its chunked prefill — the mid-prefill request
    is the victim, restarts from the front on re-admission, and still
    completes with no block leak."""
    # pool 11 pages x 8; budget 8; chunk_min 4 so the long prompt chunks at
    # 7 tokens/iter next to the short request's decode
    a = BlockAllocator(11, PS)
    s = IterationScheduler(a, max_tokens_per_iter=8, max_running=4,
                           prefill_chunk_min=4)
    short = Request(0, 0.0, list(range(14)), max_new_tokens=30)
    s.add_request(short)
    for it in range(3):  # chunks (0,8),(8,6) -> first token; then decode
        plan = s.schedule()
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, float(it))
    assert short.phase == Phase.INCREMENT
    long = Request(1, 0.0, list(range(100, 164)), max_new_tokens=2)
    s.add_request(long)
    preempted_mid_prefill = False
    it = 100.0
    for _ in range(300):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            break
        if long in plan.preempted and \
                long.prefilled_len < long.prompt_len:
            preempted_mid_prefill = True
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    assert preempted_mid_prefill, "scenario must preempt the mid-prefill req"
    assert long.preemptions >= 1
    assert short.phase == Phase.FINISHED and long.phase == Phase.FINISHED
    assert short.total_generated == 30 and long.total_generated == 2
    assert a.num_free == 11 and not a.refcount


def test_prefill_backlog_tokens():
    a = BlockAllocator(64, PS)
    s = IterationScheduler(a, max_tokens_per_iter=16)
    s.add_request(Request(0, 0.0, list(range(40)), max_new_tokens=2))
    s.add_request(Request(1, 0.0, list(range(24)), max_new_tokens=2))
    assert s.prefill_backlog_tokens() == 64  # both queued
    plan = s.schedule()  # req 0 admitted, 16/40 prefilled; req 1 queued
    s.complete_iteration(plan, 0.0)
    assert s.prefill_backlog_tokens() == (40 - 16) + 24


def test_bad_chunk_policy_rejected():
    a = BlockAllocator(8, PS)
    with pytest.raises(ValueError, match="chunk_policy"):
        IterationScheduler(a, chunk_policy="nope")


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from(["decode_first", "prefill_first"]))
def test_budget_never_exceeded_property(seed, policy):
    """Property: under the chunking policies no iteration plans more than
    ``max_tokens_per_iter`` flattened tokens, and everything still drains."""
    rng = np.random.default_rng(seed)
    a = BlockAllocator(64, PS)
    budget = int(rng.integers(8, 40))
    s = IterationScheduler(a, max_running=6, max_tokens_per_iter=budget,
                           chunk_policy=policy)
    reqs = [Request(i, 0.0, list(range(int(rng.integers(1, 90)))),
                    max_new_tokens=int(rng.integers(1, 12)))
            for i in range(5)]
    for r in reqs:
        s.add_request(r)
    for it in range(800):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            break
        assert plan.token_count() <= budget, \
            f"iteration exceeded the token budget under {policy}"
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, float(it))
    assert all(r.phase == Phase.FINISHED for r in reqs)
    assert a.num_free == 64 and not a.refcount


# -- token-level (mid-page) radix hits -----------------------------------------

def test_match_partial_frontier():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    toks = list(range(24))  # 3 full pages
    from repro.core.paging import BlockTable
    t = BlockTable()
    a.append_tokens(t, 24)
    t_blocks = list(t.blocks)
    c.insert(toks, t.blocks)
    # diverges 4 tokens into page 3: full match 2 pages + partial run of 4
    probe = toks[:20] + [777, 778]
    path = c.match(probe, max_tokens=len(probe) - 1)
    assert len(path) == 2
    partial = c.match_partial(probe, path, max_tokens=len(probe) - 1)
    assert partial is not None
    node, run = partial
    assert run == 4 and node.block == t_blocks[2]
    # page-aligned divergence -> no partial
    probe2 = toks[:16] + [888] * 8
    path2 = c.match(probe2)
    assert c.match_partial(probe2, path2) is None
    # token_level=False restores page-aligned-only behavior
    c2 = PrefixCache(a, token_level=False)
    assert c2.match_partial(probe, path) is None
    a.free_table(t)


def test_scheduler_token_level_hit_cows_boundary_page():
    """Admission with a mid-page hit locks the boundary node and the first
    suffix write COWs it — the tree's page is untouched, the request gets
    its own copy, and nothing leaks."""
    a = BlockAllocator(64, PS)
    c = PrefixCache(a)
    s = IterationScheduler(a, prefix_cache=c, max_tokens_per_iter=999)
    r1 = Request(0, 0.0, list(range(24)), max_new_tokens=2)
    _drive(s, r1)
    tree_path = c.match(list(range(24)))
    boundary_block = tree_path[2].block

    r2 = Request(1, 0.0, list(range(20)) + [777] * 12, max_new_tokens=2)
    s.add_request(r2)
    plan = s.schedule()
    assert r2.num_cached_tokens == 20, \
        "token-level match must recover the 4 mid-page tokens"
    assert [(ch.start, ch.length) for ch in plan.chunks] == [(20, 12)]
    table = s.tables[r2.request_id]
    cow_copy = table.blocks[2]  # (free_table clears the list at finish)
    # the boundary page was COW-copied for r2's divergent suffix
    assert (boundary_block, cow_copy) in plan.cow
    assert cow_copy != boundary_block
    assert c.match(list(range(24)))[2].block == boundary_block, \
        "the tree's own branch must keep its original page"
    r2.output.append(0)
    s.complete_iteration(plan, 10.0)
    _drive(s, max_iters=50, start_it=11.0)
    assert r2.phase == Phase.FINISHED
    # both divergent boundary pages are now cached (post-split siblings)
    assert c.match(list(range(20)) + [777] * 4)[2].block == cow_copy
    c.clear()
    assert a.num_free == 64 and not a.refcount


def test_rescinded_victim_leaves_no_stale_cow_pairs():
    """A request admitted with a partial-page COW and preempted later in
    the SAME schedule() call must take its pending COW pair out of the
    plan: its fresh target block is freed and may be reallocated before
    the engine applies plan.cow — a stale copy would clobber the new
    owner's page."""
    # decode_reserve=False: the reserve (PR 5) forecloses exactly this
    # admit-then-preempt-same-iteration scenario; disable it so the rescind
    # machinery (which still guards decode-vs-decode preemptions and COW
    # shortfalls) keeps its regression coverage
    a = BlockAllocator(10, PS)
    c = PrefixCache(a)
    s = IterationScheduler(a, prefix_cache=c, max_tokens_per_iter=8192,
                           chunk_policy="prefill_first",
                           decode_reserve=False)
    r0 = Request(0, 0.0, list(range(24)), max_new_tokens=2)
    _drive(s, r0)  # seeds the tree with 3 pages
    r1 = Request(1, 0.0, list(range(1000, 1006)), max_new_tokens=20)
    r3 = Request(3, 0.0, list(range(2000, 2006)), max_new_tokens=20)
    s.add_request(r1)
    s.add_request(r3)
    it = 10.0
    # lockstep decode until each table stores exactly 16 tokens (the first
    # output token comes from prefill logits without a KV append, so stored
    # tokens lag n_generated by one): the NEXT decode needs a third page
    while True:
        plan = s.schedule()
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
        if s.tables[1].num_tokens >= 16:
            break
    # r2: token-level hit (2 full pages + 4 mid-page tokens) -> its
    # admission generates a partial-page COW pair. The same iteration, both
    # decoders cross a page boundary; the second finds no free page and
    # preempts the just-admitted r2.
    r2 = Request(2, 0.0, list(range(20)) + [777] * 8, max_new_tokens=2)
    s.add_request(r2)
    plan = s.schedule()
    assert r2 in plan.preempted and r2 in s.waiting
    assert r1 in plan.decode and r3 in plan.decode
    assert r2 not in plan.prefill and not plan.chunks
    assert plan.cow == [], \
        "rescinded victim's pending COW pair must not reach the engine"
    for r in plan.prefill + plan.decode:
        r.output.append(0)
    s.complete_iteration(plan, it)
    _drive(s, max_iters=200, start_it=it + 1)
    assert all(r.phase == Phase.FINISHED for r in (r1, r2, r3))
    c.clear()
    assert a.num_free == 10 and not a.refcount


def test_partial_hit_rollback_under_memory_pressure():
    """If admission cannot get the pages it needs, a locked partial path
    (including the boundary node) unwinds cleanly."""
    a = BlockAllocator(4, PS)
    c = PrefixCache(a)
    s = IterationScheduler(a, prefix_cache=c, max_tokens_per_iter=999,
                           watermark=0.0)
    r1 = Request(0, 0.0, list(range(20)), max_new_tokens=2)
    _drive(s, r1)  # 3 pages; all stay in the tree (2 full inserted + tail)
    # a huge prompt sharing 20 tokens: partial hit, but the 6 pages it needs
    # cannot be found even after eviction of unpinned pages
    r2 = Request(1, 0.0, list(range(20)) + [5] * 28, max_new_tokens=2)
    s.add_request(r2)
    s.schedule()
    # r2 was not admitted and its locks unwound: every page either free or
    # exclusively tree-owned
    assert r2.request_id not in s.tables
    for node in c.match(list(range(16))):
        assert node.pin_count == 0
    c.clear()
    assert a.num_free == 4 and not a.refcount


# -- engine: token identity (acceptance) ---------------------------------------

@pytest.fixture(scope="module")
def model_setup_f32():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None, dtype="float32",
                              logits_fp32=True)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _gathered_prompt_kv(eng, rid, plen):
    """(L, plen, Hkv, Dh) K/V actually stored for the request's prompt."""
    table = eng.scheduler.tables[rid]
    npg = -(-plen // eng.ecfg.page_size)
    idx = jnp.asarray(table.blocks[:npg], jnp.int32)
    L = eng.cfg.num_layers
    k = np.asarray(eng.k_pages[:, idx]).reshape(L, -1, eng.cfg.num_kv_heads,
                                                eng.cfg.head_dim)[:, :plen]
    v = np.asarray(eng.v_pages[:, idx]).reshape(L, -1, eng.cfg.num_kv_heads,
                                                eng.cfg.head_dim)[:, :plen]
    return k, v


def test_engine_chunked_equals_monolithic(model_setup_f32):
    """ACCEPTANCE: a chunked prefill produces exactly the same first sampled
    token and KV state as a monolithic prefill (float32: comparisons are
    exact at argmax resolution), and the full decode matches."""
    cfg, model, params = model_setup_f32
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 42).tolist()

    def build(budget):
        eng = PagedEngine(cfg, params, EngineConfig(
            num_pages=64, page_size=PS, max_slots=4,
            max_tokens_per_iter=budget))
        r = Request(0, 0.0, list(prompt), max_new_tokens=5)
        eng.add_request(r)
        # step until the first token exists (the final chunk's iteration)
        iters = 0
        while not r.output:
            eng.step()
            iters += 1
        return eng, r, iters

    mono_eng, mono_r, mono_iters = build(budget=1000)
    chunk_eng, chunk_r, chunk_iters = build(budget=16)
    assert mono_iters == 1 and chunk_iters == 3  # ceil(42/16) chunks

    # same first sampled token...
    assert chunk_r.output[0] == mono_r.output[0]
    # ...and the same prompt KV state, page layout aside
    km, vm = _gathered_prompt_kv(mono_eng, 0, len(prompt))
    kc, vc = _gathered_prompt_kv(chunk_eng, 0, len(prompt))
    np.testing.assert_allclose(kc, km, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(vc, vm, rtol=1e-5, atol=1e-6)

    # the remaining decode is token-identical too
    mono_eng.run_to_completion()
    chunk_eng.run_to_completion()
    assert chunk_r.full_output == mono_r.full_output


def test_engine_chunked_suffix_after_prefix_hit(model_setup_f32):
    """A radix-cache hit followed by a long suffix: the suffix itself is
    chunked across iterations and the output matches a cold engine."""
    cfg, model, params = model_setup_f32
    rng = np.random.default_rng(12)
    shared = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    suffix = rng.integers(0, cfg.vocab_size, 36).tolist()
    prompt2 = shared + suffix

    cold = PagedEngine(cfg, params, EngineConfig(
        num_pages=64, page_size=PS, max_slots=4, max_tokens_per_iter=1000))
    rc = Request(0, 0.0, list(prompt2), max_new_tokens=4)
    cold.add_request(rc)
    cold.run_to_completion()

    warm = PagedEngine(cfg, params, EngineConfig(
        num_pages=64, page_size=PS, max_slots=4, max_tokens_per_iter=16,
        enable_prefix_cache=True))
    r1 = Request(0, 0.0, list(shared), max_new_tokens=1)
    warm.add_request(r1)
    warm.run_to_completion()  # seeds the tree with the shared pages
    r2 = Request(1, 0.0, list(prompt2), max_new_tokens=4)
    warm.add_request(r2)
    iters_before = warm.iterations
    warm.run_to_completion()
    assert r2.num_cached_tokens >= 2 * PS
    # 36 uncached tokens at budget 16 = 3 chunk iterations minimum
    assert warm.iterations - iters_before >= 3
    assert r2.full_output == rc.full_output, \
        "chunked suffix after a cache hit must be a pure optimization"


def test_engine_token_level_partial_hit_identity(model_setup_f32):
    """Two prompts diverging mid-page: the second request's token-level hit
    resumes prefill at an UNALIGNED boundary from a COW'd page — and still
    decodes token-identically to a cold engine."""
    cfg, model, params = model_setup_f32
    rng = np.random.default_rng(13)
    common = rng.integers(0, cfg.vocab_size, 20).tolist()  # 2.5 pages
    sufa = rng.integers(0, cfg.vocab_size, 6).tolist()
    sufb = rng.integers(0, cfg.vocab_size, 9).tolist()

    cold = PagedEngine(cfg, params, EngineConfig(
        num_pages=64, page_size=PS, max_slots=4))
    rb_cold = Request(0, 0.0, common + sufb, max_new_tokens=4)
    cold.add_request(rb_cold)
    cold.run_to_completion()

    warm = PagedEngine(cfg, params, EngineConfig(
        num_pages=64, page_size=PS, max_slots=4, enable_prefix_cache=True))
    ra = Request(0, 0.0, common + sufa, max_new_tokens=2)
    warm.add_request(ra)
    warm.run_to_completion()
    rb = Request(1, 0.0, common + sufb, max_new_tokens=4)
    warm.add_request(rb)
    warm.run_to_completion()
    assert rb.num_cached_tokens == 20, \
        "mid-page divergence must still hit 2 pages + 4 partial tokens"
    assert rb.full_output == rb_cold.full_output


# -- simulator: chunked vs monolithic ------------------------------------------

def test_sim_chunked_matches_monolithic_and_bounds_stall():
    wl = lambda: make_workload(80, rate=20.0, seed=2, max_len=512,
                               long_frac=0.1, long_len=6000)
    mono = simulate_paged(wl(), num_blocks=3000, max_tokens_per_iter=1024,
                          chunk_policy="monolithic")
    chunked = simulate_paged(wl(), num_blocks=3000, max_tokens_per_iter=1024,
                             chunk_policy="decode_first")
    assert mono.completed_frac == 1.0 and chunked.completed_frac == 1.0
    for rm, rc in zip(mono.requests, chunked.requests):
        assert rm.total_generated == rc.total_generated, \
            "chunked prefill must not change what gets generated"
    # the decode-stall tail shrinks; total work is the same
    assert chunked.p99_tbt < mono.p99_tbt
    assert chunked.throughput_tokens_per_s >= \
        0.95 * mono.throughput_tokens_per_s


def test_sim_ttft_spans_chunks():
    """A long prompt's first token arrives only after its LAST chunk: TTFT
    covers the whole chunked prefill, and prefill_time is multi-iteration."""
    backend = SimBackend(num_blocks=2000, max_tokens_per_iter=512,
                         chunk_policy="decode_first")
    from repro.serving.api import LLMService
    svc = LLMService(backend)
    long = Request(0, 0.0, [], max_new_tokens=4, prompt_len=2000)
    svc.submit_request(long)
    svc.drain()
    assert long.first_token_time is not None
    # 2000 tokens at 512/iter = 4 chunk iterations before the first token
    assert long.first_token_time - long.scheduled_time > \
        3 * backend.cost.t_fixed
    stats = svc.stats()
    assert stats.n_finished == 1
    assert stats.per_instance is None  # single backend: no router breakdown


def test_service_stats_stall_metrics():
    wl = lambda: make_workload(60, rate=25.0, seed=4, max_len=512,
                               long_frac=0.15, long_len=5000)
    mono = simulate_paged(wl(), num_blocks=3000, max_tokens_per_iter=1024,
                          chunk_policy="monolithic")
    chunked = simulate_paged(wl(), num_blocks=3000, max_tokens_per_iter=1024,
                             chunk_policy="decode_first")
    # SimResult-level: per-request worst gaps are recorded
    assert len(chunked.max_tbts) > 0
    assert chunked.p99_tbt < mono.p99_tbt


# -- logprob streaming ---------------------------------------------------------

def test_engine_streams_logprobs(model_setup_f32):
    from repro.serving.api import LLMService, SamplingParams
    cfg, model, params = model_setup_f32
    eng = PagedEngine(cfg, params, EngineConfig(num_pages=32, page_size=PS,
                                                max_slots=2))
    svc = LLMService(eng)
    rng = np.random.default_rng(3)
    svc.submit(rng.integers(0, cfg.vocab_size, 6).tolist(),
               SamplingParams(max_new_tokens=4))
    got_tokens, got_lps = [], []
    while svc.pending:
        for ch in svc.poll():
            assert ch.logprobs is not None, \
                "engine chunks must stream per-token logprobs"
            assert len(ch.logprobs) == len(ch.token_ids)
            got_tokens += ch.token_ids
            got_lps += ch.logprobs
    assert len(got_lps) == 4
    assert all(lp <= 0.0 for lp in got_lps), "log-probabilities are <= 0"
    out = svc._results[0]
    assert out.samples[0].token_logprobs is not None
    assert out.cumulative_logprob == pytest.approx(sum(got_lps), rel=1e-5)


def test_sim_streams_no_logprobs():
    from repro.serving.api import LLMService, SamplingParams
    svc = LLMService(SimBackend(num_blocks=100, block_size=PS))
    svc.submit([1, 2, 3], SamplingParams(max_new_tokens=3))
    chunks = []
    while svc.pending:
        chunks += svc.poll()
    assert chunks and all(ch.logprobs is None for ch in chunks), \
        "the cost-model sim does not score tokens"


# -- router: prefill tokens count as load --------------------------------------

def test_least_loaded_counts_prefill_backlog():
    from repro.serving.router import LeastLoadedPolicy
    heavy = SimBackend(num_blocks=2000, max_tokens_per_iter=256)
    light = SimBackend(num_blocks=2000, max_tokens_per_iter=256)
    # same request COUNT on both; instance 0 carries a 4000-token in-flight
    # prefill, instance 1 a short chat
    heavy.add_request(Request(0, 0.0, [], max_new_tokens=4, prompt_len=4000))
    light.add_request(Request(1, 0.0, [], max_new_tokens=4, prompt_len=8))
    heavy.step()
    light.step()
    assert heavy.scheduler.prefill_backlog_tokens() > 0
    pol = LeastLoadedPolicy()
    probe = Request(2, 0.0, [], max_new_tokens=4, prompt_len=8)
    assert pol.choose(probe, [heavy, light]) == 1, \
        "in-flight prefill tokens must count as load"
