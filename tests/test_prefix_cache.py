"""Radix-tree prefix KV cache: tree unit tests, scheduler integration, and
engine equivalence (cached-prefix prefill must be a pure optimization)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.paging import BlockAllocator, BlockTable
from repro.core.prefixcache import PrefixCache
from repro.core.scheduling import IterationScheduler, Phase, Request
from repro.serving.engine import EngineConfig, PagedEngine


PS = 8  # page size used throughout


def _table_for(alloc, tokens):
    t = BlockTable()
    alloc.append_tokens(t, len(tokens))
    return t


# -- radix tree unit tests -----------------------------------------------------

def test_match_insert_roundtrip():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    toks = list(range(20))  # 2 full pages + partial
    t = _table_for(a, toks)
    blocks = list(t.blocks)
    assert c.insert(toks, t.blocks) == 2  # partial page 3 not insertable
    a.free_table(t)
    # tree's refs keep both full pages alive
    assert a.num_free == 16 - 2
    path = c.match(toks)
    assert [n.block for n in path] == blocks[:2]
    assert all(a.refcount_of(n.block) == 1 for n in path)


def test_match_is_page_aligned_and_capped():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    toks = list(range(PS * 3))
    t = _table_for(a, toks)
    c.insert(toks, t.blocks)
    # divergence in the middle of page 2 stops the walk after page 1
    other = toks[:PS] + [999] + toks[PS + 1:]
    assert len(c.match(other)) == 1
    # a fully-cached prompt capped at len-1 leaves the last page unmatched
    assert len(c.match(toks, max_tokens=len(toks) - 1)) == 2
    assert len(c.match(toks)) == 3
    a.free_table(t)


def test_insert_existing_pages_skipped():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    toks = list(range(PS * 2))
    t1 = _table_for(a, toks)
    assert c.insert(toks, t1.blocks) == 2
    t2 = _table_for(a, toks)  # same tokens, different physical pages
    assert c.insert(toks, t2.blocks) == 0, "duplicate pages are not adopted"
    a.free_table(t1)
    a.free_table(t2)
    assert a.num_free == 16 - 2  # only the first copy is retained


def test_lock_increfs_into_block_table():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    toks = list(range(PS * 2))
    t = _table_for(a, toks)
    c.insert(toks, t.blocks)
    a.free_table(t)
    path = c.match(toks)
    blocks = c.lock(path)
    assert all(a.refcount_of(b) == 2 for b in blocks)  # tree + request
    shared = BlockTable(blocks=list(blocks), num_tokens=PS * 2)
    a.free_table(shared)
    c.release(path)
    assert all(a.refcount_of(b) == 1 for b in blocks)  # tree ref remains


def test_evict_lru_order_and_pinning():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    old = list(range(PS))
    new = list(range(100, 100 + PS))
    t1, t2 = _table_for(a, old), _table_for(a, new)
    c.insert(old, t1.blocks)
    c.insert(new, t2.blocks)
    a.free_table(t1)
    a.free_table(t2)
    c.match(new)  # touch "new" so "old" is LRU
    pinned = c.match(old)
    c.lock(pinned)  # a running request holds "old"
    # eviction must take the unpinned leaf even though it is more recent
    assert c.evict(1) == 1
    assert len(c.match(new)) == 0, "unpinned page was evicted"
    assert len(c.match(old)) == 1, "pinned page survived"
    # and with only pinned leaves left, eviction gives up rather than free
    # a referenced page
    free_before = a.num_free
    assert c.evict(5) == 0
    assert a.num_free == free_before


def test_evict_never_frees_referenced_page():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    toks = list(range(PS))
    t = _table_for(a, toks)
    c.insert(toks, t.blocks)
    block = t.blocks[0]
    # request still holds its own ref (table not freed), node unpinned:
    # the page is not an eviction candidate at all — freeing it is
    # impossible and forgetting it would lose cache for nothing
    assert c.evict(1) == 0
    assert block not in a.free_list
    assert a.refcount_of(block) == 2 and c.num_pages == 1
    a.free_table(t)
    # now exclusively tree-owned -> evictable, page really freed
    assert c.evict(1) == 1
    assert a.num_free == 16


def test_hit_rate_stats():
    a = BlockAllocator(16, PS)
    c = PrefixCache(a)
    c.record_admission(20, 0)
    c.record_admission(20, 16)
    assert c.hit_rate == pytest.approx(16 / 40)
    assert c.stats()["admissions"] == 2


# -- scheduler integration -----------------------------------------------------

def _sched(num_blocks=64, **kw):
    a = BlockAllocator(num_blocks, PS)
    c = PrefixCache(a)
    s = IterationScheduler(a, prefix_cache=c, **kw)
    return a, c, s


def _drain(s, *reqs, max_iters=300):
    for r in reqs:
        s.add_request(r)
    for it in range(max_iters):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            return
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, float(it))


def test_scheduler_second_request_hits_cache():
    a, c, s = _sched(max_tokens_per_iter=64)
    shared = list(range(PS * 2))
    r1 = Request(0, 0.0, shared + [7, 8], max_new_tokens=2)
    _drain(s, r1)
    r2 = Request(1, 0.0, shared + [9, 10], max_new_tokens=2)
    s.add_request(r2)
    plan = s.schedule()
    assert plan.prefill == [r2]
    assert r2.num_cached_tokens == PS * 2
    # budget was charged for the suffix only
    assert plan.token_count() == r2.prompt_len - PS * 2
    # the shared pages are physically shared (tree + r2's table)
    t2 = s.tables[r2.request_id]
    assert all(a.refcount_of(b) == 2 for b in t2.blocks[:2])


def test_insert_at_prefill_not_at_finish():
    """A follow-up sharing the prefix hits while the first request is still
    decoding — pages are adopted at prefill completion, so a same-prefix
    burst doesn't recompute the prefix once per member."""
    a, c, s = _sched(max_tokens_per_iter=20)
    shared = list(range(PS * 2))
    r0 = Request(0, 0.0, shared + [1, 2], max_new_tokens=5)
    r1 = Request(1, 0.0, shared + [3, 4], max_new_tokens=5)
    s.add_request(r0)
    s.add_request(r1)
    plan = s.schedule()  # budget 20 admits only r0 (prompt 18)
    assert plan.prefill == [r0] and not r1.num_cached_tokens
    r0.output.append(0)
    s.complete_iteration(plan, 0.0)
    plan = s.schedule()  # r0 decodes; r1 admitted against the warm tree
    assert r0 in plan.decode and r1 in plan.prefill
    assert r0.phase != Phase.FINISHED
    assert r1.num_cached_tokens == PS * 2


def test_scheduler_no_leak_with_cache():
    a, c, s = _sched(max_tokens_per_iter=128)
    shared = list(range(PS * 2))
    reqs = [Request(i, 0.0, shared + [100 + i], max_new_tokens=3)
            for i in range(6)]
    _drain(s, *reqs)
    assert all(r.phase == Phase.FINISHED for r in reqs)
    # only tree-held pages remain; clearing the cache frees everything
    c.clear()
    assert a.num_free == a.num_blocks and not a.refcount


def test_scheduler_evicts_cache_before_preempting():
    # 8 blocks x 8 = 64 slots. r1 fills + finishes, leaving cached pages;
    # r2 then needs the space back — eviction must free it without any
    # preemption.
    a, c, s = _sched(num_blocks=8, max_tokens_per_iter=999)
    r1 = Request(0, 0.0, list(range(40)), max_new_tokens=2)
    _drain(s, r1)
    assert c.num_pages == 5
    r2 = Request(1, 0.0, list(range(1000, 1040)), max_new_tokens=16)
    _drain(s, r2)
    assert r2.phase == Phase.FINISHED
    assert r2.preemptions == 0
    assert c.evicted_pages > 0


def test_evict_retry_after_preemption_saves_survivor():
    """A victim preempted straight after prefill with a page-aligned prompt
    frees ZERO blocks directly (all its pages live on as tree-held cache
    pages) — the decode loop must then evict those pages rather than
    self-preempt the request it was trying to grow."""
    a, c, s = _sched(num_blocks=5, max_tokens_per_iter=999)
    rb = Request(0, 0.0, list(range(PS)), max_new_tokens=20)
    s.add_request(rb)
    plan = s.schedule()  # rb prefills: 1 page-aligned block
    rb.output.append(0)
    s.complete_iteration(plan, 0.0)
    ra = Request(1, 0.0, list(range(100, 100 + 2 * PS)), max_new_tokens=8)
    s.add_request(ra)
    plan = s.schedule()  # rb decodes (block 2); ra prefills its 2 pages
    assert ra in plan.prefill
    for r in plan.prefill + plan.decode:
        r.output.append(0)
    s.complete_iteration(plan, 1.0)
    # budget 1: only rb (older) decodes; ra never gets a token, so its table
    # stays exactly its two tree-shared prompt pages
    s.max_tokens = 1
    it = 2.0
    while rb.phase != Phase.FINISHED:
        plan = s.schedule()
        assert ra not in plan.decode
        if ra in plan.preempted:
            # the crunch: rb needed a block, ra's preemption freed nothing
            # directly, and the retry-evict reclaimed ra's cached pages
            assert rb in plan.decode, \
                "survivor must not be self-preempted after the victim"
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, it)
        it += 1.0
    assert rb.preemptions == 0 and ra.preemptions == 1
    # drain ra (already re-queued in waiting) with a full budget again
    s.max_tokens = 999
    for it2 in range(100):
        plan = s.schedule()
        if plan.empty and not s.waiting:
            break
        for r in plan.prefill + plan.decode:
            r.output.append(0)
        s.complete_iteration(plan, 100.0 + it2)
    assert ra.phase == Phase.FINISHED
    c.clear()
    assert a.num_free == a.num_blocks and not a.refcount


def test_preempted_request_releases_and_rematches():
    a, c, s = _sched(num_blocks=12, max_tokens_per_iter=999, max_running=4)
    shared = list(range(PS))
    r0 = Request(0, 0.0, shared + [5], max_new_tokens=2)
    _drain(s, r0)  # seeds the tree
    r1 = Request(1, 0.0, shared + [6], max_new_tokens=60)
    r2 = Request(2, 0.0, shared + [7], max_new_tokens=60)
    _drain(s, r1, r2)
    assert r1.phase == Phase.FINISHED and r2.phase == Phase.FINISHED
    c.clear()
    assert a.num_free == a.num_blocks, "locks must unwind through preemption"


# -- simulator mode ------------------------------------------------------------

def test_simulator_prefix_cache_mode():
    from repro.serving.simulator import (make_shared_prefix_workload,
                                         make_workload, simulate_paged)

    def shared():
        # staggered arrivals: early finishers seed the tree for later ones
        return make_shared_prefix_workload(120, rate=40.0, seed=3)

    base = simulate_paged(shared(), num_blocks=3000)
    pc = simulate_paged(shared(), num_blocks=3000, prefix_cache=True)
    assert base.prefix_hit_rate is None
    assert pc.prefix_hit_rate > 0.5
    assert pc.completed_frac == 1.0
    assert pc.throughput_tokens_per_s > base.throughput_tokens_per_s
    assert pc.mean_ttft <= base.mean_ttft

    def unique():
        return make_workload(60, rate=30.0, seed=3, materialize_tokens=True)

    u_base = simulate_paged(unique(), num_blocks=2000)
    u_pc = simulate_paged(unique(), num_blocks=2000, prefix_cache=True)
    assert u_pc.prefix_hit_rate == 0.0
    assert u_pc.throughput_tokens_per_s >= \
        0.98 * u_base.throughput_tokens_per_s


# -- engine equivalence (acceptance criterion) ---------------------------------

@pytest.fixture(scope="module")
def model_setup():
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("h2o-danube-1.8b")
    cfg = dataclasses.replace(cfg, sliding_window=None)
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_shared_prefix_equivalence_and_hit_rate(model_setup):
    """Shared 2-page system prompt across 8 requests: every request after the
    first prefills only its suffix, outputs match the no-cache engine, and
    the prompt-token hit rate clears 50%."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(5)
    system = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    prompts = [system + rng.integers(0, cfg.vocab_size, 6).tolist()
               for _ in range(8)]

    def run(enable):
        eng = PagedEngine(cfg, params, EngineConfig(
            num_pages=64, page_size=PS, max_slots=4,
            enable_prefix_cache=enable))
        outs = []
        for i, p in enumerate(prompts):
            r = Request(i, 0.0, list(p), max_new_tokens=4)
            eng.add_request(r)
            eng.run_to_completion()
            outs.append((r.full_output, r.num_cached_tokens))
        return outs, eng

    base, _ = run(False)
    cached, eng = run(True)
    assert [o for o, _ in base] == [o for o, _ in cached]
    assert all(nc == 0 for _, nc in base)
    assert all(nc == 2 * PS for _, nc in cached[1:]), \
        "every follow-up request must reuse the system-prompt pages"
    stats = eng.prefix_cache_stats()
    assert stats["hit_rate"] >= 0.5


def test_suffix_prefill_logits_match_full_prefill(model_setup):
    """The cached-suffix prefill computes the same first-token logits as the
    full prefill, within fp tolerance."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, 2 * PS + 5).tolist()

    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=32, page_size=PS, max_slots=2, enable_prefix_cache=True))
    r1 = Request(0, 0.0, list(prompt), max_new_tokens=1)
    eng.add_request(r1)
    eng.run_to_completion()  # seeds the radix tree with 2 prompt pages

    # full-prefill logits for the same prompt, computed directly
    full_logits = model.prefill(params, jnp.asarray(prompt, jnp.int32)[None],
                                seq_capacity=64)[0][0]
    # admit an identical prompt: the engine takes the suffix path
    r2 = Request(1, 0.0, list(prompt), max_new_tokens=1)
    eng.add_request(r2)
    plan = eng.scheduler.schedule()
    assert plan.prefill == [r2]
    assert r2.num_cached_tokens == 2 * PS
    table = eng.scheduler.tables[r2.request_id]
    cached = r2.num_cached_tokens
    # build the pow2-bucketed chunk inputs the engine's step() would:
    # tokens pad with zeros (masked via `length`), page_ids pad with a real
    # page (causally masked), empty remote K/V (no zero-copy lease here)
    from repro.serving.engine import _pow2_bucket
    suffix = prompt[cached:]
    tok = np.zeros(_pow2_bucket(len(suffix)), np.int32)
    tok[:len(suffix)] = suffix
    pages = np.full(_pow2_bucket(len(table.blocks), 1), table.blocks[0],
                    np.int32)
    pages[:len(table.blocks)] = table.blocks
    rk = jnp.zeros((eng.nlayers, 0, cfg.num_kv_heads, cfg.head_dim),
                   eng.k_pages.dtype)
    suffix_logits, _, _ = eng._prefill_chunk_fn(
        eng.params, eng.k_pages, eng.v_pages,
        jnp.asarray(tok)[None], jnp.asarray(pages), jnp.int32(cached),
        jnp.int32(len(suffix)), jnp.int32(0), rk, rk)
    np.testing.assert_allclose(np.asarray(suffix_logits),
                               np.asarray(full_logits), rtol=2e-4, atol=2e-4)


def test_engine_swa_prefix_cache(model_setup):
    """Sliding-window arch through the cached-suffix path (window masks the
    gathered prefix pages)."""
    from repro.configs import smoke_config
    from repro.models import Model
    cfg = smoke_config("h2o-danube-1.8b")  # window=64 active
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    shared = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()

    def run(enable):
        eng = PagedEngine(cfg, params, EngineConfig(
            num_pages=64, page_size=PS, max_slots=2,
            enable_prefix_cache=enable))
        outs = []
        for i in range(3):
            r = Request(i, 0.0, shared + [int(100 + i)], max_new_tokens=3)
            eng.add_request(r)
            eng.run_to_completion()
            outs.append(r.full_output)
        return outs

    assert run(False) == run(True)


# -- generated-token caching (multi-turn) --------------------------------------

def test_scheduler_inserts_generated_tokens_at_finish():
    """Finishing a request adopts its *generated* full pages too (KV exists
    for all but the final sampled token), so a follow-up that resends the
    reply as history hits past the prompt."""
    a, c, s = _sched(max_tokens_per_iter=999)
    r1 = Request(0, 0.0, list(range(PS * 2)), max_new_tokens=PS + 1)
    s.add_request(r1)
    it = 0.0
    toks = iter(range(1000, 2000))
    while r1.phase != Phase.FINISHED:
        plan = s.schedule()
        for r in plan.prefill + plan.decode:
            r.output.append(next(toks))  # distinct "real" generated ids
        s.complete_iteration(plan, it)
        it += 1.0
    # prompt pages (2) + one full generated page (PS of PS+1 tokens; the
    # final sampled token has no KV and its page is partial)
    assert c.num_pages == 3
    history = r1.prompt + r1.output  # what a client resends next turn
    r2 = Request(1, 0.0, history + [7, 8], max_new_tokens=2)
    s.add_request(r2)
    plan = s.schedule()
    assert plan.prefill == [r2]
    assert r2.num_cached_tokens == PS * 3, \
        "multi-turn reuse must cover the generated reply, not just the prompt"


def test_scheduler_cache_generated_opt_out():
    """cache_generated=False (the simulator: outputs are placeholder ids)
    keeps the old prompt-only insertion behavior."""
    alloc = BlockAllocator(64, PS)
    cache = PrefixCache(alloc)
    s = IterationScheduler(alloc, prefix_cache=cache, cache_generated=False)
    r1 = Request(0, 0.0, list(range(PS)), max_new_tokens=PS + 1)
    _drain(s, r1)
    assert cache.num_pages == 1  # prompt page only


def test_engine_multi_turn_hits_generated_pages(model_setup):
    """End-to-end multi-turn chat on the engine: turn 2 resends turn 1's
    reply and must hit the radix tree beyond the client-resent prompt —
    and produce identical outputs to a cold engine (pure optimization)."""
    cfg, model, params = model_setup
    rng = np.random.default_rng(21)
    system_user1 = rng.integers(0, cfg.vocab_size, 2 * PS).tolist()
    user2 = rng.integers(0, cfg.vocab_size, 5).tolist()
    n_reply = PS + 1  # KV exists for the first PS generated tokens

    def turn2_prompt(reply):
        return system_user1 + reply + user2

    def run(enable):
        eng = PagedEngine(cfg, params, EngineConfig(
            num_pages=64, page_size=PS, max_slots=2,
            enable_prefix_cache=enable))
        r1 = Request(0, 0.0, list(system_user1), max_new_tokens=n_reply)
        eng.add_request(r1)
        eng.run_to_completion()
        r2 = Request(1, 0.0, turn2_prompt(r1.full_output), max_new_tokens=4)
        eng.add_request(r2)
        eng.run_to_completion()
        return r1, r2

    r1c, r2c = run(False)
    r1w, r2w = run(True)
    assert r1c.full_output == r1w.full_output
    assert r2c.full_output == r2w.full_output, \
        "generated-page reuse must not change the decode"
    # turn-2 hit covers prompt pages AND the first generated page: the
    # resent history is 2*PS prompt + PS+1 reply tokens -> 3 full pages
    assert r2w.num_cached_tokens == 3 * PS
    assert r2c.num_cached_tokens == 0


# -- block-table sizing (satellite) --------------------------------------------

def test_block_table_width_from_context_limit(model_setup):
    cfg, model, params = model_setup
    eng = PagedEngine(cfg, params, EngineConfig(
        num_pages=64, page_size=PS, max_slots=2, max_context_len=40))
    assert eng.max_pages_per_seq == 5  # ceil(40/8), not num_pages=64
    bt, _, _, _ = eng._ctx_arrays()
    assert bt.shape == (2, 5)
    with pytest.raises(ValueError):
        eng.add_request(Request(0, 0.0, [1] * 30, max_new_tokens=20))
    # a fitting request still runs through decode with the narrow table
    r = Request(1, 0.0, [1, 2, 3, 4], max_new_tokens=3)
    eng.add_request(r)
    eng.run_to_completion()
    assert len(r.full_output) == 3


def test_arch_max_seq_len_bounds_width(model_setup):
    cfg, model, params = model_setup
    cfg2 = dataclasses.replace(cfg, max_seq_len=64)
    eng = PagedEngine(cfg2, params, EngineConfig(
        num_pages=64, page_size=PS, max_slots=2))
    assert eng.max_pages_per_seq == 8  # from ArchConfig, not the page supply
