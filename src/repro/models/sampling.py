"""Token sampling for the serving engine.

The fused decode path samples ALL slots in one call with **vectorized
per-slot parameters** — a batch can mix greedy, temperature, top-k, and
top-p requests without leaving the single jitted kernel. Per-request
determinism: each row's PRNG key is derived from its own ``(seed, step)``
pair, so a request draws the same stream whether it runs alone or batched,
whatever slot it lands in, and across preemption/recompute (the step counter
is the request's cumulative token index).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def filter_logits(logits, top_k, top_p):
    """Vectorized per-row top-k / nucleus filtering.

    logits: (n, V) float; top_k: (n,) int32, 0 = disabled; top_p: (n,)
    float in (0, 1], 1 = disabled. Returns logits with filtered entries at
    ``-inf``. Nucleus keeps the *smallest* set of highest-probability tokens
    whose mass reaches ``top_p`` (the argmax always survives).
    """
    n, v = logits.shape
    logits = logits.astype(jnp.float32)
    order = jnp.argsort(-logits, axis=-1)  # descending
    # rank[i, tok] = position of tok in row i's descending order
    ranks = jnp.zeros((n, v), jnp.int32).at[
        jnp.arange(n)[:, None], order].set(jnp.arange(v, dtype=jnp.int32))
    k_eff = jnp.where(top_k > 0, top_k, v)
    keep_k = ranks < k_eff[:, None]
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs_sorted = jax.nn.softmax(sorted_logits, axis=-1)
    # exclusive cumulative mass: token t is kept iff the mass strictly above
    # it is still short of top_p  ->  smallest set with mass >= top_p
    cum_excl = jnp.cumsum(probs_sorted, axis=-1) - probs_sorted
    keep_sorted = cum_excl < top_p[:, None]
    keep_p = jnp.take_along_axis(keep_sorted, ranks, axis=-1)
    return jnp.where(keep_k & keep_p, logits, -jnp.inf)


def sample_batch(logits, seeds, steps, temperature, top_k, top_p):
    """One fused sampling step over all decode slots.

    logits: (n, V); seeds/steps: (n,) int32 per-request PRNG stream ids;
    temperature/top_k/top_p: (n,). Rows with ``temperature <= 0`` are greedy
    (argmax over raw logits). Temperature scaling happens BEFORE the
    top-k/top-p filters (vLLM/HF semantics: the nucleus is taken over the
    temperature-shaped distribution). Returns ``(tokens (n,) int32,
    logprobs (n,) float32)`` — logprobs are log p(token) under the raw
    (unfiltered, unscaled) distribution, for best-of-n ranking.
    """
    logits = logits.astype(jnp.float32)
    greedy_tok = greedy(logits)
    # greedy rows get a dummy temperature of 1 so scaling stays finite;
    # their sampled value is discarded below
    safe_t = jnp.where(temperature > 0, temperature, 1.0)[:, None]
    scaled = filter_logits(logits / safe_t, top_k, top_p)
    keys = jax.vmap(
        lambda s, c: jax.random.fold_in(jax.random.PRNGKey(s), c))(
            seeds, steps)
    drawn = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    tokens = jnp.where(temperature > 0, drawn, greedy_tok)
    logprobs = jnp.take_along_axis(
        jax.nn.log_softmax(logits, axis=-1), tokens[:, None], axis=-1)[:, 0]
    return tokens, logprobs


def sample(logits, key, *, temperature: float = 1.0, top_k: int = 0):
    """Scalar-parameter sampling (legacy path; the engine uses
    :func:`sample_batch`). logits: (B, V). temperature<=0 => greedy."""
    if temperature <= 0:
        return greedy(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
