"""Mamba2 SSD chunked-scan Pallas kernel (beyond-paper extension).

Grid ``(B, H, num_chunks)`` with the chunk axis sequential ("arbitrary"):
the SSD state ``(P, N)`` lives in VMEM scratch and carries across chunks —
the inter-chunk recurrence runs inside the kernel, the intra-chunk quadratic
term uses MXU matmuls on ``(chunk x chunk)`` tiles. One grid step streams one
``(chunk, P)`` x-tile and ``(chunk, N)`` B/C-tiles HBM→VMEM.

Equivalent math to ``repro.models.ssm.ssd_chunked`` (the XLA path used by
the models) and to the sequential oracle ``ref.ssd_scan_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)    # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (L,)
    a = a_ref[0]                              # scalar A_h (negative)
    bmat = b_ref[0, 0, 0].astype(jnp.float32)  # (L, N)
    cmat = c_ref[0, 0, 0].astype(jnp.float32)  # (L, N)

    adt = dt * a                              # (L,)
    cum = jnp.cumsum(adt)                     # (L,)
    xdt = x * dt[:, None]                     # (L, P)

    # intra-chunk quadratic term: Lmat[i,j] = exp(cum_i - cum_j) for j<=i
    diff = cum[:, None] - cum[None, :]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = (cmat @ bmat.T) * lmat           # (L, L)
    y = scores @ xdt                          # (L, P)

    # contribution of the incoming inter-chunk state
    decay_in = jnp.exp(cum)[:, None]          # (L, 1)
    y += (cmat @ state_ref[...].T) * decay_in  # (L,N)@(N,P) -> (L,P)

    # state update: S' = S * exp(sum adt) + sum_j decay(end-j) B_j xdt_j
    decay_out = jnp.exp(cum[-1] - cum)[:, None]  # (L, 1)
    state_ref[...] = (state_ref[...] * jnp.exp(cum[-1]) +
                      (decay_out * xdt).T @ bmat)  # (P, N)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_out_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = True):
    """x: (b,l,h,p); dt: (b,l,h) fp32 post-softplus; A: (h,); B,C: (b,l,g,n).
    Returns (y (b,l,h,p) fp32, final_state (b,h,p,n) fp32)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert l % chunk == 0
    nc = l // chunk

    # (b, h, nc, L, ...) layouts so one grid step reads one chunk tile
    xh = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dth = dt.astype(jnp.float32).transpose(0, 2, 1).reshape(b, h, nc, chunk)
    bh = B.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)
    ch = C.transpose(0, 2, 1, 3).reshape(b, g, nc, chunk, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bb, hh, cc: (bb, hh, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1,), lambda bb, hh, cc, rep=rep: (hh,)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bb, hh, cc, rep=rep: (bb, hh // rep, cc, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n),
                         lambda bb, hh, cc, rep=rep: (bb, hh // rep, cc, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bb, hh, cc: (bb, hh, cc, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, chunk, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dth, A.astype(jnp.float32), bh, ch)
    y = y.reshape(b, h, l, p).transpose(0, 2, 1, 3)
    return y, state
