"""Distributed training launcher.

On real hardware this runs under ``jax.distributed`` with the production
mesh; on this CPU container it runs the same code over the host mesh with a
reduced config (the dry-run covers the full-scale lowering).

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --steps 100 --reduced [--model-parallel 2]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model
from repro.training import checkpoint, optimizer
from repro.training.data import DataConfig, SyntheticCorpus
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="h2o-danube-1.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (requires 256 devices)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(args.model_parallel))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    model = Model(cfg, remat=not args.reduced)
    policy = shd.MeshPolicy(mesh, cfg)
    ocfg = optimizer.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                               total_steps=args.steps)
    with jax.sharding.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        p_shape = jax.eval_shape(lambda: params)
        p_shard = shd.param_shardings(p_shape, mesh, cfg)
        params = jax.device_put(params, p_shard)
        opt_state = optimizer.init(params)
        step_fn = jax.jit(make_train_step(model, ocfg, policy),
                          donate_argnums=(0, 1))

        dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch)
        corpus = SyntheticCorpus(dcfg)
        t0 = time.monotonic()
        for step, batch in enumerate(corpus.batches()):
            if step >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == args.steps - 1:
                dt = time.monotonic() - t0
                print(f"step {step:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"({dt:.1f}s)")
        if args.ckpt_dir:
            path = checkpoint.save(args.ckpt_dir, args.steps,
                                   {"params": params})
            print("checkpoint:", path)


if __name__ == "__main__":
    main()
