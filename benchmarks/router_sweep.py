"""Cluster-router policy sweep: placement policy x workload at N instances.

Replays a shared-prefix workload (a handful of hot system prompts) and a
unique-prompt workload (ShareGPT-like, no sharing available) through the
virtual-clock multi-instance sim (`serving.router.RouterBackend` over N
`SimBackend`s) for each placement policy, with and without cross-instance
prefix sharing over the distkv publication board.

Expected headline (the PR's acceptance bar): at N >= 4 instances,
`prefix_affinity` beats `round_robin` on prefix-cache hit rate and mean
TTFT for shared-prefix traffic, and does not regress the unique workload;
`prefix_share` lifts the load-based policies' hit rate toward affinity's by
letting instances adopt each other's hot prefixes.

    PYTHONPATH=src python benchmarks/router_sweep.py [--smoke]
"""

from __future__ import annotations

import argparse

from repro.serving.router import POLICIES
from repro.serving.simulator import (make_shared_prefix_workload,
                                     make_workload, simulate_router)

N_INSTANCES = 4
BLOCKS_PER_INSTANCE = 600
BLOCK_SIZE = 16


def _workloads(n_requests: int):
    return [
        # 8 hot system prompts in a stochastic tenant mix: the affinity case
        # (random group draw — a cyclic draw can accidentally align with
        # round-robin placement and make it look affine)
        ("shared-prefix", lambda: make_shared_prefix_workload(
            n_requests, rate=80.0, n_groups=8, prefix_len=384,
            suffix_len=48, out_len=64, seed=13, group_draw="random")),
        # one-off prompts: the control — no policy may regress it
        ("unique", lambda: make_workload(
            n_requests, rate=40.0, dist="sharegpt", seed=13, max_len=1024,
            materialize_tokens=True)),
    ]


def run(n_requests: int = 240, n_instances: int = N_INSTANCES,
        verbose: bool = True):
    rows = []
    for wname, wl in _workloads(n_requests):
        for policy in POLICIES:
            for share in (False, True):
                res = simulate_router(
                    wl(), n_instances=n_instances, policy=policy,
                    prefix_share=share,
                    blocks_per_instance=BLOCKS_PER_INSTANCE,
                    block_size=BLOCK_SIZE)
                rows.append({
                    "workload": wname,
                    "policy": policy,
                    "share": share,
                    "hit_rate": res.prefix_hit_rate or 0.0,
                    "mean_ttft": res.mean_ttft,
                    "throughput": res.throughput_tokens_per_s,
                    "adopted_pages": res.adopted_pages,
                    "completed": res.completed_frac,
                })
                if verbose:
                    r = rows[-1]
                    print(f"{wname:13s} {policy:16s} "
                          f"share={'y' if share else 'n'}  "
                          f"hit={r['hit_rate']:6.1%}  "
                          f"ttft={1e3 * r['mean_ttft']:7.2f}ms  "
                          f"thr={r['throughput']:8.1f} tok/s  "
                          f"adopted={r['adopted_pages']:4d}  "
                          f"done={r['completed']:.0%}")
    return rows


def headline(rows) -> str:
    """The acceptance comparison: prefix_affinity vs round_robin (no share)
    on the shared-prefix workload, plus the unique-workload guard."""
    def pick(workload, policy):
        return next(r for r in rows if r["workload"] == workload
                    and r["policy"] == policy and not r["share"])

    rr = pick("shared-prefix", "round_robin")
    pa = pick("shared-prefix", "prefix_affinity")
    rru = pick("unique", "round_robin")
    pau = pick("unique", "prefix_affinity")
    ok = (pa["hit_rate"] >= rr["hit_rate"]
          and pa["mean_ttft"] <= rr["mean_ttft"]
          and pau["mean_ttft"] <= 1.05 * rru["mean_ttft"]
          and pau["completed"] >= rru["completed"])
    return (f"affinity_vs_rr: hit {rr['hit_rate']:.1%}->{pa['hit_rate']:.1%} "
            f"ttft {1e3 * rr['mean_ttft']:.2f}->{1e3 * pa['mean_ttft']:.2f}ms "
            f"unique_guard={'ok' if ok else 'FAIL'}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI run; exits nonzero if prefix_affinity "
                         "loses to round_robin on shared-prefix traffic")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--instances", type=int, default=N_INSTANCES)
    args = ap.parse_args()
    n = args.requests or (96 if args.smoke else 240)
    rows = run(n_requests=n, n_instances=args.instances)
    line = headline(rows)
    print(line)
    if args.smoke and "FAIL" in line:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
