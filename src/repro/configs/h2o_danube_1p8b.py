"""H2O-Danube 1.8B — llama+mistral mix with sliding-window attention [arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
The sliding window bounds the KV cache, so ``long_500k`` runs for this dense arch.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    arch_id="h2o-danube-1.8b",
    family="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    attention="gqa",
    sliding_window=4096,
    max_seq_len=16384,
)
